"""Mamba2 mixer with SSD (state-space duality) chunked scan [arXiv:2405.21060].

The chunked SSD computation here is the pure-jnp oracle; the Pallas kernel in
``repro.kernels.ssd`` implements the same math tiled for VMEM.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rms_norm


# ---------------------------------------------------------------------------
# SSD core (also the kernel oracle — kernels/ssd/ref.py re-exports this)
# ---------------------------------------------------------------------------

def segsum(a):
    """a: (..., Q) log-decay increments -> (..., Q, Q) lower-tri segment sums:
    out[i, j] = sum_{t in (j, i]} a[t] for i >= j, -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk, h0=None):
    """Chunked SSD scan.

    x: (b, s, h, p)   inputs (already multiplied by dt)
    a: (b, s, h)      log decay = A * dt  (<= 0)
    B: (b, s, n)      input projection (single group, shared across heads)
    C: (b, s, n)      output projection
    h0: (b, h, p, n)  optional initial state
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    s_orig = s
    if s % Q:
        # pad with zero inputs and zero log-decay: padded steps leave the
        # state unchanged and contribute nothing, so outputs/final state are
        # exact for the first s_orig positions.
        pad = Q - s % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // Q

    xr = x.reshape(b, nc, Q, h, p)
    Br = B.reshape(b, nc, Q, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, Q, n).astype(jnp.float32)
    ar = a.reshape(b, nc, Q, h).transpose(0, 3, 1, 2).astype(jnp.float32)  # (b,h,nc,Q)
    a_cs = jnp.cumsum(ar, axis=-1)                                         # (b,h,nc,Q)

    # intra-chunk (quadratic within a chunk)
    L = jnp.exp(segsum(ar))                                   # (b,h,nc,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)            # (b,nc,Q,Q)
    y_diag = jnp.einsum("bcqk,bhcqk,bckhp->bcqhp", scores, L,
                        xr.astype(jnp.float32))

    # chunk final states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)             # (b,h,nc,Q)
    states = jnp.einsum("bckn,bhck,bckhp->bchpn", Br, decay_states,
                        xr.astype(jnp.float32))               # (b,nc,h,p,n)

    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([h0[:, None].astype(jnp.float32), states], axis=1)
    a_sum = a_cs[..., -1]                                     # (b,h,nc)
    a_sum = jnp.pad(a_sum, ((0, 0), (0, 0), (1, 0)))          # (b,h,nc+1)
    decay_chunk = jnp.exp(segsum(a_sum))                      # (b,h,nc+1,nc+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states = new_states[:, :-1]                          # state entering chunk
    final_state = new_states[:, -1]                           # (b,h,p,n)

    state_decay = jnp.exp(a_cs)                               # (b,h,nc,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cr, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, a, B, C, h_prev):
    """Single-token SSD state update.

    x: (b, h, p) (already * dt); a: (b, h); B, C: (b, n); h_prev: (b, h, p, n).
    Returns (y (b, h, p), h_new)."""
    decay = jnp.exp(a.astype(jnp.float32))[..., None, None]
    h_new = h_prev * decay + jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32),
                                        B.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), h_new)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Mamba2 mixer layer
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm.d_state
    h = cfg.ssm_heads
    ck = cfg.ssm.conv_kernel
    ks = jax.random.split(key, 4)
    # dt bias init: softplus(dt_bias) uniform-ish in [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype=dtype),
        "conv_w": dense_init(ks[1], (ck, di + 2 * n), scale=1.0 / math.sqrt(ck),
                             dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], (di, d),
                               scale=1.0 / math.sqrt(di * 2 * cfg.num_layers),
                               dtype=dtype),
    }


def _split_proj(zxbcdt, cfg):
    di, n, h = cfg.d_inner, cfg.ssm.d_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv. xBC: (B, S, Ch); w: (K, Ch)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def mamba_layer(x, p, cfg, *, state=None):
    """x: (B, S, D). If state is given (decode, S==1):
    state = {"conv": (B, K-1, Ch), "ssm": (B, H, P, N)} -> returns new state.
    Otherwise returns the final state (for prefill -> decode handoff)."""
    B, S, D = x.shape
    di, n, h = cfg.d_inner, cfg.ssm.d_state, cfg.ssm_heads
    P = cfg.ssm.head_dim
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    A = -jnp.exp(p["A_log"])                                  # (h,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,h)

    if state is None:
        xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        xh = xBC[..., :di].reshape(B, S, h, P)
        Bp = xBC[..., di:di + n]
        Cp = xBC[..., di + n:]
        y, final = ssd_chunked(xh * dt[..., None].astype(xh.dtype),
                               dt * A[None, None, :], Bp, Cp, cfg.ssm.chunk)
        y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
        # pre-activation conv inputs for decode handoff (zero-left-pad when the
        # prompt is shorter than the conv receptive field — matches causal pad)
        K1 = cfg.ssm.conv_kernel - 1
        _, xBC_raw, _ = _split_proj(zxbcdt, cfg)
        tail = xBC_raw[:, max(0, S - K1):, :]
        if S < K1:
            tail = jnp.pad(tail, ((0, 0), (K1 - S, 0), (0, 0)))
        new_state = {"conv": tail, "ssm": final}
    else:
        window = jnp.concatenate([state["conv"], xBC], axis=1)  # (B, K, Ch)
        conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        xBC1 = jax.nn.silu(conv_out)[:, None, :]               # (B,1,Ch)
        xh = xBC1[..., :di].reshape(B, h, P)
        Bp = xBC1[:, 0, di:di + n]
        Cp = xBC1[:, 0, di + n:]
        dt1 = dt[:, 0]                                         # (B,h)
        y, ssm_new = ssd_decode_step(xh * dt1[..., None].astype(xh.dtype),
                                     dt1 * A[None, :], Bp, Cp, state["ssm"])
        y = (y + p["D"][None, :, None].astype(jnp.float32) * xh.astype(jnp.float32)
             ).astype(x.dtype)[:, None]                        # (B,1,h,P)
        y = y.reshape(B, 1, h, P)
        new_state = {"conv": window[:, 1:, :], "ssm": ssm_new}

    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_state
