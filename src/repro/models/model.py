"""Unified model facade: init / train_loss / prefill / decode_step / init_cache.

Dispatches on config family:
  dense | moe | vlm | audio -> transformer stack
  ssm | hybrid              -> mamba2 / zamba2 stack
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as hybrid_mod
from repro.models import transformer as tf_mod

MOE_AUX_COEF = 0.01
# context length beyond which hybrid archs switch their (shared) attention to
# a sliding window (DESIGN.md §4 long-context adaptation)
FULL_ATTN_MAX_CTX = 32_768


def _backend(cfg: ModelConfig):
    return hybrid_mod if cfg.family in ("ssm", "hybrid") else tf_mod


def _window_for(cfg: ModelConfig, ctx_len: int) -> int:
    if cfg.family == "hybrid" and ctx_len > FULL_ATTN_MAX_CTX:
        return cfg.sliding_window_long
    return 0


class LM:
    """Pure-functional model wrapper (all methods are jit-safe)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------
    def init_params(self, rng):
        return _backend(self.cfg).init_params(rng, self.cfg)

    def param_shapes(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init_params, rng)

    # -- inputs ------------------------------------------------------------
    def embed_inputs(self, params, batch):
        """batch has 'tokens' (B,S) int32 or 'embeds' (B,S,D)."""
        if "embeds" in batch:
            return batch["embeds"].astype(params["embed"].dtype)
        return jnp.take(params["embed"], batch["tokens"], axis=0)

    def logits(self, params, hidden):
        head = params.get("lm_head", None)
        if head is None:
            head = params["embed"].T
        return (hidden @ head).astype(jnp.float32)

    # -- training ----------------------------------------------------------
    def train_loss(self, params, batch, *, remat=True):
        """batch: {'tokens'|'embeds', 'labels' (B,S) int32}. Returns
        (loss, metrics)."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        S = x.shape[1]
        window = _window_for(cfg, S)
        if cfg.family in ("ssm", "hybrid"):
            hidden, aux = hybrid_mod.forward(params, x, cfg, remat=remat,
                                             window=window)
        else:
            hidden, aux = tf_mod.forward(params, x, cfg, remat=remat,
                                         window=window)
        labels = batch["labels"]
        from repro.distributed import hints as _hints
        hp = _hints.current()
        chunk = hp.ce_chunk if hp is not None else None
        if chunk and cfg.vocab_size > chunk:
            ce = _chunked_ce(params, hidden, labels, cfg, chunk, self)
        else:
            logits = self.logits(params, hidden)           # (B,S,V) f32
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, labels[..., None],
                                     axis=-1)[..., 0]
            ce = (logz - ll).mean()
        loss = ce + MOE_AUX_COEF * aux
        return loss, {"ce": ce, "aux": aux}

    # -- serving -----------------------------------------------------------
    def prefill(self, params, batch, *, max_len=None, last_index=None,
                moe_mode="grouped"):
        """Returns (last-token logits (B,V), cache). ``last_index`` selects
        which position's logits to return (for right-padded prompts);
        defaults to the final position."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        S = x.shape[1]
        window = _window_for(cfg, max_len or S)
        if cfg.is_encoder:
            hidden, _ = tf_mod.forward(params, x, cfg, remat=False,
                                       window=window)
            return self.logits(params, hidden), None
        kw = {"moe_mode": moe_mode} if cfg.family == "moe" else {}
        hidden, cache = _backend(cfg).prefill(params, x, cfg,
                                              max_len=max_len, window=window,
                                              **kw)
        if last_index is None:
            last = hidden[:, -1]
        else:
            last = hidden[:, last_index]
        return self.logits(params, last), cache

    def decode_step(self, params, tokens, cache):
        """tokens: (B,) int32. Returns (logits (B,V), new cache)."""
        cfg = self.cfg
        ctx = _cache_ctx_len(cfg, cache)
        window = _window_for(cfg, ctx)
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        hidden, cache = _backend(cfg).decode_step(params, x, cfg, cache,
                                                  window=window)
        return self.logits(params, hidden[:, 0]), cache

    def init_cache(self, batch, max_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.param_dtype)
        return _backend(cfg).init_cache(cfg, batch, max_len, dtype)


def _cache_ctx_len(cfg, cache):
    # kv caches are (L|G, B, KH, S, hd): seq is dim 3
    if cfg.family in ("ssm", "hybrid"):
        if "k" in cache:
            return cache["k"].shape[3]
        return 0
    return cache["k"].shape[3]


def make_model(cfg: ModelConfig) -> LM:
    return LM(cfg)


def _chunked_ce(params, hidden, labels, cfg, chunk, model):
    """Blockwise cross-entropy: scan over vocab chunks carrying the online
    logsumexp state, never materializing the full (B,S,V) logits.  For
    small-model / large-vocab training the full-logit tensor (and its
    gradient all-gathers) dominates the roofline (EXPERIMENTS.md §Perf:
    mamba2-130m train is 53 GB/layer-step of lm-head collectives)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    d, V = head.shape
    nc = -(-V // chunk)
    pad = nc * chunk - V
    if pad:
        head = jnp.pad(head, ((0, 0), (0, pad)),
                       constant_values=0.0)

    B, S, _ = hidden.shape
    NEG = jnp.float32(-1e30)

    def body(carry, i):
        m, s, ll = carry
        w = lax.dynamic_slice(head, (0, i * chunk), (d, chunk))
        lg = (hidden @ w).astype(jnp.float32)              # (B,S,chunk)
        if pad:
            valid = (i * chunk + jnp.arange(chunk)) < V
            lg = jnp.where(valid[None, None, :], lg, NEG)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        loc = labels - i * chunk
        in_ch = (loc >= 0) & (loc < chunk)
        picked = jnp.take_along_axis(
            lg, jnp.clip(loc, 0, chunk - 1)[..., None], axis=-1)[..., 0]
        ll = ll + jnp.where(in_ch, picked, 0.0)
        return (m_new, s, ll), None

    m0 = jnp.full((B, S), NEG, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    ll0 = jnp.zeros((B, S), jnp.float32)
    (m, s, ll), _ = lax.scan(body, (m0, s0, ll0), jnp.arange(nc))
    logz = m + jnp.log(jnp.maximum(s, 1e-30))
    return (logz - ll).mean()
