"""Top-k token-choice MoE with grouped, capacity-limited gather dispatch.

Two execution modes (DESIGN.md §5):

* ``grouped`` (train / prefill): tokens are grouped per sequence; each expert
  gathers its top-``capacity`` tokens *within each group* by gate priority
  (GShard-style capacity with priority dropping, but gather/scatter based — no
  one-hot dispatch einsum, so HLO FLOPs stay ~= useful expert FLOPs). The
  expert (E) dimension of the batched GEMMs shards over the ``model`` mesh
  axis (EP); the group (G) dimension shards over ``data``.
* ``dense`` (decode): token count per step is tiny, the step is weight-read
  bound, and routing drops are unacceptable mid-generation — every expert
  computes every token and results are combined by gates. Zero drops; the
  extra FLOPs are irrelevant next to the HBM weight reads.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init


def init_moe(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    E = cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), scale=0.02, dtype=jnp.float32),
        "w1": dense_init(ks[1], (E, d, f), dtype=dtype),
        "w3": dense_init(ks[2], (E, d, f), dtype=dtype),
        "w2": dense_init(ks[3], (E, f, d),
                         scale=1.0 / math.sqrt(f * 2 * cfg.num_layers), dtype=dtype),
    }


def _routing(x, p, cfg):
    """Returns (gate_full (B,S,E), gates (B,S,k), idx (B,S,k), aux)."""
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    logits = (x.astype(jnp.float32) @ p["router"])            # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)                          # (B,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (B,S,k,E)
    gate_full = (onehot * gates[..., None]).sum(axis=2)       # (B,S,E)
    # Switch-style load-balance loss
    frac_tokens = (onehot.sum(axis=2) > 0).astype(jnp.float32).mean(axis=(0, 1))
    mean_probs = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return gate_full, gates, idx, aux


def moe_ffn(x, p, cfg, mode="grouped", combine="gather"):
    """x: (B, S, D) -> (out (B,S,D), aux_loss).

    ``combine``: how expert outputs return to token order.
      * "gather" (default): each token gathers its top-k experts' outputs
        via the inverse dispatch permutation.  Gathers partition cleanly
        under GSPMD: only the gathered (B,S,k,D) crosses expert shards.
      * "scatter": the classic scatter-add combine.  The partitioner
        expands a scatter whose updates are expert-sharded into per-expert
        masked all-reduces of the FULL (B,S,D) output — 32 all-reduces/layer
        for dbrx (§Perf) — kept as the paper-faithful baseline.
    """
    B, S, D = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    gate_full, gates, idx, aux = _routing(x, p, cfg)

    if mode == "dense" or S * k < 4 * E:
        # decode / tiny-token path: no drops, combine by gates
        h1 = jnp.einsum("bsd,edf->bsef", x, p["w1"])
        h3 = jnp.einsum("bsd,edf->bsef", x, p["w3"])
        y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h1) * h3, p["w2"])
        out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32),
                         gate_full).astype(x.dtype)
        return out, aux

    cf = cfg.moe.capacity_factor
    cap = int(math.ceil(cf * S * k / E))
    cap = min(S, -(-cap // 4) * 4)                            # pad to multiple of 4
    gate_es = gate_full.transpose(0, 2, 1)                    # (B,E,S)
    topc_gate, topc_idx = lax.top_k(gate_es, cap)             # (B,E,cap)
    x_e = jnp.take_along_axis(
        x[:, None, :, :],                                     # (B,1,S,D)
        topc_idx[..., None], axis=2)                          # (B,E,cap,D)

    h1 = jnp.einsum("becd,edf->becf", x_e, p["w1"])
    h3 = jnp.einsum("becd,edf->becf", x_e, p["w3"])
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(h1) * h3, p["w2"])

    if combine == "gather":
        # inverse permutation: pos[b,s,e] = slot of token s in expert e's
        # capacity buffer, or ``cap`` (-> zero-padded row) if dropped
        bb = jnp.arange(B)[:, None, None]
        ee = jnp.arange(E)[None, :, None]
        cc = jnp.broadcast_to(jnp.arange(cap)[None, None, :], (B, E, cap))
        pos = jnp.full((B, S, E), cap, jnp.int32)
        pos = pos.at[bb, topc_idx, ee].set(cc, mode="drop")
        slot = jnp.take_along_axis(pos, idx, axis=2)          # (B,S,k)
        y = y.astype(x.dtype)                                 # combine in bf16
        from repro.distributed import hints as _hints
        hp = _hints.current()
        if hp is not None and hp.moe_ep:
            # gathering across the expert-sharded dim would otherwise lower
            # to per-expert masked all-reduces of the full (B,S,k,D) result
            # (68 GB/layer, phi3.5 §Perf): replicate experts FIRST (one
            # explicit all-gather of y) and gather shard-locally
            y = _hints.constrain(y, ((hp.dp or ("data",)), None, None, None))
        y_pad = jnp.pad(y, ((0, 0), (0, 0), (0, 1), (0, 0)))  # slot==cap -> 0
        bb2 = jnp.arange(B)[:, None, None]
        yk = y_pad[bb2, idx, slot]                            # (B,S,k,D)
        out = jnp.einsum("bskd,bsk->bsd", yk, gates,
                         preferred_element_type=jnp.float32)
        return out.astype(x.dtype), aux

    y = y.astype(jnp.float32) * topc_gate[..., None]          # zero where gate==0
    out = jnp.zeros((B, S, D), jnp.float32)
    bidx = jnp.arange(B)[:, None]
    out = out.at[bidx, topc_idx.reshape(B, E * cap)].add(
        y.reshape(B, E * cap, D), mode="drop")
    return out.astype(x.dtype), aux
