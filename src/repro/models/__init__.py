from repro.models.model import LM, make_model

__all__ = ["LM", "make_model"]
