"""Dense / MoE / VLM / audio-encoder transformer stack.

Layers are homogeneous and stacked (leading L axis) so the whole stack runs
under ``lax.scan`` with per-layer remat — this keeps HLO size O(1) in depth,
which matters for the 512-device dry-run compiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import (
    attention_layer, chunked_attention, decode_attention, dense_init,
    init_attention, init_mlp, mlp_layer, rms_norm, rope,
)
from repro.models.moe import init_moe, moe_ffn


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_block(key, cfg):
    dtype = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.moe:
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.num_layers, dtype)
    return p


def init_params(key, cfg):
    dtype = _dtype(cfg)
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    params = {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_size),
                                       dtype=dtype)
    return params


def _block(x, lp, cfg, positions, *, cache=None, cache_index=None, window=0,
           moe_mode="grouped", return_kv=False):
    """One transformer block. Returns (x, new_cache_or_kv, aux)."""
    h, kv = attention_layer(
        rms_norm(x, lp["norm1"], cfg.norm_eps), lp["attn"], cfg,
        positions=positions, cache=cache, cache_index=cache_index,
        window=window, return_kv=return_kv)
    x = x + h
    g = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe:
        f, aux = moe_ffn(g, lp["moe"], cfg, mode=moe_mode)
    else:
        f, aux = mlp_layer(g, lp["mlp"]), jnp.float32(0.0)
    return x + f, kv, aux


def forward(params, x, cfg, *, remat=True, moe_mode="grouped", window=0):
    """Full-sequence forward (train / encoder). x: (B,S,D) embeddings.
    Returns (hidden (B,S,D), aux_loss)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(carry, lp):
        h, aux = carry
        h2, _, aux_l = _block(h, lp, cfg, positions, window=window,
                              moe_mode=moe_mode)
        return (h2, aux + aux_l), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def prefill(params, x, cfg, *, max_len=None, window=0, moe_mode="grouped"):
    """Forward that also materializes the KV cache for decode.
    Returns (hidden (B,S,D), cache dict). Serving paths pass
    ``moe_mode='dense'`` (no capacity drops — generation must not depend on
    batch composition); the throughput-oriented dry-run keeps 'grouped'."""
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(h, lp):
        h2, (k, v), _ = _block(h, lp, cfg, positions, window=window,
                               moe_mode=moe_mode, return_kv=True)
        # store kv-heads-major (B,KH,S,hd): decode contractions then need
        # no transpose copies of the cache (§Perf iteration 3)
        return h2, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    x, (ks, vs) = lax.scan(body, x, params["layers"])
    if max_len > S:
        pad = ((0, 0), (0, 0), (0, 0), (0, max_len - S), (0, 0))
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs,
             "len": jnp.full((B,), S, jnp.int32)}
    return rms_norm(x, params["final_norm"], cfg.norm_eps), cache


def decode_step(params, x, cfg, cache, *, window=0):
    """x: (B,1,D) embedding of the new token. Returns (hidden (B,1,D), cache).

    The layer scan only emits each layer's new kv vectors; the stacked cache
    is updated with ONE batched scatter afterwards (per-layer in-scan cache
    updates cost a full-cache round trip per layer — §Perf)."""
    positions = cache["len"][:, None]

    def body(h, xs):
        lp, kc, vc = xs
        h2, (kn, vn), _ = _block(h, lp, cfg, positions,
                                 cache={"k": kc, "v": vc},
                                 cache_index=cache["len"],
                                 window=window, moe_mode="dense")
        return h2, (kn, vn)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    kc = _scatter_new_kv(cache["k"], ks, cache["len"])
    vc = _scatter_new_kv(cache["v"], vs, cache["len"])
    new_cache = {"k": kc, "v": vc, "len": cache["len"] + 1}
    return rms_norm(x, params["final_norm"], cfg.norm_eps), new_cache


def _scatter_new_kv(cache, new, lens):
    """Write new kv vectors into the stacked cache in ONE scatter.

    cache: (L, B, KH, S, hd); new: (L, B, KH, hd); lens: (B,) positions.
    Flattening (B, KH) makes the two advanced-index dims ADJACENT, which
    keeps the scatter in place (non-adjacent advanced indices make XLA's
    scatter expander materialize transposed copies of the whole cache —
    §Perf iteration log, yi-34b decode)."""
    L, B, KH, S, hd = cache.shape
    flat = cache.reshape(L, B * KH, S, hd)
    rows = jnp.arange(B * KH)
    seqi = jnp.repeat(lens, KH)
    upd = new.astype(cache.dtype).reshape(L, B * KH, hd)
    flat = flat.at[:, rows, seqi].set(upd)
    return flat.reshape(L, B, KH, S, hd)


def init_cache(cfg, batch, max_len, dtype):
    L, KH, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    # kv-heads-major (B,KH,S,hd): matches the decode contraction layout
    return {
        "k": jnp.zeros((L, batch, KH, max_len, hd), dtype),
        "v": jnp.zeros((L, batch, KH, max_len, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
