"""SSM-only (Mamba2) and hybrid (Zamba2-style) stacks.

Hybrid = Mamba2 backbone + ONE shared attention+MLP block whose parameters are
reused at every application (after every ``attn_every`` mamba layers) — the
Zamba parameter-sharing trick. ``attn_every == 0`` gives the pure SSM stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import (
    attention_layer, dense_init, init_attention, init_mlp, mlp_layer, rms_norm,
)
from repro.models.transformer import _scatter_new_kv
from repro.models.mamba2 import init_mamba, mamba_layer


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_params(key, cfg):
    dtype = _dtype(cfg)
    ke, kl, ks, kh = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.num_layers)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {"norm": jnp.ones((cfg.d_model,), dtype),
                "mamba": init_mamba(k1, cfg, dtype)}

    params = {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "layers": jax.vmap(one)(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size), dtype=dtype),
    }
    if cfg.attn_every:
        k1, k2 = jax.random.split(ks)
        params["shared"] = {
            "norm1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attention(k1, cfg, dtype),
            "norm2": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.num_layers, dtype),
        }
    return params


def _group_params(params, cfg):
    """Reshape stacked mamba layers (L, ...) -> (G, per, ...) for scan-of-scan."""
    per = cfg.attn_every if cfg.attn_every else cfg.num_layers
    G = cfg.num_layers // per
    grouped = jax.tree.map(lambda a: a.reshape(G, per, *a.shape[1:]),
                           params["layers"])
    return grouped, G, per


def _mamba_sublayer(x, lp, cfg, state=None):
    y, new_state = mamba_layer(rms_norm(x, lp["norm"], cfg.norm_eps),
                               lp["mamba"], cfg, state=state)
    return x + y, new_state


def _shared_block(x, sp, cfg, positions, *, cache=None, cache_index=None,
                  window=0, return_kv=False):
    a, kv = attention_layer(rms_norm(x, sp["norm1"], cfg.norm_eps), sp["attn"],
                            cfg, positions=positions, cache=cache,
                            cache_index=cache_index, window=window,
                            return_kv=return_kv)
    x = x + a
    return x + mlp_layer(rms_norm(x, sp["norm2"], cfg.norm_eps), sp["mlp"]), kv


def forward(params, x, cfg, *, remat=True, window=0):
    """Train/encoder forward. x: (B,S,D). Returns (hidden, aux=0)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    grouped, G, per = _group_params(params, cfg)
    sp = params.get("shared")

    def inner(h, lp):
        h2, _ = _mamba_sublayer(h, lp, cfg)
        return h2, None

    inner_fn = jax.checkpoint(inner, prevent_cse=False) if remat else inner

    def outer(h, glp):
        h, _ = lax.scan(inner_fn, h, glp)
        if sp is not None:
            h, _ = _shared_block(h, sp, cfg, positions, window=window)
        return h, None

    x, _ = lax.scan(outer, x, grouped)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.float32(0.0)


def prefill(params, x, cfg, *, max_len=None, window=0):
    """Returns (hidden (B,S,D), cache)."""
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    grouped, G, per = _group_params(params, cfg)
    sp = params.get("shared")

    def inner(h, lp):
        h2, st = _mamba_sublayer(h, lp, cfg)
        return h2, st

    def outer(h, glp):
        h, states = lax.scan(inner, h, glp)
        kv = None
        if sp is not None:
            h, kv = _shared_block(h, sp, cfg, positions, window=window,
                                  return_kv=True)
        return h, (states, kv)

    x, (states, kvs) = lax.scan(outer, x, grouped)
    # states leaves have shape (G, per, B, ...) -> (L, B, ...)
    states = jax.tree.map(lambda a: a.reshape(cfg.num_layers, *a.shape[2:]),
                          states)
    cache = {"ssm": states["ssm"], "conv": states["conv"],
             "len": jnp.full((B,), S, jnp.int32)}
    if sp is not None:
        k, v = kvs
        # kv-heads-major (G,B,KH,S,hd), see transformer.init_cache
        k = k.transpose(0, 1, 3, 2, 4)
        v = v.transpose(0, 1, 3, 2, 4)
        if max_len > S:
            pad = ((0, 0), (0, 0), (0, 0), (0, max_len - S), (0, 0))
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        cache["k"], cache["v"] = k, v
    return rms_norm(x, params["final_norm"], cfg.norm_eps), cache


def decode_step(params, x, cfg, cache, *, window=0):
    """x: (B,1,D). Returns (hidden (B,1,D), new cache)."""
    positions = cache["len"][:, None]
    grouped, G, per = _group_params(params, cfg)
    sp = params.get("shared")
    gstates = {
        "ssm": cache["ssm"].reshape(G, per, *cache["ssm"].shape[1:]),
        "conv": cache["conv"].reshape(G, per, *cache["conv"].shape[1:]),
    }

    def inner(h, xs):
        lp, st = xs
        h2, st2 = _mamba_sublayer(h, lp, cfg, state=st)
        return h2, st2

    def outer(h, xs):
        glp, gst, kc, vc = xs
        h, st2 = lax.scan(inner, h, (glp, gst))
        nkv = (kc, vc)
        if sp is not None:
            # returns the new kv VECTORS; scattered into the stacked cache
            # once after the scan (see transformer.decode_step)
            h, nkv = _shared_block(h, sp, cfg, positions,
                                   cache={"k": kc, "v": vc},
                                   cache_index=cache["len"], window=window)
        return h, (st2, nkv)

    if sp is not None:
        xs = (grouped, gstates, cache["k"], cache["v"])
    else:
        dummy = jnp.zeros((G, 1)), jnp.zeros((G, 1))
        xs = (grouped, gstates, *dummy)
    x, (st2, (ks, vs)) = lax.scan(outer, x, xs)
    new_cache = {
        "ssm": st2["ssm"].reshape(cfg.num_layers, *st2["ssm"].shape[2:]),
        "conv": st2["conv"].reshape(cfg.num_layers, *st2["conv"].shape[2:]),
        "len": cache["len"] + 1,
    }
    if sp is not None:
        new_cache["k"] = _scatter_new_kv(cache["k"], ks, cache["len"])
        new_cache["v"] = _scatter_new_kv(cache["v"], vs, cache["len"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), new_cache


def init_cache(cfg, batch, max_len, dtype):
    L = cfg.num_layers
    H, P, N = cfg.ssm_heads, cfg.ssm.head_dim, cfg.ssm.d_state
    Ch = cfg.d_inner + 2 * N
    cache = {
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.ssm.conv_kernel - 1, Ch), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.attn_every:
        G = cfg.num_layers // cfg.attn_every
        # kv-heads-major (B,KH,S,hd) — see transformer.init_cache
        cache["k"] = jnp.zeros((G, batch, cfg.num_kv_heads, max_len,
                                cfg.head_dim), dtype)
        cache["v"] = jnp.zeros((G, batch, cfg.num_kv_heads, max_len,
                                cfg.head_dim), dtype)
    return cache
