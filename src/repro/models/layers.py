"""Shared neural building blocks: norms, RoPE, chunked (flash-style) attention,
SwiGLU MLP, and parameter initializers.

All layers are pure functions over explicit parameter pytrees (nested dicts), so
they jit/scan/shard cleanly. Activations are computed in the dtype of the inputs.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (interleaved pairs: (2i, 2i+1) rotate together, so sharding the head
# dim keeps rotation pairs shard-local — see DESIGN.md §5)
# ---------------------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: (..., S, H, D) ; positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs           # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked attention (flash-style online softmax in pure JAX)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      kv_len=None, q_chunk=512, k_chunk=1024):
    """Memory-bounded attention with online softmax.

    q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0 (GQA).
    ``q_offset``: absolute position of q[0] (for decode / chunked prefill).
    ``kv_len``: (B,) or scalar number of valid kv positions (padded cache).
    ``window``: sliding-window size (0 = unlimited).

    Scans sequentially over q chunks and, inside, over k chunks, carrying the
    online-softmax state (m, l, acc). Peak live score block: B*H*q_chunk*k_chunk.
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    # pad to multiples
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // k_chunk)
    pq = nq * q_chunk - Sq
    pk = nk * k_chunk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    qr = q.reshape(B, nq, q_chunk, KH, G, D)
    kr = k.reshape(B, nk, k_chunk, KH, D)
    vr = v.reshape(B, nk, k_chunk, KH, D)

    if kv_len is None:
        kv_len_arr = jnp.full((B,), Sk, dtype=jnp.int32)
    else:
        kv_len_arr = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))

    def q_step(_, qi):
        qblk = qr[:, qi]                                     # (B, qc, KH, G, D)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)  # (qc,)

        def k_step(carry, ki):
            m, l, acc = carry
            kblk = kr[:, ki]                                 # (B, kc, KH, D)
            vblk = vr[:, ki]
            kpos = ki * k_chunk + jnp.arange(k_chunk)        # (kc,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            ok = kpos[None, :] < kv_len_arr[:, None]          # (B, kc) valid positions
            blockmask = ok[:, None, :]                        # (B, 1(q), kc)
            if causal:
                cm = kpos[None, :] <= qpos[:, None]           # (qc, kc)
                blockmask = blockmask & cm[None, :, :]
            if window:
                wm = (qpos[:, None] - kpos[None, :]) < window
                blockmask = blockmask & wm[None, :, :]
            s = jnp.where(blockmask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KH, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = lax.scan(k_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B, KH, G, qc, D)
        return None, out.astype(q.dtype)

    _, outs = lax.scan(q_step, None, jnp.arange(nq))          # (nq, B, KH, G, qc, D)
    out = jnp.moveaxis(outs, 0, 1)                            # (B, nq, KH, G, qc, D)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, *, cur_len, window=0):
    """Single-token attention against a padded KV cache.

    q: (B, 1, H, D); caches: (B, Smax, KH, D); cur_len: (B,) valid lengths
    (the new token's kv must already be written at cur_len-1).
    """
    B, _, H, D = q.shape
    _, Smax, KH, _ = k_cache.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    mask = pos[None, :] < cur_len[:, None]                    # (B, Smax)
    if window:
        mask = mask & (cur_len[:, None] - 1 - pos[None, :] < window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_appended(q, k_cache, v_cache, k_new, v_new, *,
                              prev_len, window=0):
    """Single-token attention over (existing cache) + (new token's kv),
    WITHOUT requiring the new kv to be written into the cache first.

    Keeping the attention read path independent of the cache update means
    the update stays a pure in-dtype scatter: the baseline formulation
    (write-then-attend) made XLA round-trip the ENTIRE stacked cache
    through f32 once per layer (§Perf iteration log, yi-34b decode).

    q: (B,1,H,D); caches: (B,KH,Smax,D) — kv-heads-major layout so the
    contraction needs NO transpose copies (§Perf iteration 3);
    k_new/v_new: (B,KH,D); prev_len: (B,) valid positions BEFORE this token.
    """
    B, _, H, D = q.shape
    _, KH, Smax, _ = k_cache.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    mask = pos[None, :] < prev_len[:, None]                   # history only
    if window:
        mask = mask & (prev_len[:, None] - pos[None, :] < window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    s_new = jnp.einsum("bhgd,bhd->bhg", qr, k_new,
                       preferred_element_type=jnp.float32) * scale
    m = jnp.maximum(s.max(axis=-1), s_new)                    # (B,KH,G)
    p = jnp.exp(s - m[..., None])
    p_new = jnp.exp(s_new - m)
    denom = p.sum(axis=-1) + p_new
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out + p_new[..., None] * v_new[:, :, None, :].astype(jnp.float32)
    out = out / denom[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention layer (projections + rope + attend), with optional KV cache
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kvd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kvd), dtype=dtype),
        "wo": dense_init(ks[3], (qd, d), scale=1.0 / math.sqrt(qd * 2 * cfg.num_layers),
                         dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def project_qkv(x, p, cfg, positions):
    """QKV projections + RoPE. x: (B, S, D) ->
    q (B,S,H,hd), k (B,S,KH,hd), v (B,S,KH,hd)."""
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KH, hd)
    v = v.reshape(B, S, KH, hd)
    if cfg.causal or not cfg.is_encoder:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_layer(x, p, cfg, *, positions, cache=None, cache_index=None,
                    window=0, return_kv=False):
    """x: (B, S, D). If cache is given (decode): cache = dict(k, v) padded
    buffers (B, Smax, KH, hd); cache_index: (B,) current lengths BEFORE this
    token. Returns (out, new_cache); with ``return_kv`` (prefill) the second
    element is the rope'd (k, v) pair instead."""
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = project_qkv(x, p, cfg, positions)

    if cache is None:
        from repro.distributed import hints as _hints
        hp = _hints.current()
        if hp is not None and hp.attn_dp is not None:
            # reshard batch over (data x model) for the attention compute:
            # avoids replicating attention across model shards when the
            # head count is not divisible by the model axis (§Perf)
            q = _hints.constrain_batch(q, hp.attn_dp)
            k = _hints.constrain_batch(k, hp.attn_dp)
            v = _hints.constrain_batch(v, hp.attn_dp)
        out = chunked_attention(q, k, v, causal=cfg.causal, window=window)
        if hp is not None and hp.attn_dp is not None:
            out = _hints.constrain_batch(out, hp.batch_axes)
        new_cache = (k, v) if return_kv else None
    else:
        # decode: S == 1.  Attend over (history cache) + (new kv) directly
        # and return the new kv VECTORS — the caller scatters them into the
        # stacked cache ONCE, outside the layer scan.  Updating the cache
        # inside the scan made XLA round-trip the entire stacked cache
        # through f32 per layer (EXPERIMENTS.md §Perf, yi-34b decode).
        kc, vc = cache["k"], cache["v"]
        idx = cache_index  # (B,)
        out = decode_attention_appended(q, kc, vc, k[:, 0], v[:, 0],
                                        prev_len=idx, window=window)
        new_cache = (k[:, 0], v[:, 0])          # (B, KH, hd) each
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, num_layers, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w3": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "w2": dense_init(ks[2], (d_ff, d_model),
                         scale=1.0 / math.sqrt(d_ff * 2 * num_layers), dtype=dtype),
    }


def mlp_layer(x, p):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]
