"""Post-SPMD HLO analysis for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 88 layers reports the FLOPs/bytes of a single layer body
(verified empirically: a scan of 10 matmuls reports the flops of one).  All
our models scan over layers and the train step scans over microbatches, so
the built-in numbers undercount by 1-3 orders of magnitude.  This module
re-derives the roofline terms from the compiled HLO text itself, multiplying
while-loop bodies by their trip counts:

* ``parse_flops``    — MXU work: 2 * prod(result dims) * contracted size for
                       every ``dot`` (descends while bodies x trip count,
                       calls, and fusion computations).
* ``parse_traffic``  — an HBM traffic model: per top-level op,
                       bytes(result) + bytes(operands), with in-place ops
                       (dynamic-slice/dynamic-update-slice/gather/scatter)
                       counted at their slice size, fusion internals skipped
                       (they live in registers/VMEM), and while bodies
                       multiplied by trip count.
* ``parse_collectives`` — operand bytes of every all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       with trip counts, plus the top call sites by volume.

Everything is parsed from the post-SPMD per-device module, so all numbers
are PER-CHIP; roofline terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import re

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*?\bbody=%?([\w.\-]+)")
_COND_RE = re.compile(r"\bwhile\(.*?\bcondition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"\b(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

# ops whose listed operand is NOT streamed in full (in-place / indexed)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "add-dependency", "while", "conditional", "call",
             "custom-call", "partition-id", "replica-id", "opt-barrier",
             "domain"}


def shapes_of(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def shape_bytes(type_str: str) -> int:
    n = 0
    for dt, dims in shapes_of(type_str):
        size = 1
        for d in dims:
            size *= d
        n += size * _DTYPE_BYTES[dt]
    return n


def split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _entry(comps: dict[str, list[str]]) -> str:
    for n in comps:
        if n.startswith("main"):
            return n
    return next(iter(comps), "")


class _Module:
    """Parsed module: per-computation op lines + symbol tables."""

    def __init__(self, hlo_text: str):
        self.comps = split_computations(hlo_text)
        self.entry = _entry(self.comps)
        self._symtabs: dict[str, dict] = {}
        self._ops: dict[str, list] = {}
        self._roots: dict[str, tuple] = {}
        for name, lines in self.comps.items():
            tab, ops = {}, []
            for ln in lines:
                m = _OP_RE.match(ln)
                if not m:
                    continue
                lhs, type_str, opcode = m.group(1), m.group(2), m.group(3)
                tab[lhs] = type_str
                ops.append((lhs, type_str, opcode, ln))
                if ln.lstrip().startswith("ROOT"):
                    self._roots[name] = (lhs, type_str, opcode, ln)
            self._symtabs[name] = tab
            self._ops[name] = ops

    def root(self, comp: str):
        return self._roots.get(comp)

    def ops(self, comp: str):
        return self._ops.get(comp, ())

    def operand_names(self, ln: str, opcode: str) -> list[str]:
        args = ln.split(opcode + "(", 1)[-1].split(")", 1)[0]
        return re.findall(r"%([\w.\-]+)", args)

    def operand_shapes(self, comp: str, ln: str, opcode: str):
        tab = self._symtabs[comp]
        return [tab.get(n) for n in self.operand_names(ln, opcode)]

    def trip_count(self, comp: str, ln: str) -> int:
        tc = _TRIP_RE.search(ln)
        if tc:
            return int(tc.group(1))
        cm = _COND_RE.search(ln)
        if not cm:
            return 1
        consts = {}
        cmp_ref = None
        for cln in self.comps.get(cm.group(1), ()):
            c = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\w+\[\]\s*"
                         r"constant\((\d+)\)", cln)
            if c:
                consts[c.group(1)] = int(c.group(2))
            if "compare(" in cln:
                cmp_ref = cln
        if cmp_ref:
            for name, val in consts.items():
                if name in cmp_ref:
                    return val
        return max(consts.values()) if consts else 1


# ---------------------------------------------------------------------------
# FLOPs (dot ops, trip-count aware, descends fusions)
# ---------------------------------------------------------------------------

def _dot_flops(mod: _Module, comp: str, lhs_type: str, ln: str) -> float:
    res = shapes_of(lhs_type)
    if not res:
        return 0.0
    _, rdims = res[0]
    out = 1.0
    for d in rdims:
        out *= d
    kc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
    ops = mod.operand_shapes(comp, ln, "dot")
    contracted = 1.0
    if kc and ops and ops[0]:
        lshapes = shapes_of(ops[0])
        if lshapes:
            _, ldims = lshapes[0]
            for i in (int(x) for x in kc.group(1).split(",") if x):
                if i < len(ldims):
                    contracted *= ldims[i]
    return 2.0 * out * contracted


def parse_flops(hlo_text: str, mod: _Module | None = None) -> dict:
    """Trip-count-corrected MXU flops (per device) + top dot call-sites."""
    mod = mod or _Module(hlo_text)
    memo: dict[str, tuple[float, dict]] = {}
    top: dict[str, float] = {}

    def walk(comp: str, stack=()) -> float:
        if comp in memo:
            return memo[comp][0]
        if comp in stack:
            return 0.0
        total = 0.0
        for lhs, type_str, opcode, ln in mod.ops(comp):
            if opcode == "dot":
                fl = _dot_flops(mod, comp, type_str, ln)
                total += fl
                nm = _OPNAME_RE.search(ln)
                key = nm.group(1) if nm else lhs
                top[key] = top.get(key, 0.0) + fl
            elif opcode == "while":
                wm = _WHILE_RE.search(ln)
                if wm:
                    trips = mod.trip_count(comp, ln)
                    total += trips * walk(wm.group(1), stack + (comp,))
            elif opcode in ("fusion", "call"):
                cm = _CALLS_RE.search(ln)
                if cm:
                    total += walk(cm.group(1), stack + (comp,))
            elif opcode == "conditional":
                bm = _BRANCH_RE.search(ln)
                if bm:
                    for br in re.findall(r"%([\w.\-]+)", bm.group(1)):
                        total += walk(br, stack + (comp,))
        memo[comp] = (total, {})
        return total

    # NOTE: ``top`` accumulates per-visit flops without loop multipliers —
    # used only to RANK call sites, whose relative order scans preserve.
    total = walk(mod.entry) if mod.entry else 0.0
    top_list = sorted(top.items(), key=lambda kv: -kv[1])[:8]
    return {"dot_flops": total,
            "top_dots": [{"site": k, "flops_per_visit": v}
                         for k, v in top_list]}


# ---------------------------------------------------------------------------
# HBM traffic model
# ---------------------------------------------------------------------------

def _line_traffic(mod: _Module, comp: str, lhs_type: str, opcode: str,
                  ln: str) -> float:
    if opcode in _FREE_OPS:
        return 0.0
    res = shape_bytes(lhs_type)
    if opcode == "dynamic-slice" or opcode == "gather":
        return 2.0 * res                      # read slice + write result
    if opcode == "dynamic-update-slice":
        ops = mod.operand_shapes(comp, ln, opcode)
        upd = shape_bytes(ops[1]) if len(ops) > 1 and ops[1] else 0
        return 2.0 * upd                      # read update + write in place
    if opcode == "scatter":
        ops = mod.operand_shapes(comp, ln, opcode)
        upd = shape_bytes(ops[2]) if len(ops) > 2 and ops[2] else res
        return 2.0 * upd
    if opcode == "iota" or opcode == "broadcast":
        return float(res)                     # write-only (operand tiny)
    total = float(res)
    for t in mod.operand_shapes(comp, ln, opcode):
        if t:
            total += shape_bytes(t)
    return total


def _fusion_traffic(mod: _Module, comp: str, fusion_comp: str,
                    ln: str) -> tuple[float, bool]:
    """Slice-aware traffic of one fusion op: parameters consumed ONLY by
    dynamic-slice/gather inside count at slice size; a dynamic-update-slice
    root writes at update size (in place).  Returns (bytes, is_convert)
    where is_convert flags convert-rooted fusions (a CPU-backend artifact:
    TPU fuses dtype converts into the consumer's operand read)."""
    ops = mod.ops(fusion_comp)
    if not ops:
        return _line_traffic(mod, comp, mod._symtabs[comp].get("", ""),
                             "fusion", ln), False
    operand_types = mod.operand_shapes(comp, ln, "fusion")
    params: dict[str, int] = {}
    for lhs, t, op, l in ops:
        if op == "parameter":
            m = re.search(r"parameter\((\d+)\)", l)
            if m:
                params[lhs] = int(m.group(1))
    uses: dict[str, list] = {}
    for lhs, t, op, l in ops:
        if op == "parameter":
            continue
        for i, nm in enumerate(mod.operand_names(l, op)):
            if nm in params:
                uses.setdefault(nm, []).append((op, t, l, i))
    total = 0.0
    for nm, idx in params.items():
        u = uses.get(nm, ())
        slicey = u and all(
            op in ("dynamic-slice", "gather")
            or (op in ("dynamic-update-slice", "scatter") and pos == 0)
            for op, _, _, pos in u)
        if slicey:
            for op, t, l, pos in u:
                if op != "dynamic-update-slice":
                    total += shape_bytes(t)          # slice read
        else:
            t = operand_types[idx] if idx < len(operand_types) else None
            if t:
                total += shape_bytes(t)              # full operand read
    root = mod.root(fusion_comp)
    if root is not None:
        rl, rt, rop, rln = root
        if rop == "dynamic-update-slice":
            rops = mod.operand_shapes(fusion_comp, rln, rop)
            total += shape_bytes(rops[1]) if len(rops) > 1 and rops[1] \
                else shape_bytes(rt)                 # in-place slice write
        elif rop == "scatter":
            # in-place on the target operand: write = update size (the
            # target param was skipped above if consumed only by scatter)
            rops = mod.operand_shapes(fusion_comp, rln, rop)
            total += shape_bytes(rops[2]) if len(rops) > 2 and rops[2] \
                else shape_bytes(rt)
        else:
            total += shape_bytes(rt)                 # full result write
    # "convert artifact": a fusion that only converts dtype (+ free reshapes
    # / slices).  The CPU backend materializes bf16->f32 copies for its f32
    # dot kernels; TPU MXU reads bf16 natively, so these vanish on target.
    _artifact_ok = {"parameter", "convert", "bitcast", "dynamic-slice",
                    "reshape", "slice"}
    opcodes = {op for _, _, op, _ in ops}
    is_convert = "convert" in opcodes and opcodes <= _artifact_ok
    return total, is_convert


def parse_traffic(hlo_text: str, mod: _Module | None = None) -> dict:
    """Approximate per-device HBM bytes moved.  ``convert_bytes`` isolates
    convert-rooted fusions (bf16->f32 copies the CPU backend materializes
    for its f32 dot kernels; TPU reads bf16 natively), so the TPU-projected
    traffic is ``traffic_bytes - convert_bytes``."""
    mod = mod or _Module(hlo_text)
    memo: dict[str, tuple[float, float]] = {}

    def walk(comp: str, stack=()) -> tuple[float, float]:
        if comp in memo:
            return memo[comp]
        if comp in stack:
            return 0.0, 0.0
        total, conv = 0.0, 0.0
        for lhs, type_str, opcode, ln in mod.ops(comp):
            if opcode == "while":
                wm = _WHILE_RE.search(ln)
                if wm:
                    trips = mod.trip_count(comp, ln)
                    st, sc = walk(wm.group(1), stack + (comp,))
                    total += trips * st
                    conv += trips * sc
                continue
            if opcode == "call":
                cm = _CALLS_RE.search(ln)
                if cm:
                    st, sc = walk(cm.group(1), stack + (comp,))
                    total += st
                    conv += sc
                continue
            if opcode == "conditional":
                bm = _BRANCH_RE.search(ln)
                if bm:
                    brs = re.findall(r"%([\w.\-]+)", bm.group(1))
                    if brs:
                        st, sc = max((walk(b, stack + (comp,)) for b in brs),
                                     key=lambda x: x[0])
                        total += st
                        conv += sc
                continue
            if opcode == "fusion":
                cm = _CALLS_RE.search(ln)
                if cm:
                    fb, is_conv = _fusion_traffic(mod, comp, cm.group(1), ln)
                    total += fb
                    if is_conv:
                        conv += fb
                    continue
            total += _line_traffic(mod, comp, type_str, opcode, ln)
        memo[comp] = (total, conv)
        return memo[comp]

    t, c = walk(mod.entry) if mod.entry else (0.0, 0.0)
    return {"traffic_bytes": t, "convert_bytes": c}


# ---------------------------------------------------------------------------
# Collectives
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[\w\[\],{}/*= ]+?)\s+("
    + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")


def parse_collectives(hlo_text: str, mod: _Module | None = None) -> dict:
    """Per-device collective bytes by kind (+counts, + top call sites)."""
    mod = mod or _Module(hlo_text)
    memo = {}
    sites: dict[tuple[str, str], float] = {}

    def walk(comp: str, mult: float, stack=()):
        key = comp
        if key in stack:
            return {k: 0 for k in COLLECTIVE_OPS}, {k: 0 for k in
                                                    COLLECTIVE_OPS}
        if key in memo:
            b, c = memo[key]
        else:
            b = {k: 0.0 for k in COLLECTIVE_OPS}
            c = {k: 0 for k in COLLECTIVE_OPS}
            for lhs, type_str, opcode, ln in mod.ops(comp):
                cm = _COLL_RE.search(ln)
                if cm and "-done(" not in ln:
                    kind = cm.group(2)
                    nbytes = shape_bytes(cm.group(1))
                    b[kind] += nbytes
                    c[kind] += 1
                elif opcode in ("fusion", "call"):
                    sub = _CALLS_RE.search(ln)
                    if sub:
                        sb, sc = walk(sub.group(1), 1.0, stack + (comp,))
                        for k in COLLECTIVE_OPS:
                            b[k] += sb[k]
                            c[k] += sc[k]
                elif opcode == "while":
                    wm = _WHILE_RE.search(ln)
                    if wm:
                        trips = mod.trip_count(comp, ln)
                        sb, sc = walk(wm.group(1), trips, stack + (comp,))
                        for k in COLLECTIVE_OPS:
                            b[k] += trips * sb[k]
                            c[k] += trips * sc[k]
            memo[key] = (b, c)
        return memo[key]

    # collect top call sites (one linear pass, no loop multipliers —
    # ranking only)
    for comp, ops in mod._ops.items():
        for lhs, type_str, opcode, ln in ops:
            cm = _COLL_RE.search(ln)
            if cm and "-done(" not in ln:
                nm = _OPNAME_RE.search(ln)
                key = (cm.group(2), nm.group(1) if nm else lhs)
                sites[key] = sites.get(key, 0.0) + shape_bytes(cm.group(1))

    b, c = walk(mod.entry, 1.0) if mod.entry else (
        {k: 0 for k in COLLECTIVE_OPS}, {k: 0 for k in COLLECTIVE_OPS})
    out = dict(b)
    out.update({f"{k}_count": v for k, v in c.items()})
    out["collective_bytes"] = float(sum(b.values()))
    top = sorted(sites.items(), key=lambda kv: -kv[1])[:10]
    out["top_collectives"] = [
        {"kind": k[0], "site": k[1], "bytes_per_visit": v} for k, v in top]
    return out


def analyze(hlo_text: str) -> dict:
    """All three families in one parse."""
    mod = _Module(hlo_text)
    out = {}
    out.update(parse_flops(hlo_text, mod))
    out.update(parse_traffic(hlo_text, mod))
    out.update(parse_collectives(hlo_text, mod))
    out["hlo_bytes"] = len(hlo_text)
    out["fusions"] = hlo_text.count(" fusion(")
    out["while_loops"] = hlo_text.count(" while(")
    return out
