"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small (data, model) mesh over however many (possibly fake) local
    devices exist — serving tensor-parallelism and distribution tests.

    Validates the request against ``jax.device_count()`` up front: a
    too-large mesh would otherwise surface as an opaque shape error deep
    inside the first jit that touches it. Simulate devices on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes its backend).
    """
    if data < 1 or model < 1:
        raise ValueError(
            f"mesh axes must be positive, got data={data} model={model}")
    need, have = data * model, jax.device_count()
    if need > have:
        raise ValueError(
            f"requested a {data}x{model} (data x model) mesh = {need} "
            f"devices but only {have} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} (before jax "
            f"initializes) to simulate them, or shrink the mesh")
    return jax.make_mesh((data, model), ("data", "model"))
