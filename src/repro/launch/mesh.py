"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by distribution tests, not the dry-run."""
    return jax.make_mesh((data, model), ("data", "model"))
