"""End-to-end serving driver: ``python -m repro.launch.serve --arch <id>``.

Brings up the real continuous-batching engine for the selected architecture
and drives a ShareGPT-like request stream through it, reporting the paper's
§5.1 metrics.  On this CPU container the reduced config is the default;
``--full`` uses the full config (TPU-sized — expect it to be slow/OOM off
target hardware, it exists so the same entry point works on a real pod).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import StreamAssembler, to_inference_request
from repro.api.schemas import CompletionRequest
from repro.configs import REGISTRY, get_config, list_archs, reduced
from repro.data.workload import make_workload, token_ids_for
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig


def main() -> None:
    ap = argparse.ArgumentParser(description="FIRST serving driver")
    ap.add_argument("--arch", default="llama3.2-3b", choices=list_archs())
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU target); default reduced")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=float("inf"))
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--backend", default="paged",
                    choices=["slots", "paged"])
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq-len", type=int, default=160)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-shards", type=int, default=1,
                    help="tensor-parallel width: shard the engine over a "
                         "(1, N) device mesh (N devices must be visible; "
                         "simulate with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--stream", action="store_true",
                    help="subscribe every request to the token stream and "
                         "report client-observed TTFT/ITL")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else reduced(REGISTRY[args.arch])
    if cfg.family in ("ssm", "hybrid") and args.backend == "paged":
        print(f"[serve] {cfg.family} arch: paged KV does not apply, "
              "using slots backend")
        args.backend = "slots"
    if cfg.family == "audio":
        raise SystemExit("hubert-xlarge is encoder-only: use the embedding "
                         "service (repro.serving.embedding), not generate")

    mesh = None
    if args.model_shards > 1:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(1, args.model_shards)

    print(f"[serve] arch={args.arch} ({'full' if args.full else 'reduced'}) "
          f"backend={args.backend} slots={args.slots} "
          f"shards={args.model_shards}")
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    engine = ContinuousBatchingEngine(model, params, EngineConfig(
        max_slots=args.slots, max_seq_len=args.max_seq_len,
        backend=args.backend, page_size=16, mesh=mesh))

    wl = make_workload(args.requests, rate=args.rate, seed=args.seed,
                       lo=4, hi=max(8, args.max_seq_len - args.max_tokens - 8))
    t0 = time.monotonic()
    streams: dict[str, StreamAssembler] = {}
    for w in wl:
        # typed /v1 request -> engine request (the serving driver speaks
        # the same contract as the gateway)
        req = CompletionRequest(
            model=cfg.name,
            prompt_tokens=token_ids_for(w, cfg.vocab_size)[:args.max_seq_len
                                                           - args.max_tokens
                                                           - 4],
            request_id=w.request_id,
            max_tokens=min(w.max_tokens, args.max_tokens),
            temperature=0.0, stream=args.stream).validate()
        on_delta = None
        if args.stream:
            streams[req.request_id] = on_delta = \
                StreamAssembler(clock=engine.clock)
        engine.add_request(to_inference_request(req), on_delta=on_delta)
    outs = engine.run_to_completion()
    dt = time.monotonic() - t0
    toks = sum(o.num_output_tokens for o in outs)
    e2e = sorted(o.metrics.e2e_latency for o in outs if o.metrics)
    print(f"[serve] {len(outs)} requests, {toks} output tokens in {dt:.1f}s")
    print(f"[serve] req/s={len(outs)/dt:.2f} tok/s={toks/dt:.1f} "
          f"median_e2e={e2e[len(e2e)//2]:.2f}s steps={engine.stats['steps']}")
    if args.stream:
        for o in outs:
            assert streams[o.request_id].tokens == o.output_tokens, \
                f"stream/output divergence for {o.request_id}"
        gaps = sorted(g for a in streams.values()
                      for g in a.inter_token_gaps)
        ttfts = sorted(a.arrivals[0] - t0 for a in streams.values()
                       if a.arrivals)
        print(f"[serve] streamed: {sum(len(a.deltas) for a in streams.values())}"
              f" frames, median TTFT {ttfts[len(ttfts)//2]:.2f}s, "
              f"median ITL {gaps[len(gaps)//2]*1e3:.1f}ms, "
              f"p99 ITL {gaps[int(0.99*(len(gaps)-1))]*1e3:.1f}ms")


if __name__ == "__main__":
    main()
