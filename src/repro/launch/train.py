"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Runs the remat'd scan-over-layers train step with grad accumulation, the
synthetic token pipeline, and periodic checkpointing (restart-safe: rerun
with the same --ckpt-dir to resume).  Reduced configs by default; on a TPU
pod the same step function is what repro.launch.dryrun lowers with
in/out shardings from repro.distributed.sharding.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs import REGISTRY, list_archs, reduced
from repro.data.tokens import TokenDataset
from repro.distributed.checkpoint import (latest_checkpoint, load_checkpoint,
                                          save_checkpoint)
from repro.models import make_model
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser(description="FIRST training driver")
    ap.add_argument("--arch", default="llama3.2-3b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(REGISTRY[args.arch])
    model = make_model(cfg)
    data = TokenDataset(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                        global_batch=args.batch, seed=args.seed)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=args.lr),
                                      num_microbatches=args.microbatches))

    start = 0
    params = opt_state = None
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        latest = latest_checkpoint(args.ckpt_dir)
        if latest:
            state, meta = load_checkpoint(latest)
            params, opt_state = state["params"], state["opt"]
            data.restore(meta["data"])
            start = meta["step"]
            print(f"[train] resumed from {latest} at step {start}")
    if params is None:
        params = model.init_params(jax.random.PRNGKey(args.seed))
        opt_state = adamw_init(params)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.next_batch()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d}  loss "
                  f"{float(metrics['loss']):.4f}  {time.time()-t0:6.1f}s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = os.path.join(args.ckpt_dir, f"ckpt_{step+1:06d}")
            save_checkpoint(path, {"params": params, "opt": opt_state},
                            step=step + 1,
                            metadata={"step": step + 1,
                                      "data": data.state()})
            print(f"[train] checkpoint -> {path}")
    print(f"[train] done: {args.steps - start} steps in "
          f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
