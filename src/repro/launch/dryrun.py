import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices to
# build the production mesh. Smoke tests / benches never import this module.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp   # noqa: E402

from repro.configs import REGISTRY, SHAPES, cells_for, get_config   # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig             # noqa: E402
from repro.distributed.sharding import ShardingRules                # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.models import make_model                                 # noqa: E402
from repro.training.optimizer import AdamWConfig, adamw_init        # noqa: E402
from repro.training.train import make_train_step                    # noqa: E402

from repro.launch.hlo_analysis import COLLECTIVE_OPS, analyze   # noqa: E402
from repro.distributed.hints import ShardingHints, use_hints        # noqa: E402


def make_hints(opts: set[str], multi_pod: bool) -> ShardingHints | None:
    """--opt flags -> activation-sharding hints (EXPERIMENTS.md §Perf)."""
    attn = "attn_dp" in opts or "attn_dp_noout" in opts
    moe = "moe_ep" in opts
    ce = "ce_chunk" in opts
    if not attn and not moe and not ce:
        return None
    dp = ("pod", "data") if multi_pod else ("data",)
    out_axes = None if "attn_dp_noout" in opts else dp
    return ShardingHints(attn_dp=dp + ("model",) if attn else None,
                         batch_axes=out_axes,
                         moe_ep="model" if moe else None,
                         dp=dp,
                         ce_chunk=16384 if ce else None)


def train_microbatches(cfg: ModelConfig) -> int:
    n = cfg.num_params
    if n > 20e9:
        return 16
    if n > 2e9:
        return 8
    return 4


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               microbatches: int | None = None, remat: bool = True,
               extra: dict | None = None):
    """Returns (fn, args_sds, in_shardings, out_shardings, info)."""
    model = make_model(cfg)
    rules = ShardingRules(mesh, cfg, train=(shape.kind == "train"))
    B, S = shape.global_batch, shape.seq_len
    rng = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init_params, rng)
    pspecs = rules.param_specs(params_sds)
    info = {"microbatches": None}

    def batch_sds():
        b = {}
        if cfg.input_kind == "embeds":
            b["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
        else:
            b["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            b["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return b

    if shape.kind == "train":
        n_micro = microbatches or train_microbatches(cfg)
        info["microbatches"] = n_micro
        step = make_train_step(model, AdamWConfig(), num_microbatches=n_micro,
                               remat=remat)
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        ospecs = rules.opt_specs(opt_sds, params_sds)
        bsds = batch_sds()
        bspecs = rules.batch_specs(bsds)
        args = (params_sds, opt_sds, bsds)
        in_sh = (rules.named(pspecs), rules.named(ospecs),
                 rules.named(bspecs))
        out_sh = (rules.named(pspecs), rules.named(ospecs), None)
        fn = step
        donate = (0, 1)
    elif shape.kind == "prefill":
        bsds = batch_sds()
        bspecs = rules.batch_specs(bsds)

        def fn(params, batch):
            return model.prefill(params, batch, max_len=S)

        args = (params_sds, bsds)
        in_sh = (rules.named(pspecs), rules.named(bspecs))
        out_logits, out_cache = jax.eval_shape(fn, params_sds, bsds)
        if out_cache is None:
            out_sh = None
        else:
            cspecs = rules.cache_specs(out_cache)
            out_sh = (None, rules.named(cspecs))
        donate = ()
    else:  # decode
        tok_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(B, S, jnp.bfloat16))
        cspecs = rules.cache_specs(cache_sds)

        def fn(params, tokens, cache):
            return model.decode_step(params, tokens, cache)

        args = (params_sds, tok_sds, cache_sds)
        in_sh = (rules.named(pspecs),
                 rules.named(rules.batch_specs(tok_sds)),
                 rules.named(cspecs))
        out_sh = (None, rules.named(cspecs))
        donate = (2,)
    return fn, args, in_sh, out_sh, donate, info


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, *, microbatches=None,
             remat=True, save_hlo=False, opts: set | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False,
           "devices": 512 if multi_pod else 256,
           "opts": sorted(opts) if opts else []}
    t0 = time.time()
    opts = opts or set()
    hints = make_hints(opts, multi_pod)
    from contextlib import nullcontext
    hints_ctx = use_hints(hints) if hints else nullcontext()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # single-pod mesh uses the first 256 of the 512 host devices
        fn, args, in_sh, out_sh, donate, info = build_cell(
            cfg, shape, mesh, microbatches=microbatches, remat=remat)
        rec.update(info)
        with mesh, hints_ctx:
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate)
            lowered = jfn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        cost = compiled.cost_analysis() or {}
        rec["flops"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        hlo = compiled.as_text()
        rec.update(analyze(hlo))
        rec["ok"] = True
        if save_hlo and out_dir:
            with open(os.path.join(
                    out_dir, f"{mesh_name}_{arch}_{shape_name}.hlo"),
                    "w") as f:
                f.write(hlo)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s, "
              f"flops {rec['flops']:.3e}, "
              f"coll {rec['collective_bytes']:.3e}B)")
        if mem is not None:
            print(f"[dryrun]   memory: args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"out={rec.get('output_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{mesh_name}_{arch}_{shape_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run every runnable (arch x shape) cell")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated optimizations, e.g. attn_dp")
    args = ap.parse_args()
    opts = {o for o in args.opt.split(",") if o}

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for name, cfg in sorted(REGISTRY.items()):
            for sh in cells_for(cfg):
                cells.append((name, sh.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, sh in cells:
        for mp in meshes:
            rec = run_cell(arch, sh, mp, args.out,
                           microbatches=args.microbatches,
                           remat=not args.no_remat, save_hlo=args.save_hlo,
                           opts=opts)
            failures += 0 if rec["ok"] else 1
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
