"""Typed, versioned /v1 request & response schemas (OpenAI-compatible).

These dataclasses are the system's public contract: the gateway parses
every inbound payload into one of them, the compute hop serializes them
into a version-tagged wire dict (``to_wire``/``from_wire``), endpoints
decode them back, and responses return as typed objects carrying OpenAI
``usage`` accounting.

Two prompt representations coexist because the repo has two planes:

* control plane (DES): ``prompt_tokens`` is an int TOKEN COUNT — the
  simulator never materializes token ids;
* data plane (real JAX engine): ``prompt_tokens`` is a list of token ids.

``content_hash`` is defined for id-list prompts (sha256 of the ids) or an
explicit ``prompt_hash``; count-only prompts have NO content identity and
are therefore never response-cached (two different prompts with equal
length must not share a cache entry).

Serialization is canonical: ``dumps()`` emits sorted keys with compact
separators, so serialize -> parse -> serialize is byte-stable — the golden
fixtures under ``tests/golden/`` pin this for every schema.

Legacy compatibility: response objects support read-only ``Mapping``-style
access (``resp["output_tokens"]``) for the pre-/v1 dict keys, so existing
drivers keep working while they migrate.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field, replace
from typing import Any

from repro.api.errors import InvalidRequestError

API_VERSION = "v1"

VALID_ENDPOINTS = ("chat/completions", "completions", "embeddings")


def dumps(obj) -> str:
    """Canonical JSON for a schema object (or plain dict): sorted keys,
    compact separators — the byte-stable wire form."""
    d = obj.to_dict() if hasattr(obj, "to_dict") else obj
    return json.dumps(d, sort_keys=True, separators=(",", ":"))


def _req_int(d: dict, key: str, minimum: int | None = None, default=None):
    v = d.get(key, default)
    if v is None:
        raise InvalidRequestError(f"missing required field {key!r}",
                                  param=key)
    try:
        v = int(v)
    except (TypeError, ValueError):
        raise InvalidRequestError(f"field {key!r} must be an integer",
                                  param=key) from None
    if minimum is not None and v < minimum:
        raise InvalidRequestError(f"field {key!r} must be >= {minimum}",
                                  param=key)
    return v


def _prompt_field(v, key: str):
    """Validate a prompt: int token count (DES) or list of token ids."""
    if isinstance(v, bool):
        raise InvalidRequestError(f"field {key!r} must be a token count or "
                                  "a list of token ids", param=key)
    if isinstance(v, int):
        if v < 0:
            raise InvalidRequestError(f"field {key!r} must be >= 0",
                                      param=key)
        return v
    if isinstance(v, (list, tuple)):
        try:
            return [int(t) for t in v]
        except (TypeError, ValueError):
            raise InvalidRequestError(
                f"field {key!r} token ids must be integers",
                param=key) from None
    raise InvalidRequestError(f"field {key!r} must be a token count or a "
                              "list of token ids", param=key)


# ---------------------------------------------------------------------------
# usage accounting
# ---------------------------------------------------------------------------

@dataclass
class Usage:
    """OpenAI usage block; ``cached_tokens`` is the prefix-cache reuse."""
    prompt_tokens: int = 0
    completion_tokens: int = 0
    total_tokens: int = 0
    cached_tokens: int = 0

    def to_dict(self) -> dict:
        return {"prompt_tokens": self.prompt_tokens,
                "completion_tokens": self.completion_tokens,
                "total_tokens": self.total_tokens,
                "prompt_tokens_details": {"cached_tokens": self.cached_tokens}}

    @classmethod
    def from_dict(cls, d: dict) -> "Usage":
        details = d.get("prompt_tokens_details") or {}
        return cls(prompt_tokens=_req_int(d, "prompt_tokens", 0, 0),
                   completion_tokens=_req_int(d, "completion_tokens", 0, 0),
                   total_tokens=_req_int(d, "total_tokens", 0, 0),
                   cached_tokens=int(details.get("cached_tokens", 0)))


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclass
class ChatMessage:
    role: str
    content: str

    def to_dict(self) -> dict:
        return {"role": self.role, "content": self.content}

    @classmethod
    def from_dict(cls, d: dict) -> "ChatMessage":
        if not isinstance(d.get("role"), str) \
                or not isinstance(d.get("content"), str):
            raise InvalidRequestError("message needs string 'role' and "
                                      "'content'", param="messages")
        return cls(role=d["role"], content=d["content"])


@dataclass
class _RequestBase:
    """Fields shared by every generation request."""
    model: str = ""
    max_tokens: int = 16
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0
    stop_token: int | None = None
    stream: bool = False
    user: str = ""
    qos: str = "interactive"              # interactive | batch
    priority: int = 0                     # intra-class, lower = more urgent
    deadline: float | None = None         # absolute TTFT deadline
    request_id: str = ""
    prompt_hash: str | None = None        # explicit content hash override
    resume_tokens: int = 0                # failover resume: tokens already
    #                                       streamed to the client; the new
    #                                       engine restores and continues

    endpoint = "completions"              # class attr, set per subclass

    def _prompt(self) -> int | list:
        raise NotImplementedError         # each endpoint defines its prompt

    def _validate(self):
        if not self.model or not isinstance(self.model, str):
            raise InvalidRequestError("field 'model' is required",
                                      param="model")
        if int(self.max_tokens) < 1:
            raise InvalidRequestError("field 'max_tokens' must be >= 1",
                                      param="max_tokens")
        if self.qos not in ("interactive", "batch"):
            raise InvalidRequestError(
                f"unknown qos class {self.qos!r}", param="qos")
        if not (0.0 < float(self.top_p) <= 1.0):
            raise InvalidRequestError("field 'top_p' must be in (0, 1]",
                                      param="top_p")
        if float(self.temperature) < 0.0:
            raise InvalidRequestError("field 'temperature' must be >= 0",
                                      param="temperature")

    # -- token-count views (both planes) -----------------------------------
    @property
    def prompt_token_count(self) -> int:
        p = self._prompt()
        return p if isinstance(p, int) else len(p)

    @property
    def prompt_token_ids(self) -> list | None:
        p = self._prompt()
        return p if isinstance(p, list) else None

    @property
    def content_hash(self) -> str | None:
        """Content identity for response caching: explicit hash, or the
        hash of materialized token ids. Count-only prompts return None —
        they carry no content and MUST NOT be cached."""
        if self.prompt_hash:
            return self.prompt_hash
        return self._ids_hash()

    def _ids_hash(self) -> str | None:
        ids = self.prompt_token_ids
        if ids is None:
            return None
        h = hashlib.sha256()
        h.update(repr(ids).encode())
        return h.hexdigest()[:32]

    def _common_dict(self) -> dict:
        d = {"model": self.model, "max_tokens": self.max_tokens,
             "temperature": self.temperature, "top_p": self.top_p,
             "seed": self.seed, "stream": self.stream, "qos": self.qos,
             "priority": self.priority}
        if self.stop_token is not None:
            d["stop_token"] = self.stop_token
        if self.deadline is not None:
            d["deadline"] = self.deadline
        if self.user:
            d["user"] = self.user
        if self.request_id:
            d["request_id"] = self.request_id
        if self.prompt_hash:
            d["prompt_hash"] = self.prompt_hash
        if self.resume_tokens:
            d["resume_tokens"] = self.resume_tokens
        return d

    @classmethod
    def _common_kwargs(cls, d: dict) -> dict:
        if not isinstance(d.get("model"), str) or not d.get("model"):
            raise InvalidRequestError("field 'model' is required",
                                      param="model")
        return dict(
            model=d["model"],
            max_tokens=_req_int(d, "max_tokens", 1, 16),
            temperature=float(d.get("temperature", 0.0)),
            top_p=float(d.get("top_p", 1.0)),
            seed=int(d.get("seed", 0)),
            stop_token=(None if d.get("stop_token") is None
                        else int(d["stop_token"])),
            stream=bool(d.get("stream", False)),
            user=str(d.get("user", "") or ""),
            qos=str(d.get("qos", "interactive")),
            priority=int(d.get("priority", 0)),
            deadline=(None if d.get("deadline") is None
                      else float(d["deadline"])),
            request_id=str(d.get("request_id", "") or ""),
            prompt_hash=d.get("prompt_hash"),
            resume_tokens=int(d.get("resume_tokens", 0) or 0),
        )


@dataclass
class CompletionRequest(_RequestBase):
    """/v1/completions — raw prompt in, tokens out."""
    prompt_tokens: int | list = 0

    endpoint = "completions"

    def _prompt(self):
        return self.prompt_tokens

    def validate(self) -> "CompletionRequest":
        self.prompt_tokens = _prompt_field(self.prompt_tokens,
                                           "prompt_tokens")
        self._validate()
        return self

    def to_dict(self) -> dict:
        d = self._common_dict()
        d["object"] = "completion.request"
        d["prompt_tokens"] = self.prompt_tokens
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompletionRequest":
        kw = cls._common_kwargs(d)
        prompt = d.get("prompt_tokens", d.get("prompt"))
        return cls(prompt_tokens=_prompt_field(prompt, "prompt_tokens"),
                   **kw).validate()


@dataclass
class ChatCompletionRequest(_RequestBase):
    """/v1/chat/completions — messages in (or a pre-tokenized prompt)."""
    messages: list = field(default_factory=list)      # list[ChatMessage]
    prompt_tokens: int | list | None = None           # tokenized override

    endpoint = "chat/completions"

    def _prompt(self):
        if self.prompt_tokens is not None:
            return self.prompt_tokens
        # count view of untokenized messages: whitespace token estimate
        return sum(len(m.content.split()) for m in self.messages)

    @property
    def content_hash(self) -> str | None:
        if self.prompt_hash:
            return self.prompt_hash
        if self.prompt_tokens is None and self.messages:
            h = hashlib.sha256()
            for m in self.messages:
                h.update(f"{m.role}\x00{m.content}\x00".encode())
            return h.hexdigest()[:32]
        return self._ids_hash()

    def validate(self) -> "ChatCompletionRequest":
        if self.prompt_tokens is None and not self.messages:
            raise InvalidRequestError(
                "chat completion needs 'messages' or 'prompt_tokens'",
                param="messages")
        if self.prompt_tokens is not None:
            self.prompt_tokens = _prompt_field(self.prompt_tokens,
                                               "prompt_tokens")
        self._validate()
        return self

    def to_dict(self) -> dict:
        d = self._common_dict()
        d["object"] = "chat.completion.request"
        if self.messages:
            d["messages"] = [m.to_dict() for m in self.messages]
        if self.prompt_tokens is not None:
            d["prompt_tokens"] = self.prompt_tokens
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ChatCompletionRequest":
        kw = cls._common_kwargs(d)
        msgs = [ChatMessage.from_dict(m) for m in d.get("messages", ())]
        prompt = d.get("prompt_tokens")
        if prompt is not None:
            prompt = _prompt_field(prompt, "prompt_tokens")
        return cls(messages=msgs, prompt_tokens=prompt, **kw).validate()


@dataclass
class EmbeddingRequest(_RequestBase):
    """/v1/embeddings — one-step encode; ``input`` is count or token ids."""
    input: int | list = 0

    endpoint = "embeddings"

    def _prompt(self):
        return self.input

    def validate(self) -> "EmbeddingRequest":
        self.input = _prompt_field(self.input, "input")
        self.max_tokens = 1               # embeddings are single-step tasks
        self._validate()
        return self

    def to_dict(self) -> dict:
        d = self._common_dict()
        d["object"] = "embedding.request"
        d["input"] = self.input
        d.pop("stream", None)             # embeddings never stream
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "EmbeddingRequest":
        kw = cls._common_kwargs(d)
        kw["max_tokens"] = 1
        prompt = d.get("input", d.get("prompt_tokens"))
        return cls(input=_prompt_field(prompt, "input"), **kw).validate()


_REQUEST_TYPES = {
    "chat/completions": ChatCompletionRequest,
    "completions": CompletionRequest,
    "embeddings": EmbeddingRequest,
}

_WIRE_KINDS = {
    "chat.completion.request": ChatCompletionRequest,
    "completion.request": CompletionRequest,
    "embedding.request": EmbeddingRequest,
}


def parse_request(payload: dict, endpoint: str | None = None):
    """Parse an untyped payload into the matching typed request.

    ``endpoint`` (or the payload's legacy ``api`` key) selects the schema;
    defaults to chat/completions like the original gateway."""
    if not isinstance(payload, dict):
        raise InvalidRequestError("request payload must be a JSON object")
    ep = endpoint or payload.get("api") or payload.get("endpoint") \
        or "chat/completions"
    cls = _REQUEST_TYPES.get(ep)
    if cls is None:
        raise InvalidRequestError(f"unknown endpoint {ep!r}", param="api")
    return cls.from_dict(payload)


def to_wire(req) -> dict:
    """Version-tagged wire envelope for the gateway -> endpoint hop."""
    d = req.to_dict()
    return {"v": API_VERSION, "kind": d["object"], "data": d}


def abort_wire(request_id: str) -> dict:
    """Version-tagged control payload for the 'abort' endpoint function."""
    return {"v": API_VERSION, "request_id": request_id}


def from_wire(payload: dict):
    """Decode a wire envelope back into a typed request (endpoint side).
    Untagged legacy dicts fall back to ``parse_request``."""
    if payload.get("v") == API_VERSION and "kind" in payload:
        cls = _WIRE_KINDS.get(payload["kind"])
        if cls is None:
            raise InvalidRequestError(
                f"unknown wire kind {payload['kind']!r}", param="kind")
        return cls.from_dict(payload["data"])
    return parse_request(payload)


# ---------------------------------------------------------------------------
# responses
# ---------------------------------------------------------------------------

@dataclass
class CompletionChoice:
    index: int = 0
    tokens: list | None = None            # token ids (data plane) or None
    finish_reason: str = ""

    def to_dict(self) -> dict:
        d = {"index": self.index, "finish_reason": self.finish_reason}
        if self.tokens is not None:
            d["tokens"] = self.tokens
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompletionChoice":
        return cls(index=int(d.get("index", 0)), tokens=d.get("tokens"),
                   finish_reason=str(d.get("finish_reason", "")))


# legacy dict keys the pre-/v1 drivers read off raw result dicts
_LEGACY_KEYS = {
    "request_id": lambda r: r.id,
    "output_tokens": lambda r: r.usage.completion_tokens,
    "prompt_tokens": lambda r: r.usage.prompt_tokens,
    "cached_prompt_tokens": lambda r: r.usage.cached_tokens,
    "endpoint": lambda r: r.endpoint_id,
    "first_token_time": lambda r: r.first_token_time,
    "finish_time": lambda r: r.finish_time,
    "prefill_chunks": lambda r: r.prefill_chunks,
    "preemptions": lambda r: r.preemptions,
    "restore_cached_tokens": lambda r: r.restore_cached_tokens,
}


@dataclass
class _ResponseBase:
    id: str = ""
    model: str = ""
    created: float = 0.0
    usage: Usage = field(default_factory=Usage)
    # serving metadata beyond the OpenAI shape (kept under one key on the
    # wire): which federation endpoint answered + engine timing/accounting
    endpoint_id: str = ""
    first_token_time: float = 0.0
    finish_time: float = 0.0
    prefill_chunks: int = 0
    preemptions: int = 0
    restore_cached_tokens: int = 0
    cached: bool = False                  # served from the response cache

    object = "response"

    # -- Mapping-style legacy access ---------------------------------------
    def __getitem__(self, key):
        fn = _LEGACY_KEYS.get(key)
        if fn is None:
            raise KeyError(key)
        return fn(self)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def copy(self):
        return replace(self, usage=replace(self.usage))

    def _meta_dict(self) -> dict:
        return {"endpoint": self.endpoint_id,
                "first_token_time": round(self.first_token_time, 6),
                "finish_time": round(self.finish_time, 6),
                "prefill_chunks": self.prefill_chunks,
                "preemptions": self.preemptions,
                "restore_cached_tokens": self.restore_cached_tokens,
                "cached": self.cached}

    def _base_dict(self) -> dict:
        return {"id": self.id, "object": self.object, "model": self.model,
                "created": round(self.created, 6),
                "usage": self.usage.to_dict(),
                "first_meta": self._meta_dict()}

    @classmethod
    def _base_kwargs(cls, d: dict) -> dict:
        meta = d.get("first_meta") or {}
        return dict(id=str(d.get("id", "")), model=str(d.get("model", "")),
                    created=float(d.get("created", 0.0)),
                    usage=Usage.from_dict(d.get("usage") or {}),
                    endpoint_id=str(meta.get("endpoint", "")),
                    first_token_time=float(meta.get("first_token_time", 0.0)),
                    finish_time=float(meta.get("finish_time", 0.0)),
                    prefill_chunks=int(meta.get("prefill_chunks", 0)),
                    preemptions=int(meta.get("preemptions", 0)),
                    restore_cached_tokens=int(
                        meta.get("restore_cached_tokens", 0)),
                    cached=bool(meta.get("cached", False)))


@dataclass
class ChatCompletionResponse(_ResponseBase):
    choices: list = field(default_factory=list)   # list[CompletionChoice]

    object = "chat.completion"

    def to_dict(self) -> dict:
        d = self._base_dict()
        d["choices"] = [c.to_dict() for c in self.choices]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ChatCompletionResponse":
        return cls(choices=[CompletionChoice.from_dict(c)
                            for c in d.get("choices", ())],
                   **cls._base_kwargs(d))


@dataclass
class CompletionResponse(_ResponseBase):
    choices: list = field(default_factory=list)

    object = "text_completion"

    def to_dict(self) -> dict:
        d = self._base_dict()
        d["choices"] = [c.to_dict() for c in self.choices]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompletionResponse":
        return cls(choices=[CompletionChoice.from_dict(c)
                            for c in d.get("choices", ())],
                   **cls._base_kwargs(d))


@dataclass
class EmbeddingResponse(_ResponseBase):
    # DES embeddings carry no vector data; the real embedding service fills
    # ``data`` with {"object": "embedding", "index", "embedding"} entries
    data: list = field(default_factory=list)

    object = "list"

    def to_dict(self) -> dict:
        d = self._base_dict()
        d["data"] = self.data
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "EmbeddingResponse":
        return cls(data=list(d.get("data", ())), **cls._base_kwargs(d))


_RESPONSE_FOR = {
    "chat/completions": ChatCompletionResponse,
    "completions": CompletionResponse,
    "embeddings": EmbeddingResponse,
}


def response_from_result(req, result: dict, created: float):
    """Build the typed /v1 response for ``req`` from an endpoint result
    dict (the engine completion record)."""
    out = int(result.get("output_tokens", 0))
    usage = Usage(
        prompt_tokens=req.prompt_token_count,
        completion_tokens=out,
        total_tokens=req.prompt_token_count + out,
        cached_tokens=int(result.get("cached_prompt_tokens", 0)))
    cls = _RESPONSE_FOR[req.endpoint]
    kw = dict(
        id=str(result.get("request_id", req.request_id)),
        model=req.model, created=created, usage=usage,
        endpoint_id=str(result.get("endpoint", "")),
        first_token_time=float(result.get("first_token_time", 0.0)),
        finish_time=float(result.get("finish_time", 0.0)),
        prefill_chunks=int(result.get("prefill_chunks", 0)),
        preemptions=int(result.get("preemptions", 0)),
        restore_cached_tokens=int(result.get("restore_cached_tokens", 0)))
    if cls is EmbeddingResponse:
        return EmbeddingResponse(**kw)
    choice = CompletionChoice(index=0, tokens=result.get("tokens"),
                              finish_reason=str(
                                  result.get("finish_reason", "length")))
    return cls(choices=[choice], **kw)


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------

@dataclass
class StreamDelta:
    """One incremental chunk of a streamed response (SSE frame analogue).

    ``tokens`` holds the emitted ids on the data plane; the DES control
    plane streams counts only (``tokens=None``, ``n_tokens`` set). The
    final frame has ``finished=True`` + ``finish_reason`` and no tokens.

    ``offset`` is the stream position of the frame's FIRST token: if a
    fault-tolerance requeue restarts generation, re-emitted frames carry
    offsets the receiver has already passed and are deduplicated at the
    gateway — the client never sees a token twice."""
    id: str = ""
    index: int = 0                        # 0-based frame sequence number
    tokens: list | None = None
    n_tokens: int = 0
    offset: int = 0                       # stream position of tokens[0]
    created: float = 0.0                  # engine-side emit time
    finished: bool = False
    finish_reason: str = ""

    object = "chat.completion.chunk"

    def to_dict(self) -> dict:
        d = {"id": self.id, "object": self.object, "index": self.index,
             "n_tokens": self.n_tokens, "offset": self.offset,
             "created": round(self.created, 6)}
        if self.tokens is not None:
            d["tokens"] = self.tokens
        if self.finished:
            d["finished"] = True
            d["finish_reason"] = self.finish_reason
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "StreamDelta":
        return cls(id=str(d.get("id", "")), index=int(d.get("index", 0)),
                   tokens=d.get("tokens"),
                   n_tokens=int(d.get("n_tokens", 0)),
                   offset=int(d.get("offset", 0)),
                   created=float(d.get("created", 0.0)),
                   finished=bool(d.get("finished", False)),
                   finish_reason=str(d.get("finish_reason", "")))


# ---------------------------------------------------------------------------
# batches (/v1/batches)
# ---------------------------------------------------------------------------

@dataclass
class BatchItem:
    """One NDJSON line of a batch input file. ``body`` may be a typed
    request or its raw dict: parsing/validation is DEFERRED to
    ``parsed_body()`` so one malformed line becomes a per-request error
    instead of rejecting the whole batch."""
    custom_id: str
    body: Any                             # typed request OR its raw dict
    method: str = "POST"
    url: str = "/v1/completions"

    def parsed_body(self):
        """The typed, validated request; raises InvalidRequestError for
        THIS item only."""
        if isinstance(self.body, dict):
            ep = self.url.split("/v1/", 1)[-1]
            return parse_request(self.body, endpoint=ep)
        return self.body.validate()

    def body_model(self) -> str:
        return (self.body.get("model", "") if isinstance(self.body, dict)
                else self.body.model)

    def to_dict(self) -> dict:
        body = self.body if isinstance(self.body, dict) \
            else self.body.to_dict()
        return {"custom_id": self.custom_id, "method": self.method,
                "url": self.url, "body": body}

    @classmethod
    def from_dict(cls, d: dict) -> "BatchItem":
        if not d.get("custom_id"):
            raise InvalidRequestError("batch item needs 'custom_id'",
                                      param="custom_id")
        body = d.get("body")
        if not isinstance(body, dict):
            raise InvalidRequestError("batch item needs a 'body' object",
                                      param="body")
        return cls(custom_id=str(d["custom_id"]), body=body,
                   method=str(d.get("method", "POST")),
                   url=str(d.get("url", "/v1/completions")))


@dataclass
class BatchRequest:
    """/v1/batches submission: a list of request items processed offline
    on a dedicated instance. All items must target one model (one batch =
    one dedicated cluster job)."""
    items: list = field(default_factory=list)         # list[BatchItem]
    completion_window: str = "24h"
    metadata: dict = field(default_factory=dict)

    @property
    def model(self) -> str:
        for it in self.items:
            if it.body_model():
                return it.body_model()
        return ""

    def validate(self) -> "BatchRequest":
        models = {it.body_model() for it in self.items} - {""}
        if len(models) > 1:
            raise InvalidRequestError(
                f"batch items span multiple models {sorted(models)}; one "
                "batch runs one dedicated model job", param="items")
        ids = [it.custom_id for it in self.items]
        if len(set(ids)) != len(ids):
            raise InvalidRequestError("duplicate custom_id in batch",
                                      param="custom_id")
        return self

    def to_dict(self) -> dict:
        return {"object": "batch.request",
                "completion_window": self.completion_window,
                "metadata": self.metadata,
                "items": [it.to_dict() for it in self.items]}

    @classmethod
    def from_dict(cls, d: dict) -> "BatchRequest":
        return cls(items=[BatchItem.from_dict(it)
                          for it in d.get("items", ())],
                   completion_window=str(d.get("completion_window", "24h")),
                   metadata=dict(d.get("metadata") or {})).validate()


@dataclass
class BatchStatus:
    """/v1/batches/{id} poll result (OpenAI batch object shape)."""
    id: str = ""
    status: str = "validating"
    model: str = ""
    created_at: float = 0.0
    in_progress_at: float = 0.0
    completed_at: float = 0.0
    total: int = 0
    completed: int = 0
    failed: int = 0
    output_tokens: int = 0

    object = "batch"

    def to_dict(self) -> dict:
        return {"id": self.id, "object": self.object, "status": self.status,
                "model": self.model,
                "created_at": round(self.created_at, 6),
                "in_progress_at": round(self.in_progress_at, 6),
                "completed_at": round(self.completed_at, 6),
                "request_counts": {"total": self.total,
                                   "completed": self.completed,
                                   "failed": self.failed},
                "output_tokens": self.output_tokens}

    @classmethod
    def from_dict(cls, d: dict) -> "BatchStatus":
        counts = d.get("request_counts") or {}
        return cls(id=str(d.get("id", "")),
                   status=str(d.get("status", "validating")),
                   model=str(d.get("model", "")),
                   created_at=float(d.get("created_at", 0.0)),
                   in_progress_at=float(d.get("in_progress_at", 0.0)),
                   completed_at=float(d.get("completed_at", 0.0)),
                   total=int(counts.get("total", 0)),
                   completed=int(counts.get("completed", 0)),
                   failed=int(counts.get("failed", 0)),
                   output_tokens=int(d.get("output_tokens", 0)))

    # legacy keys (pre-/v1 BatchJob.status() dict)
    def __getitem__(self, key):
        legacy = {"batch_id": self.id, "state": self.status,
                  "completed": self.completed, "total": self.total,
                  "output_tokens": self.output_tokens}
        if key in legacy:
            return legacy[key]
        raise KeyError(key)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default


# ---------------------------------------------------------------------------
# data-plane bridge
# ---------------------------------------------------------------------------

def to_inference_request(req, arrival_time: float = 0.0):
    """Convert a typed /v1 request into the engine's ``InferenceRequest``
    (data plane only: the prompt must be token ids)."""
    from repro.serving.request import InferenceRequest, SamplingParams
    ids = req.prompt_token_ids
    if ids is None:
        raise InvalidRequestError(
            "data-plane requests need token ids, not a token count",
            param="prompt_tokens")
    return InferenceRequest(
        model=req.model, prompt_tokens=list(ids),
        request_id=req.request_id, user=req.user or "anonymous",
        arrival_time=arrival_time, api_endpoint=req.endpoint,
        qos=req.qos, priority=req.priority, deadline=req.deadline,
        sampling=SamplingParams(max_tokens=req.max_tokens,
                                temperature=req.temperature,
                                top_p=req.top_p, seed=req.seed,
                                stop_token=req.stop_token))
