"""Typed /v1 client over the Inference Gateway.

The DES analogue of an OpenAI SDK: builds typed requests, submits them to
a gateway, and hands back futures of typed responses. Streaming requests
attach a ``StreamAssembler`` (or any callback) to receive ``StreamDelta``
frames; ``cancel`` models a client disconnect.

    client = FirstClient(system.gateway, token)
    fut = client.chat(model="llama3.3-70b", prompt_tokens=256,
                      max_tokens=64)
    system.loop.run_until_idle()
    resp = fut.result()             # ChatCompletionResponse, with .usage
"""
from __future__ import annotations

from repro.api import schemas
from repro.api.stream import StreamAssembler


class FirstClient:
    def __init__(self, gateway, token: str):
        self.gateway = gateway
        self.token = token

    # -- generation -------------------------------------------------------------
    def chat(self, *, on_delta=None, **fields):
        """/v1/chat/completions; pass ``stream=True`` + ``on_delta`` for
        incremental frames."""
        req = schemas.ChatCompletionRequest(**fields)
        return self.gateway.submit(self.token, req, on_delta=on_delta)

    def complete(self, *, on_delta=None, **fields):
        """/v1/completions."""
        req = schemas.CompletionRequest(**fields)
        return self.gateway.submit(self.token, req, on_delta=on_delta)

    def embed(self, **fields):
        """/v1/embeddings."""
        req = schemas.EmbeddingRequest(**fields)
        return self.gateway.submit(self.token, req)

    def stream(self, *, assembler: StreamAssembler | None = None, **fields):
        """Streamed chat completion: returns ``(future, assembler)`` — the
        assembler collects frames and client-observed TTFT/ITL while the
        future resolves with the full typed response."""
        asm = assembler or StreamAssembler(clock=self.gateway.loop)
        fut = self.chat(stream=True, on_delta=asm, **fields)
        return fut, asm

    def cancel(self, request_id: str) -> bool:
        """Model a client disconnect: abort the in-flight request."""
        return self.gateway.cancel(request_id)

    # -- batches ----------------------------------------------------------------
    def create_batch(self, items, **fields):
        """/v1/batches: ``items`` are ``BatchItem``s (or their dicts)."""
        req = schemas.BatchRequest(
            items=[schemas.BatchItem.from_dict(it) if isinstance(it, dict)
                   else it for it in items], **fields)
        return self.gateway.create_batch(self.token, req)

    def batch_status(self, batch_id: str):
        return self.gateway.batch_status(batch_id)

    def batch_results(self, batch_id: str):
        return self.gateway.batch_results(batch_id)

    # -- status -----------------------------------------------------------------
    def jobs(self) -> dict:
        return self.gateway.jobs_status()
