"""OpenAI-style API error taxonomy with stable codes (paper §3.1).

Every error the /v1 surface can return is an ``APIError`` subclass carrying
a stable ``code`` (what clients switch on), an HTTP-equivalent ``status``
(what a real front end would send), and — for throttling errors — a
computed ``retry_after`` in seconds. ``to_dict()`` renders the OpenAI wire
shape ``{"error": {"message", "type", "code", "param", "retry_after"}}``.

The taxonomy is part of the versioned contract: codes never change meaning
across /v1 revisions, new conditions get NEW codes.
"""
from __future__ import annotations


class APIError(Exception):
    """Base of the /v1 error taxonomy."""

    code = "api_error"
    status = 500

    def __init__(self, message: str, *, param: str | None = None,
                 retry_after: float | None = None):
        super().__init__(message)
        self.message = message
        self.param = param
        self.retry_after = retry_after

    def to_dict(self) -> dict:
        err: dict = {"message": self.message, "type": self.code,
                     "code": self.code}
        if self.param is not None:
            err["param"] = self.param
        if self.retry_after is not None:
            err["retry_after"] = round(self.retry_after, 6)
        return {"error": err}

    def __repr__(self):                                    # pragma: no cover
        return f"{type(self).__name__}({self.message!r})"


class InvalidRequestError(APIError):
    """Malformed payload: unknown endpoint, bad types, out-of-range values."""
    code = "invalid_request_error"
    status = 400


class AuthenticationError(APIError):
    """Invalid/expired token, or the identity lacks access to the model."""
    code = "authentication_error"
    status = 401


class ModelNotFoundError(APIError):
    """The model is not configured anywhere in the federation registry."""
    code = "model_not_found"
    status = 404


class RateLimitError(APIError):
    """Per-user token bucket exhausted; ``retry_after`` says when the next
    request token accrues."""
    code = "rate_limit_error"
    status = 429


class OverloadedError(APIError):
    """Transient capacity exhaustion: gateway queue full, or no healthy
    endpoint currently hosts the model."""
    code = "overloaded"
    status = 503


class RequestCancelled(APIError):
    """The client disconnected (or a hedged duplicate lost the race) and the
    request was aborted before completion."""
    code = "request_cancelled"
    status = 499


class DegradedError(OverloadedError):
    """Shed by brownout admission control: the gateway is running in a
    degraded mode (capacity loss or sustained overload) and is deliberately
    rejecting lower-value work to protect interactive latency. A subclass
    of ``overloaded`` so legacy handlers keep working; clients that switch
    on the code can distinguish policy shedding from raw capacity
    exhaustion."""
    code = "degraded"
    status = 503


class UpstreamTimeoutError(APIError):
    """Every dispatch attempt timed out (or the retry budget ran dry) before
    an upstream endpoint produced a first token."""
    code = "upstream_timeout"
    status = 504


def error_from_dict(d: dict) -> APIError:
    """Parse the wire shape back into the matching typed error."""
    err = d.get("error", d)
    cls = _BY_CODE.get(err.get("code"), APIError)
    return cls(err.get("message", ""), param=err.get("param"),
               retry_after=err.get("retry_after"))


_BY_CODE = {c.code: c for c in (InvalidRequestError, AuthenticationError,
                                ModelNotFoundError, RateLimitError,
                                OverloadedError, RequestCancelled,
                                DegradedError, UpstreamTimeoutError,
                                APIError)}
