"""Client-side streaming helpers: collect ``StreamDelta`` frames back into
a full response and observe first-token / inter-token timing.

The gateway delivers frames through a plain callback (the DES analogue of
an SSE connection). ``StreamAssembler`` is that callback: it checks frame
ordering, accumulates tokens/counts, records arrival timestamps (TTFT and
per-frame inter-token gaps as seen by the CLIENT), and exposes the
reassembled stream — which must be token-identical to the non-streamed
response for the same request.
"""
from __future__ import annotations

from repro.api.schemas import StreamDelta


class StreamAssembler:
    """Reassemble a streamed response; call the instance with each frame."""

    def __init__(self, clock=None) -> None:
        self._clock = clock
        self.deltas: list[StreamDelta] = []
        self.tokens: list = []            # token ids (data plane)
        self.n_tokens = 0                 # token count (both planes)
        self.finish_reason = ""
        self.finished = False
        self.arrivals: list[float] = []   # client-side receive times

    def __call__(self, delta: StreamDelta):
        if delta.index != len(self.deltas):
            raise RuntimeError(
                f"stream frame out of order: got index {delta.index}, "
                f"expected {len(self.deltas)}")
        if self.finished:
            raise RuntimeError("frame after the finished frame")
        self.deltas.append(delta)
        if self._clock is not None:
            self.arrivals.append(self._clock.now())
        if delta.tokens is not None:
            self.tokens.extend(delta.tokens)
        self.n_tokens += delta.n_tokens
        if delta.finished:
            self.finished = True
            self.finish_reason = delta.finish_reason

    # -- client-observed timing -------------------------------------------
    @property
    def ttft(self) -> float | None:
        return self.arrivals[0] if self.arrivals else None

    @property
    def inter_token_gaps(self) -> list[float]:
        return [b - a for a, b in zip(self.arrivals, self.arrivals[1:])]
