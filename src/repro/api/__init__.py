"""Typed, versioned OpenAI-compatible /v1 API layer (the paper's product
surface): request/response schemas, the error taxonomy, token streaming,
and the batch-jobs shape. See docs/API.md for the full reference."""
from repro.api.errors import (APIError, AuthenticationError,
                              InvalidRequestError, ModelNotFoundError,
                              OverloadedError, RateLimitError,
                              RequestCancelled, error_from_dict)
from repro.api.schemas import (API_VERSION, VALID_ENDPOINTS, BatchItem,
                               BatchRequest, BatchStatus, ChatCompletionRequest,
                               ChatCompletionResponse, ChatMessage,
                               CompletionChoice, CompletionRequest,
                               CompletionResponse, EmbeddingRequest,
                               EmbeddingResponse, StreamDelta, Usage, dumps,
                               from_wire, parse_request, response_from_result,
                               to_inference_request, to_wire)
from repro.api.stream import StreamAssembler
from repro.api.client import FirstClient

__all__ = [
    "FirstClient",
    "APIError", "AuthenticationError", "InvalidRequestError",
    "ModelNotFoundError", "OverloadedError", "RateLimitError",
    "RequestCancelled", "error_from_dict",
    "API_VERSION", "VALID_ENDPOINTS", "BatchItem", "BatchRequest",
    "BatchStatus", "ChatCompletionRequest", "ChatCompletionResponse",
    "ChatMessage", "CompletionChoice", "CompletionRequest",
    "CompletionResponse", "EmbeddingRequest", "EmbeddingResponse",
    "StreamDelta", "Usage", "dumps", "from_wire", "parse_request",
    "response_from_result", "to_inference_request", "to_wire",
    "StreamAssembler",
]
