"""Activation-sharding hints: opt-in `with_sharding_constraint` insertion
points inside model code.

Default is OFF (None policy): single-device tests and the real CPU engine
never touch jax sharding machinery.  The dry-run (and a TPU launcher) wraps
lowering in ``use_hints(ShardingHints(...))`` to enable specific reshards.

Why this exists: archs whose head count is not divisible by the model axis
(qwen 20H, llama3.2 24H, yi/llava 56H on a 16-way axis) degrade head
sharding to REPLICATION — every model shard recomputes the full attention.
``attn_dp`` reshards the attention inputs so the BATCH covers
(data × model) and each chip does 1/256th of the attention work, at the
cost of two activation all-to-alls per layer (measured win in
EXPERIMENTS.md §Perf: the all-to-all bytes are ~100× smaller than the
replicated-compute waste).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass


@dataclass(frozen=True)
class ShardingHints:
    # axes that shard the batch dim of attention inputs (q/k/v) during
    # full-sequence attention; None disables the reshard
    attn_dp: tuple | None = None
    # axes the output is constrained back to (the model's default DP axes)
    batch_axes: tuple | None = None
    # mesh axis that keeps the MoE expert dim sharded through dispatch ->
    # GEMM -> combine, so only the (B,S,D) partial sums cross shards
    moe_ep: str | None = None
    # the plain data-parallel axes of the mesh (for explicit reshards)
    dp: tuple | None = None
    # blockwise cross-entropy: compute the LM loss in vocab chunks of this
    # size, never materializing the full (tokens, V) logits (the dominant
    # memory/collective term for small-model/large-vocab training)
    ce_chunk: int | None = None


def constrain(x, spec_axes):
    """with_sharding_constraint with an explicit per-dim axes tuple."""
    from jax import lax
    from jax.sharding import PartitionSpec as P
    return lax.with_sharding_constraint(x, P(*spec_axes))


_POLICY: ShardingHints | None = None


def current() -> ShardingHints | None:
    return _POLICY


@contextmanager
def use_hints(policy: ShardingHints):
    global _POLICY
    prev = _POLICY
    _POLICY = policy
    try:
        yield policy
    finally:
        _POLICY = prev


def constrain_batch(x, axes):
    """with_sharding_constraint(x, P(axes, None...)) if axes else x."""
    if axes is None:
        return x
    from jax import lax
    from jax.sharding import PartitionSpec as P
    return lax.with_sharding_constraint(
        x, P(axes, *(None,) * (x.ndim - 1)))
