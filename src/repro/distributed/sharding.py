"""Sharding rules: PartitionSpecs for params, optimizer state, batches, and
caches on the production mesh (DESIGN.md §5).

Layout summary
  mesh axes     single-pod (data=16, model=16); multi-pod (pod=2, data=16, model=16)
  TP ("model")  attention q/k/v/o columns-rows, MLP hidden, MoE experts,
                vocab/embedding
  DP (pod,data) batch dimension (training + serving)
  FSDP ("data") second weight dim during TRAINING (ZeRO-3-style: weights,
                grads, and Adam moments all sharded over data; XLA inserts the
                per-layer all-gather / reduce-scatter inside the layer scan).
                Serving keeps weights TP-only unless the model cannot fit
                (dbrx-132b), where FSDP stays on.
  KV caches     batch over DP; kv-heads over "model" when divisible, else
                head_dim over "model" (the contraction all-reduces over
                model — MQA/GQA-friendly, see DESIGN.md).

Every rule degrades to None when a dim is not divisible by the axis size
(GSPMD would pad; we prefer explicit replication and let the roofline's
MODEL_FLOPS/HLO ratio expose any waste we keep).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

_REPLICATED_NAMES = {
    "norm", "norm1", "norm2", "final_norm", "A_log", "D", "dt_bias",
    "conv_b", "conv_w", "router", "len",
}

SERVE_FSDP_BYTES = 8 << 30      # params/chip above this forces FSDP at serve


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_bytes(cfg: ModelConfig) -> int:
    bpp = 2 if cfg.param_dtype == "bfloat16" else 4
    return cfg.num_params * bpp


def needs_serve_fsdp(cfg: ModelConfig, model_shards: int = 16) -> bool:
    return param_bytes(cfg) / model_shards > SERVE_FSDP_BYTES


@dataclass
class ShardingRules:
    mesh: Mesh
    cfg: ModelConfig
    train: bool = True

    # -- helpers -----------------------------------------------------------------
    def _ax(self, axis, size):
        if axis is None:
            return None
        n = int(np.prod([self.mesh.shape[a] for a in
                         (axis if isinstance(axis, tuple) else (axis,))]))
        return axis if size % n == 0 else None

    @property
    def _fsdp(self):
        if self.train:
            return "data"
        return "data" if needs_serve_fsdp(self.cfg,
                                          self.mesh.shape["model"]) else None

    @property
    def _dp(self):
        return dp_axes(self.mesh)

    # -- params --------------------------------------------------------------------
    def _param_spec(self, path, shape) -> P:
        name = None
        for k in reversed(path):
            key = getattr(k, "key", None)
            if isinstance(key, str):
                name = key
                break
        nd = len(shape)
        lead = (None,) * (nd - 2)
        f, m = self._fsdp, "model"
        if name in _REPLICATED_NAMES or nd <= 1:
            return P()
        if name == "embed":
            return P(self._ax(m, shape[0]), self._ax(f, shape[1]))
        if name == "lm_head":
            return P(self._ax(f, shape[0]), self._ax(m, shape[1]))
        if name in ("wq", "wk", "wv", "w1", "w3", "in_proj"):
            if nd == 4:      # MoE expert stack (L, E, D, F): experts over
                # model, FSDP on F (column-split): contracting D stays
                # shard-local, so no giant partial-sum all-reduce (§Perf,
                # dbrx prefill: 28 GB/layer -> (B,E,cap,D) once)
                return P(None, self._ax(m, shape[1]), None,
                         self._ax(f, shape[3]))
            return P(*lead, self._ax(f, shape[-2]), self._ax(m, shape[-1]))
        if name in ("wo", "w2", "out_proj"):
            if nd == 4:      # MoE w2 (L, E, F, D): FSDP on F (row-split),
                # paired with w1/w3 so h flows shard-local through the MLP
                return P(None, self._ax(m, shape[1]),
                         self._ax(f, shape[2]), None)
            return P(*lead, self._ax(m, shape[-2]), self._ax(f, shape[-1]))
        if name in ("bq", "bk", "bv"):
            # stacked-per-layer biases are (L, dim): only the LAST dim is TP
            return P(*((None,) * (nd - 1)), self._ax(m, shape[-1]))
        return P()           # conservative default: replicate

    def param_specs(self, params_shapes):
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: self._param_spec(p, leaf.shape), params_shapes)

    def opt_specs(self, opt_shapes, params_shapes):
        """Adam m/v mirror the (train) param layout; step is replicated."""
        pspecs = self.param_specs(params_shapes)
        return {"m": pspecs, "v": pspecs, "step": P()}

    # -- batches ----------------------------------------------------------------------
    def _batched(self, shape) -> P:
        b = self._ax(self._dp, shape[0])
        return P(b, *(None,) * (len(shape) - 1))

    def batch_specs(self, batch_shapes):
        return jax.tree.map(lambda leaf: self._batched(leaf.shape),
                            batch_shapes)

    # -- caches -----------------------------------------------------------------------
    def _cache_spec(self, path, shape) -> P:
        name = None
        for k in reversed(path):
            key = getattr(k, "key", None)
            if isinstance(key, str):
                name = key
                break
        if name == "len":
            return P(self._ax(self._dp, shape[0]))
        b = self._ax(self._dp, shape[1])
        if name in ("k", "v"):
            # (L|G, B, KH, S, hd): batch over DP, SEQUENCE over model —
            # flash-decoding-style split: each model shard attends over its
            # S-chunk and GSPMD combines with small all-reduces (max/sum of
            # the online softmax + the (B,H,hd) output). Uniform across GQA/
            # MQA/MHA head counts, unlike head sharding (DESIGN.md §5).
            # kv-heads-major layout: seq is dim 3.
            return P(None, b, None, self._ax("model", shape[3]), None)
        if name == "ssm":      # (L, B, H, Phead, N)
            return P(None, b, self._ax("model", shape[2]), None, None)
        if name == "conv":     # (L, B, K-1, Ch)
            return P(None, b, None, self._ax("model", shape[3]))
        return P(*(None,) * len(shape))

    def cache_specs(self, cache_shapes):
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: self._cache_spec(p, leaf.shape), cache_shapes)

    # -- materialization -----------------------------------------------------------------
    def named(self, spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))


class ServeSharding:
    """Serving-time placement policy for tensor-parallel inference.

    One object per backend, built from the engine's mesh. The placement
    contract the sharded engine relies on:

    * **params** — TP-sharded by :class:`ShardingRules` (``train=False``):
      attention q/k/v and MLP columns over ``model``, wo/w2 rows over
      ``model``, MoE expert stacks over ``model`` (expert-parallel decode
      falls out of the einsum), everything small replicated.
    * **KV** — paged pools ``(L, NP, page, KH, hd)`` and slot caches
      ``(L, B, KH, S, hd)`` shard the kv-head axis over ``model``; when the
      head count is not divisible (GQA/MQA on a wide mesh) the head_dim
      axis shards instead, and when neither divides the cache replicates.
      Block tables / lengths / refcounts are host-side and replicated.
    * **everything the sampler touches** — decode state, tables, lens,
      token uploads — is replicated, so every shard samples the same token
      from its full (all-gathered) logits and only O(max_slots) ids ever
      sync to the host: the zero-logits-transfer invariant survives
      sharding.

    ``pin_*`` wrap ``with_sharding_constraint`` and are applied inside the
    jitted bodies so carried cache/state shardings are fixed points across
    calls (donation stays effective, GSPMD never drifts the layout).
    """

    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        if "model" not in mesh.axis_names:
            raise ValueError(
                f"serving mesh needs a 'model' axis, got {mesh.axis_names}; "
                f"build one with launch.make_local_mesh(data, model)")
        self.mesh = mesh
        self.cfg = cfg
        self.rules = ShardingRules(mesh, cfg, train=False)
        self.replicated = NamedSharding(mesh, P())

    @property
    def model_shards(self) -> int:
        return int(self.mesh.shape["model"])

    # -- placement (device_put, at init / upload time) ---------------------------
    def shard_params(self, params):
        specs = self.rules.param_specs(params)
        return jax.device_put(params, self.rules.named(specs))

    def _head_axes(self, kh: int, hd: int):
        """(kv-head axis, head_dim axis): kv-heads over model when
        divisible, else head_dim over model, else replicate."""
        if self.rules._ax("model", kh) is not None:
            return "model", None
        if self.rules._ax("model", hd) is not None:
            return None, "model"
        return None, None

    def pool_spec(self, shape) -> P:
        """Paged KV pool (L, num_pages, page_size, KH, hd)."""
        kh, hd = self._head_axes(shape[3], shape[4])
        return P(None, None, None, kh, hd)

    def view_spec(self, shape) -> P:
        """Gathered context view (L, B, S, KH, hd): same head-axis policy
        as the pool it was gathered from, batch/seq replicated (the fused
        twin's split attention contracts over S per shard)."""
        kh, hd = self._head_axes(shape[3], shape[4])
        return P(None, None, None, kh, hd)

    def slot_cache_spec(self, name: str, shape) -> P:
        """Slot cache leaf by name: k/v are (L, B, KH, S, hd); len and the
        SSM/conv states replicate."""
        if name in ("k", "v"):
            kh, hd = self._head_axes(shape[2], shape[4])
            return P(None, None, kh, None, hd)
        return P()

    def shard_pools(self, pools):
        return {n: jax.device_put(
            a, NamedSharding(self.mesh, self.pool_spec(a.shape)))
            for n, a in pools.items()}

    def shard_slot_cache(self, cache):
        return {n: jax.device_put(
            a, NamedSharding(self.mesh, self.slot_cache_spec(n, a.shape)))
            for n, a in cache.items()}

    def replicate(self, x):
        """Host upload, replicated onto the mesh's device set (mixing a
        committed single-device array into a mesh jit is an error)."""
        return jax.device_put(x, self.replicated)

    # -- constraints (with_sharding_constraint, inside jit) ----------------------
    def pin(self, x, spec: P):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def pin_replicated(self, tree):
        return jax.tree.map(lambda a: self.pin(a, P()), tree)

    def pin_pools(self, pools):
        return {n: self.pin(a, self.pool_spec(a.shape))
                for n, a in pools.items()}

    def pin_view(self, view):
        return {n: self.pin(a, self.view_spec(a.shape))
                for n, a in view.items()}

    def pin_slot_cache(self, cache):
        return {n: self.pin(a, self.slot_cache_spec(n, a.shape))
                for n, a in cache.items()}
