"""Fault-tolerant checkpointing: msgpack + zstd, atomic writes, and ELASTIC
restore — a checkpoint written under one mesh restores onto any other mesh
(arrays are saved in logical (unsharded) form and re-placed with the target
shardings at load). This is the restart path for node failures and for
elastic up/down-scaling of the training fleet.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:                       # pragma: no cover - env dependent
    zstandard = None                      # fall back to stdlib zlib
import zlib

FORMAT_VERSION = 1
# compression is self-describing so a checkpoint written with zstd loads in
# an environment that only has zlib (and vice versa)
_MAGIC_ZSTD = b"RPZS"
_MAGIC_ZLIB = b"RPZL"


def _compress(data: bytes, level: int) -> bytes:
    if zstandard is not None:
        return _MAGIC_ZSTD + zstandard.ZstdCompressor(level=level).compress(
            data)
    # zstd levels go to 22; zlib only accepts -1..9
    return _MAGIC_ZLIB + zlib.compress(data, min(level, 9))


def _decompress(blob: bytes) -> bytes:
    magic, body = blob[:4], blob[4:]
    if magic == _MAGIC_ZLIB:
        return zlib.decompress(body)
    if magic == _MAGIC_ZSTD:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "zstandard module is not installed")
        return zstandard.ZstdDecompressor().decompress(body)
    # legacy (pre-magic) checkpoints were always zstd
    if zstandard is not None:
        return zstandard.ZstdDecompressor().decompress(blob)
    raise RuntimeError("unrecognized checkpoint compression header")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(path: str, tree, *, step: int = 0, metadata: dict | None
                    = None, level: int = 3) -> None:
    """Atomic (tmp + rename) so a crash mid-save never corrupts the latest
    checkpoint."""
    paths, leaves, _ = _flatten(tree)
    arrays = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        arrays.append({
            "dtype": arr.dtype.str if arr.dtype != jnp.bfloat16 else "bfloat16",
            "shape": list(arr.shape),
            "data": (arr.view(np.uint16) if arr.dtype == jnp.bfloat16
                     else arr).tobytes(),
        })
    payload = {
        "version": FORMAT_VERSION,
        "step": step,
        "metadata": metadata or {},
        "paths": paths,
        "arrays": arrays,
    }
    packed = msgpack.packb(payload, use_bin_type=True)
    compressed = _compress(packed, level)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(compressed)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_checkpoint(path: str, target=None, shardings=None):
    """Returns (tree, step, metadata). ``target`` (a pytree of the same
    structure) restores the original structure; without it a flat
    {path: array} dict is returned. ``shardings`` (pytree of NamedSharding
    matching target) re-places arrays for the CURRENT mesh — elastic restore."""
    with open(path, "rb") as f:
        packed = _decompress(f.read())
    payload = msgpack.unpackb(packed, raw=False)
    assert payload["version"] == FORMAT_VERSION
    arrays = []
    for spec in payload["arrays"]:
        if spec["dtype"] == "bfloat16":
            arr = np.frombuffer(spec["data"], np.uint16).reshape(
                spec["shape"])
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(spec["data"],
                                np.dtype(spec["dtype"])).reshape(spec["shape"])
        arrays.append(arr)
    if target is None:
        tree = dict(zip(payload["paths"], arrays))
    else:
        t_paths, t_leaves, treedef = _flatten(target)
        by_path = dict(zip(payload["paths"], arrays))
        missing = [p for p in t_paths if p not in by_path]
        if missing:
            raise KeyError(f"checkpoint missing {len(missing)} arrays, "
                           f"e.g. {missing[:3]}")
        ordered = [by_path[p] for p in t_paths]
        tree = jax.tree_util.tree_unflatten(treedef, ordered)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings)
    step = payload["step"]
    return tree, step, payload["metadata"]


def latest_checkpoint(ckpt_dir: str, prefix: str = "ckpt_"):
    if not os.path.isdir(ckpt_dir):
        return None
    cands = [f for f in os.listdir(ckpt_dir)
             if f.startswith(prefix) and f.endswith(".ckpt")]
    if not cands:
        return None
    steps = sorted((int(f[len(prefix):-5]), f) for f in cands)
    return os.path.join(ckpt_dir, steps[-1][1])


def checkpoint_path(ckpt_dir: str, step: int, prefix: str = "ckpt_"):
    return os.path.join(ckpt_dir, f"{prefix}{step:08d}.ckpt")
