"""PagedAttention decode Pallas TPU kernel.

TPU adaptation of vLLM's PagedAttention (DESIGN.md §2): the per-sequence block
table lives in scalar-prefetch (SMEM) and *drives the DMA schedule* — the
BlockSpec index_map dereferences ``block_tables[b, pi]`` so each grid step
streams exactly one KV page HBM->VMEM. Pages are large (multiples of 128
tokens) so tiles are MXU/VPU aligned, and an online-softmax accumulator in
VMEM scratch merges pages (flash-decoding style).

Grid: (B, KH, pages_per_seq) — pages innermost for the accumulator carry.

GQA: the G = H // KH query heads sharing a KV head ride along the q tile's
sublane axis, so one page DMA serves all of them in a single (G, page)
MXU contraction. The ops wrapper pads G up to the dtype's sublane tile for
real-TPU lowering; the kernel itself is grouping-agnostic (G=1 MHA,
G=H MQA, anything between).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size, num_pages, scale):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = lens_ref[b]
    page_start = pi * page_size
    live = page_start < ctx

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)                # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)                # (page, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, page)
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < ctx, s, NEG_INF)
        m_prev = m_scr[...]                                   # (G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(pi == num_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _compiler_kw(interpret, semantics):
    # renamed across jax releases: CompilerParams <-> TPUCompilerParams
    params_cls = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
    if params_cls is None or interpret:
        return {}
    return {"compiler_params": params_cls(dimension_semantics=semantics)}


def paged_attention_fwd(q, k_pages, v_pages, block_tables, context_lens, *,
                        interpret=False):
    """q: (B, KH, G, D); k_pages/v_pages: (NP, page, KH, D);
    block_tables: (B, PPS) int32; context_lens: (B,) int32.
    Returns (B, KH, G, D)."""
    B, KH, G, D = q.shape
    NP, page, _, _ = k_pages.shape
    PPS = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_paged_kernel, page_size=page,
                               num_pages=PPS, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, PPS),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, pi, tables, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, pi, tables, lens: (tables[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, pi, tables, lens: (tables[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, pi, tables, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    # batch and kv-head grid axes are independent; the page axis carries
    # the online-softmax accumulator and must run in order
    kw = _compiler_kw(interpret, ("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        interpret=interpret,
        **kw,
    )(block_tables, context_lens, q, k_pages, v_pages)


# -- fused decode: paged context + in-flight tail ----------------------------

def _decode_tail_kernel(tables_ref, clens_ref, tlens_ref, q_ref, k_ref, v_ref,
                        kt_ref, vt_ref, o_ref, m_scr, l_scr, acc_scr, *,
                        page_size, num_pages, scale):
    """One extra grid step past the pages attends the in-flight tail.

    The K-step fused decode loop keeps the tokens generated *this call* in
    small (B, K, KH, D) tail buffers instead of scattering them into the
    page pool every step.  Grid step ``pi == num_pages`` folds that tail
    into the same online-softmax accumulator the page steps built, so one
    kernel launch covers committed context + uncommitted tail.
    """
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _online_update(k, v, valid):
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    ctx = clens_ref[b]
    page_start = pi * page_size
    is_tail = pi == num_pages

    @pl.when(jnp.logical_and(pi < num_pages, page_start < ctx))
    def _pages():
        k = k_ref[0, :, 0].astype(jnp.float32)                # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_ref.shape[2], page_size), 1)
        _online_update(k, v, pos < ctx)

    @pl.when(jnp.logical_and(is_tail, tlens_ref[b] > 0))
    def _tail():
        k = kt_ref[0, :, 0].astype(jnp.float32)               # (Kt, D)
        v = vt_ref[0, :, 0].astype(jnp.float32)
        j = jax.lax.broadcasted_iota(
            jnp.int32, (q_ref.shape[2], kt_ref.shape[1]), 1)
        _online_update(k, v, j < tlens_ref[b])

    @pl.when(is_tail)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_tail_fwd(q, k_pages, v_pages, block_tables, context_lens,
                          k_tail, v_tail, tail_lens, *, interpret=False):
    """q: (B, KH, G, D); k_pages/v_pages: (NP, page, KH, D);
    k_tail/v_tail: (B, Kt, KH, D) this call's in-flight tokens;
    block_tables: (B, PPS), context_lens / tail_lens: (B,), all int32.
    Returns (B, KH, G, D).  Position ``i`` attends committed context
    ``[0, context_lens[i])`` from the pages plus tail rows
    ``[0, tail_lens[i])`` — exactly contiguous positions
    ``[0, context_lens[i] + tail_lens[i])``."""
    B, KH, G, D = q.shape
    NP, page, _, _ = k_pages.shape
    Kt = k_tail.shape[1]
    PPS = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_tail_kernel, page_size=page,
                               num_pages=PPS, scale=scale)
    # grid step PPS is the tail step; its page index_map is clamped onto a
    # real page (the block is DMA'd but unread — only the tail refs are)
    last = PPS - 1

    def page_map(b, h, pi, tables, clens, tlens):
        return (tables[b, jnp.minimum(pi, last)], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KH, PPS + 1),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, pi, tables, clens, tlens: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), page_map),
            pl.BlockSpec((1, page, 1, D), page_map),
            pl.BlockSpec((1, Kt, 1, D),
                         lambda b, h, pi, tables, clens, tlens: (b, 0, h, 0)),
            pl.BlockSpec((1, Kt, 1, D),
                         lambda b, h, pi, tables, clens, tlens: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, D),
            lambda b, h, pi, tables, clens, tlens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kw = _compiler_kw(interpret, ("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        interpret=interpret,
        **kw,
    )(block_tables, context_lens, tail_lens, q, k_pages, v_pages,
      k_tail, v_tail)
