"""Public jit'd wrapper for the paged-attention decode kernel.

GQA handling lives here: the kernel grid iterates (batch, kv-head, page)
and expects the query tensor grouped as (B, KH, G, D) with G = H // KH
query heads sharing each KV head. Real-TPU lowering requires the (G, D)
query tile's sublane axis to be a multiple of the dtype's min tile (8 for
f32, 16 for bf16), which odd groupings (e.g. yi's 56q/8kv -> G=7) and
small groups (G < 8) violate — so the wrapper pads the group axis up to
the sublane tile, lets the padded rows compute garbage against the same
pages, and slices them off. MQA (KH=1) and MHA (G=1) are just the
endpoints of the same path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_fwd


def _sublane(dtype) -> int:
    return 16 if dtype == jnp.bfloat16 else 8


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    interpret=None):
    """Decode attention over a paged KV cache.

    q: (B, H, D) one query token per sequence;
    k_pages / v_pages: (NP, page_size, KH, D) the global page pool;
    block_tables: (B, pages_per_seq) int32 page ids (pad with 0 beyond len);
    context_lens: (B,) int32 valid token counts.
    ``interpret=None`` auto-selects: compiled Pallas on TPU, the
    interpreter elsewhere (CPU tests / parity checks).
    Returns (B, H, D).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, D = q.shape
    KH = k_pages.shape[2]
    assert H % KH == 0, \
        f"query heads ({H}) must be a multiple of kv heads ({KH})"
    G = H // KH
    qr = q.reshape(B, KH, G, D)
    sub = _sublane(q.dtype)
    Gp = -(-G // sub) * sub
    if Gp != G:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    out = paged_attention_fwd(qr, k_pages, v_pages,
                              block_tables.astype(jnp.int32),
                              context_lens.astype(jnp.int32),
                              interpret=interpret)
    if Gp != G:
        out = out[:, :, :G]
    return out.reshape(B, H, D)
