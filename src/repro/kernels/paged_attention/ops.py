"""Public wrappers for the paged-attention decode kernels.

GQA handling lives here: the kernel grid iterates (batch, kv-head, page)
and expects the query tensor grouped as (B, KH, G, D) with G = H // KH
query heads sharing each KV head. Real-TPU lowering requires the (G, D)
query tile's sublane axis to be a multiple of the dtype's min tile (8 for
f32, 16 for bf16), which odd groupings (e.g. yi's 56q/8kv -> G=7) and
small groups (G < 8) violate — so the wrapper pads the group axis up to
the sublane tile, lets the padded rows compute garbage against the same
pages, and slices them off. MQA (KH=1) and MHA (G=1) are just the
endpoints of the same path. The fused-decode wrapper pads the in-flight
tail the same way along its token axis.

``interpret`` resolution: ``interpret`` is a static argument of the inner
jitted functions, so its value must be stable across calls — a per-call
``jax.default_backend()`` probe could flip (e.g. a test harness forcing a
platform mid-process) and silently retrace every kernel mid-serve. The
backend is therefore resolved ONCE, at first use, and cached in
``_BACKEND_INTERPRET``; ``kernels_compiled()`` exposes the same answer to
the serving layer for dispatch decisions.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                    # moved across jax releases
    from jax import shard_map as _shard_map
except ImportError:                     # pragma: no cover - version shim
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.kernels.paged_attention.kernel import (paged_attention_fwd,
                                                  paged_decode_tail_fwd)

_BACKEND_INTERPRET: bool | None = None


def _default_interpret() -> bool:
    """Resolve (once) whether Pallas runs interpreted on this backend."""
    global _BACKEND_INTERPRET
    if _BACKEND_INTERPRET is None:
        _BACKEND_INTERPRET = jax.default_backend() != "tpu"
    return _BACKEND_INTERPRET


def kernels_compiled() -> bool:
    """True when compiled Pallas lowering is available (TPU backend)."""
    return not _default_interpret()


def _sublane(dtype) -> int:
    return 16 if dtype == jnp.bfloat16 else 8


def _group(q, KH):
    B, H, D = q.shape
    assert H % KH == 0, \
        f"query heads ({H}) must be a multiple of kv heads ({KH})"
    return q.reshape(B, KH, H // KH, D)


def _pad_axis(x, axis, mult):
    n = x.shape[axis]
    np_ = -(-n // mult) * mult
    if np_ == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, np_ - n)
    return jnp.pad(x, pad)


@partial(jax.jit, static_argnames=("interpret",))
def _paged_attention_grouped(qr, k_pages, v_pages, block_tables,
                             context_lens, *, interpret):
    """qr: (B, KH, G, D) grouped queries. Returns (B, KH, G, D)."""
    G = qr.shape[2]
    qp = _pad_axis(qr, 2, _sublane(qr.dtype))
    out = paged_attention_fwd(qp, k_pages, v_pages,
                              block_tables.astype(jnp.int32),
                              context_lens.astype(jnp.int32),
                              interpret=interpret)
    return out[:, :, :G]


def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    interpret=None):
    """Decode attention over a paged KV cache.

    q: (B, H, D) one query token per sequence;
    k_pages / v_pages: (NP, page_size, KH, D) the global page pool;
    block_tables: (B, pages_per_seq) int32 page ids (pad with 0 beyond len);
    context_lens: (B,) int32 valid token counts.
    ``interpret=None`` auto-selects once per process: compiled Pallas on
    TPU, the interpreter elsewhere (CPU tests / parity checks).
    Returns (B, H, D).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, H, D = q.shape
    out = _paged_attention_grouped(_group(q, k_pages.shape[2]), k_pages,
                                   v_pages, block_tables, context_lens,
                                   interpret=interpret)
    return out.reshape(B, H, D)


@partial(jax.jit, static_argnames=("interpret",))
def _fused_decode_grouped(qr, k_pages, v_pages, block_tables, context_lens,
                          k_tail, v_tail, tail_lens, *, interpret):
    G = qr.shape[2]
    qp = _pad_axis(qr, 2, _sublane(qr.dtype))
    # tail rides the kernel's sublane axis too: pad the token axis and let
    # tail_lens mask the padded rows
    kt = _pad_axis(k_tail, 1, _sublane(k_tail.dtype))
    vt = _pad_axis(v_tail, 1, _sublane(v_tail.dtype))
    out = paged_decode_tail_fwd(qp, k_pages, v_pages,
                                block_tables.astype(jnp.int32),
                                context_lens.astype(jnp.int32),
                                kt, vt, tail_lens.astype(jnp.int32),
                                interpret=interpret)
    return out[:, :, :G]


def fused_decode_attention(q, k_pages, v_pages, block_tables, context_lens,
                           k_tail, v_tail, tail_lens, *, interpret=None):
    """Decode attention over committed pages + an in-flight tail buffer.

    The K-step fused decode loop accumulates this call's freshly generated
    KV in (B, K, KH, D) tail buffers and defers the page-pool scatter to
    the end of the call; position ``b`` attends pages ``[0, context_lens[b])``
    plus tail rows ``[0, tail_lens[b])``.  Shapes as ``paged_attention``
    plus k_tail/v_tail: (B, Kt, KH, D) and tail_lens: (B,).
    Returns (B, H, D).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, H, D = q.shape
    out = _fused_decode_grouped(_group(q, k_pages.shape[2]), k_pages,
                                v_pages, block_tables, context_lens,
                                k_tail, v_tail, tail_lens,
                                interpret=interpret)
    return out.reshape(B, H, D)


# -- shard_map variants ------------------------------------------------------
# GSPMD cannot partition a Pallas kernel body, so under a mesh the kernel
# runs per-shard via shard_map over the kv-head axis: queries (grouped) and
# the page pools both split on KH, block tables / lengths are replicated,
# and no collective is needed — each kv head's attention is independent.
# Requires KH % mesh.shape[axis] == 0 (the caller falls back to the jnp
# reference otherwise).


def shardable_kv_heads(num_kv_heads: int, mesh, axis: str = "model") -> bool:
    return mesh is not None and num_kv_heads % mesh.shape[axis] == 0


def paged_attention_sharded(q, k_pages, v_pages, block_tables, context_lens,
                            *, mesh, axis: str = "model", interpret=None):
    """``paged_attention`` under a mesh: per-shard kernels over kv heads."""
    if interpret is None:
        interpret = _default_interpret()
    B, H, D = q.shape
    qr = _group(q, k_pages.shape[2])
    fn = _shard_map(
        partial(_paged_attention_grouped, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, axis, None, None), P(None, None, axis, None),
                  P(None, None, axis, None), P(None, None), P(None)),
        out_specs=P(None, axis, None, None),
        check_rep=False,
    )
    out = fn(qr, k_pages, v_pages, block_tables.astype(jnp.int32),
             context_lens.astype(jnp.int32))
    return out.reshape(B, H, D)


def fused_decode_attention_sharded(q, k_pages, v_pages, block_tables,
                                   context_lens, k_tail, v_tail, tail_lens,
                                   *, mesh, axis: str = "model",
                                   interpret=None):
    """``fused_decode_attention`` under a mesh (tails split on KH too)."""
    if interpret is None:
        interpret = _default_interpret()
    B, H, D = q.shape
    qr = _group(q, k_pages.shape[2])
    fn = _shard_map(
        partial(_fused_decode_grouped, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, axis, None, None), P(None, None, axis, None),
                  P(None, None, axis, None), P(None, None), P(None),
                  P(None, None, axis, None), P(None, None, axis, None),
                  P(None)),
        out_specs=P(None, axis, None, None),
        check_rep=False,
    )
    out = fn(qr, k_pages, v_pages, block_tables.astype(jnp.int32),
             context_lens.astype(jnp.int32), k_tail, v_tail,
             tail_lens.astype(jnp.int32))
    return out.reshape(B, H, D)
