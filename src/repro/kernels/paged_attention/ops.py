"""Public jit'd wrapper for the paged-attention decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_fwd


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    interpret=False):
    """Decode attention over a paged KV cache.

    q: (B, H, D) one query token per sequence;
    k_pages / v_pages: (NP, page_size, KH, D) the global page pool;
    block_tables: (B, pages_per_seq) int32 page ids (pad with 0 beyond len);
    context_lens: (B,) int32 valid token counts.
    Returns (B, H, D).
    """
    B, H, D = q.shape
    KH = k_pages.shape[2]
    G = H // KH
    qr = q.reshape(B, KH, G, D)
    out = paged_attention_fwd(qr, k_pages, v_pages,
                              block_tables.astype(jnp.int32),
                              context_lens.astype(jnp.int32),
                              interpret=interpret)
    return out.reshape(B, H, D)
