"""Pure-jnp oracle for paged decode attention: gather pages into a contiguous
cache, then masked softmax attention for a single query token. Also hosts the
paged *prefill* read path used by chunked prefill: a multi-token query block
attending over the page pool (cached prefix pages + the chunk's own pages)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_kv(pages, block_tables):
    """pages: (NP, page, KH, D); block_tables: (B, PPS) -> (B, PPS*page, KH, D)."""
    g = pages[block_tables]                   # (B, PPS, page, KH, D)
    B, PPS, page, KH, D = g.shape
    return g.reshape(B, PPS * page, KH, D)


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """q: (B, H, D); pages: (NP, page, KH, D); returns (B, H, D)."""
    B, H, D = q.shape
    KH = k_pages.shape[2]
    G = H // KH
    k = gather_kv(k_pages, block_tables)      # (B, S, KH, D)
    v = gather_kv(v_pages, block_tables)
    S = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < context_lens[:, None]      # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def decode_tail_attention_ref(q, k_ctx, v_ctx, context_lens, k_tail, v_tail,
                              tail_lens):
    """Split decode attention: committed context view + in-flight tail.

    q: (B, H, D); k_ctx/v_ctx: (B, S, KH, D) a contiguous view of the
    committed pages (only ``[0, context_lens[b])`` valid); k_tail/v_tail:
    (B, Kt, KH, D) tokens generated this fused call (``[0, tail_lens[b])``
    valid). Scores for both segments are concatenated before ONE softmax,
    so the result equals attention over the contiguous positions
    ``[0, context_lens[b] + tail_lens[b])``. Returns (B, H, D).
    """
    B, H, D = q.shape
    KH = k_ctx.shape[2]
    G = H // KH
    S = k_ctx.shape[1]
    Kt = k_tail.shape[1]
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D).astype(jnp.float32)
    s_ctx = jnp.einsum("bhgd,bkhd->bhgk", qr,
                       k_ctx.astype(jnp.float32)) * scale
    s_tail = jnp.einsum("bhgd,bkhd->bhgk", qr,
                        k_tail.astype(jnp.float32)) * scale
    m_ctx = jnp.arange(S)[None, :] < context_lens[:, None]
    m_tail = jnp.arange(Kt)[None, :] < tail_lens[:, None]
    s = jnp.concatenate(
        [jnp.where(m_ctx[:, None, None, :], s_ctx, NEG_INF),
         jnp.where(m_tail[:, None, None, :], s_tail, NEG_INF)], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    out = (jnp.einsum("bhgk,bkhd->bhgd", p[..., :S],
                      v_ctx.astype(jnp.float32))
           + jnp.einsum("bhgk,bkhd->bhgd", p[..., S:],
                        v_tail.astype(jnp.float32)))
    return out.reshape(B, H, D).astype(q.dtype)


def fused_decode_attention_ref(q, k_pages, v_pages, block_tables,
                               context_lens, k_tail, v_tail, tail_lens):
    """Oracle for the fused decode-tail kernel: gather pages, then split
    attention. Same signature as ``ops.fused_decode_attention``."""
    k_ctx = gather_kv(k_pages, block_tables)
    v_ctx = gather_kv(v_pages, block_tables)
    return decode_tail_attention_ref(q, k_ctx, v_ctx, context_lens,
                                     k_tail, v_tail, tail_lens)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, q_offset,
                                kv_len):
    """Chunked-prefill attention over a paged KV cache.

    q: (B, C, H, D) — a chunk of C query tokens whose first token sits at
    absolute position ``q_offset``; the chunk's own KV must already be
    written into the pages. Gathers the sequence's pages into a contiguous
    view and runs causal flash-style attention with ``kv_len`` valid
    positions (cached prefix + this chunk). Returns (B, C, H, D).
    """
    from repro.models.layers import chunked_attention

    k = gather_kv(k_pages, block_tables)      # (B, S_ctx, KH, D)
    v = gather_kv(v_pages, block_tables)
    return chunked_attention(q, k, v, causal=True, q_offset=q_offset,
                             kv_len=kv_len)
