"""Pure-jnp oracle for paged decode attention: gather pages into a contiguous
cache, then masked softmax attention for a single query token."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_kv(pages, block_tables):
    """pages: (NP, page, KH, D); block_tables: (B, PPS) -> (B, PPS*page, KH, D)."""
    g = pages[block_tables]                   # (B, PPS, page, KH, D)
    B, PPS, page, KH, D = g.shape
    return g.reshape(B, PPS * page, KH, D)


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """q: (B, H, D); pages: (NP, page, KH, D); returns (B, H, D)."""
    B, H, D = q.shape
    KH = k_pages.shape[2]
    G = H // KH
    k = gather_kv(k_pages, block_tables)      # (B, S, KH, D)
    v = gather_kv(v_pages, block_tables)
    S = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < context_lens[:, None]      # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
