"""Pure-jnp oracle for paged decode attention: gather pages into a contiguous
cache, then masked softmax attention for a single query token. Also hosts the
paged *prefill* read path used by chunked prefill: a multi-token query block
attending over the page pool (cached prefix pages + the chunk's own pages)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_kv(pages, block_tables):
    """pages: (NP, page, KH, D); block_tables: (B, PPS) -> (B, PPS*page, KH, D)."""
    g = pages[block_tables]                   # (B, PPS, page, KH, D)
    B, PPS, page, KH, D = g.shape
    return g.reshape(B, PPS * page, KH, D)


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """q: (B, H, D); pages: (NP, page, KH, D); returns (B, H, D)."""
    B, H, D = q.shape
    KH = k_pages.shape[2]
    G = H // KH
    k = gather_kv(k_pages, block_tables)      # (B, S, KH, D)
    v = gather_kv(v_pages, block_tables)
    S = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] < context_lens[:, None]      # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, q_offset,
                                kv_len):
    """Chunked-prefill attention over a paged KV cache.

    q: (B, C, H, D) — a chunk of C query tokens whose first token sits at
    absolute position ``q_offset``; the chunk's own KV must already be
    written into the pages. Gathers the sequence's pages into a contiguous
    view and runs causal flash-style attention with ``kv_len`` valid
    positions (cached prefix + this chunk). Returns (B, C, H, D).
    """
    from repro.models.layers import chunked_attention

    k = gather_kv(k_pages, block_tables)      # (B, S_ctx, KH, D)
    v = gather_kv(v_pages, block_tables)
    return chunked_attention(q, k, v, causal=True, q_offset=q_offset,
                             kv_len=kv_len)
