"""Public jit'd wrappers for the flash-attention Pallas kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_attention_fwd,
                                                  paged_flash_prefill_fwd)
from repro.kernels.paged_attention.ops import (_default_interpret, _pad_axis,
                                               _sublane)


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "k_block",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_block=256,
                    k_block=512, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0.
    Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    q_block = min(q_block, max(16, Sq))
    k_block = min(k_block, max(16, Sk))

    pq = (-Sq) % q_block
    pk = (-Sk) % k_block
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v

    # (B, S, H, D) -> (B*KH, G, S, D) / (B*KH, S, D)
    qr = qp.reshape(B, Sq + pq, KH, G, D).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B * KH, G, Sq + pq, D)
    kr = kp.transpose(0, 2, 1, 3).reshape(B * KH, Sk + pk, D)
    vr = vp.transpose(0, 2, 1, 3).reshape(B * KH, Sk + pk, D)

    out = flash_attention_fwd(qr, kr, vr, causal=causal, window=window,
                              q_block=q_block, k_block=k_block, seq_k=Sk,
                              interpret=interpret)
    out = out.reshape(B, KH, G, Sq + pq, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq + pq, H, D)[:, :Sq]


@partial(jax.jit, static_argnames=("group", "q_block", "interpret"))
def _paged_prefill_rows(qr, k_pages, v_pages, block_tables, kv_lens,
                        q_starts, *, group, q_block, interpret):
    qp = _pad_axis(qr, 2, q_block)
    out = paged_flash_prefill_fwd(qp, k_pages, v_pages,
                                  block_tables.astype(jnp.int32),
                                  kv_lens.astype(jnp.int32),
                                  q_starts.astype(jnp.int32),
                                  group=group, q_block=q_block,
                                  interpret=interpret)
    return out[:, :, :qr.shape[2]]


def paged_flash_prefill(q, k_pages, v_pages, block_tables, q_offset, kv_len,
                        *, interpret=None):
    """Chunked-prefill flash attention reading the paged pool directly.

    Drop-in for ``paged_prefill_attention_ref``: q is (B, C, H, D), a chunk
    whose first token sits at absolute position ``q_offset`` and whose own
    KV is already written into the pages; ``kv_len`` counts the valid
    positions (cached prefix + this chunk). No (B, S, KH, D) gather is
    materialized — the kernel streams pages straight from the pool.
    ``q_offset`` / ``kv_len`` may be scalars or (B,). Returns (B, C, H, D).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, C, H, D = q.shape
    KH = k_pages.shape[2]
    assert H % KH == 0, \
        f"query heads ({H}) must be a multiple of kv heads ({KH})"
    G = H // KH
    # fold (token, group) into query rows: r = c * G + g
    qr = q.reshape(B, C, KH, G, D).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(B, KH, C * G, D)
    sub = _sublane(q.dtype)
    q_block = min(128, -(-C * G // sub) * sub)
    starts = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    out = _paged_prefill_rows(qr, k_pages, v_pages, block_tables, lens,
                              starts, group=G, q_block=q_block,
                              interpret=interpret)
    out = out.reshape(B, KH, C, G, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, C, H, D)
