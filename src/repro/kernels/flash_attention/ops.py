"""Public jit'd wrapper for the flash-attention Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "k_block",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_block=256,
                    k_block=512, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0.
    Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    q_block = min(q_block, max(16, Sq))
    k_block = min(k_block, max(16, Sk))

    pq = (-Sq) % q_block
    pk = (-Sk) % k_block
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v

    # (B, S, H, D) -> (B*KH, G, S, D) / (B*KH, S, D)
    qr = qp.reshape(B, Sq + pq, KH, G, D).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B * KH, G, Sq + pq, D)
    kr = kp.transpose(0, 2, 1, 3).reshape(B * KH, Sk + pk, D)
    vr = vp.transpose(0, 2, 1, 3).reshape(B * KH, Sk + pk, D)

    out = flash_attention_fwd(qr, kr, vr, causal=causal, window=window,
                              q_block=q_block, k_block=k_block, seq_k=Sk,
                              interpret=interpret)
    out = out.reshape(B, KH, G, Sq + pq, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq + pq, H, D)[:, :Sq]
