"""Pure-jnp oracle for flash attention (full softmax, GQA, causal/window)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, kv_len=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KH, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, Sq, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # align ends (decode-style)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    if kv_len is not None:
        mask = mask & (kpos < kv_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)
