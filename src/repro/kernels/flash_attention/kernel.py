"""FlashAttention forward Pallas TPU kernel (prefill / training attention).

Grid: (B*KH, G, num_q_blocks, num_k_blocks), k innermost so the online-softmax
accumulators (m, l, acc) persist in VMEM scratch across k-blocks. Fully-masked
causal blocks skip compute via ``pl.when``. Tiles are MXU-aligned (block sizes
are multiples of 128 on the contracting/lane dims for the TPU target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, q_block, k_block, num_k_blocks,
                  seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block
    k_start = ki * k_block
    # skip fully-masked blocks (strictly above the causal diagonal)
    live = jnp.bool_(True)
    if causal:
        live = k_start <= q_start + q_block - 1
    if window:
        live = jnp.logical_and(live, q_start - (k_start + k_block - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (qb, D)
        k = k_ref[0].astype(jnp.float32)                     # (kb, D)
        v = v_ref[0].astype(jnp.float32)                     # (kb, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                                  # (qb, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_prefill_kernel(tables_ref, lens_ref, starts_ref, q_ref, k_ref,
                          v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
                          page_size, num_pages, q_block, group):
    """Causal flash attention whose KV stream is a paged pool.

    Query rows fold (chunk position, GQA group) as ``r = c * G + g`` so one
    q tile serves all G heads of each token; the row's absolute position is
    ``starts[b] + r // G``. The page axis is innermost: the block table in
    scalar prefetch drives the page DMA (as in the decode kernel) and the
    online-softmax accumulators carry across pages.
    """
    b = pl.program_id(0)
    qi = pl.program_id(2)
    pi = pl.program_id(3)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = lens_ref[b]
    start = starts_ref[b]
    page_start = pi * page_size
    row0 = qi * q_block
    # causal skip: the page is dead if it starts past this tile's last
    # query position (and past the valid kv prefix)
    max_qpos = start + (row0 + q_block - 1) // group
    live = jnp.logical_and(page_start < kv_len, page_start <= max_qpos)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (qb, D)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, page_size), 0)
        qpos = start + rows // group
        kpos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, page_size), 1)
        mask = jnp.logical_and(kpos < kv_len, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(pi == num_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_flash_prefill_fwd(q, k_pages, v_pages, block_tables, kv_lens,
                            q_starts, *, group, q_block, interpret=False):
    """q: (B, KH, R, D) with R = C * G query rows (row ``c*G+g`` is head
    group ``g`` of chunk token ``c``), padded to a q_block multiple;
    k_pages / v_pages: (NP, page, KH, D); block_tables: (B, PPS);
    kv_lens / q_starts: (B,) int32. Returns (B, KH, R, D)."""
    B, KH, R, D = q.shape
    NP, page, _, _ = k_pages.shape
    PPS = block_tables.shape[1]
    assert R % q_block == 0
    nq = R // q_block
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                               page_size=page, num_pages=PPS,
                               q_block=q_block, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KH, nq, PPS),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, D),
                         lambda b, h, qi, pi, t, kl, qs: (b, h, qi, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, qi, pi, t, kl, qs: (t[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, qi, pi, t, kl, qs: (t[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, D),
                               lambda b, h, qi, pi, t, kl, qs: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, D), jnp.float32),
        ],
    )
    params_cls = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
    kw = {}
    if params_cls is not None and not interpret:
        kw["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, R, D), q.dtype),
        interpret=interpret,
        **kw,
    )(block_tables, kv_lens, q_starts, q, k_pages, v_pages)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, q_block=256,
                        k_block=512, seq_k=None, interpret=False):
    """q: (BKH, G, Sq, D); k, v: (BKH, Sk, D). Returns (BKH, G, Sq, D).

    Sq / Sk must already be padded to block multiples; ``seq_k`` is the true
    (unpadded) kv length used for masking.
    """
    BKH, G, Sq, D = q.shape
    _, Sk, _ = k.shape
    seq_k = seq_k or Sk
    assert Sq % q_block == 0 and Sk % k_block == 0
    nq, nk = Sq // q_block, Sk // k_block
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, k_block=k_block, num_k_blocks=nk, seq_k=seq_k)

    grid = (BKH, G, nq, nk)
    # renamed across jax releases: CompilerParams <-> TPUCompilerParams
    params_cls = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
    try:
        compiler_params = params_cls(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except TypeError:  # older naming
        compiler_params = None

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, D), lambda b, g, qi, ki: (b, g, qi, 0)),
            pl.BlockSpec((1, k_block, D), lambda b, g, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, k_block, D), lambda b, g, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, D),
                               lambda b, g, qi, ki: (b, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BKH, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, D), jnp.float32),
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(q, k, v)
