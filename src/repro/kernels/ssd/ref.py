"""Oracle for the SSD kernel: the chunked-einsum formulation from
``repro.models.mamba2`` (itself validated against the step recurrence)."""
from repro.models.mamba2 import segsum, ssd_chunked, ssd_decode_step

__all__ = ["segsum", "ssd_chunked", "ssd_decode_step"]
