"""Public jit'd wrapper for the SSD Pallas kernel (model-layout shapes)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_fwd


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, a, B, C, *, chunk=256, interpret=False):
    """Same contract as models.mamba2.ssd_chunked (h0=0):
    x: (b, s, h, p); a: (b, s, h); B, C: (b, s, n) shared across heads.
    Returns (y (b, s, h, p), final_state (b, h, p, n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    pad = (-s) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = s + pad

    xr = x.transpose(0, 2, 1, 3).reshape(b * h, S, p)
    ar = a.transpose(0, 2, 1).reshape(b * h, S, 1)
    Br = jnp.broadcast_to(B[:, None], (b, h, S, n)).reshape(b * h, S, n)
    Cr = jnp.broadcast_to(C[:, None], (b, h, S, n)).reshape(b * h, S, n)

    y, st = ssd_fwd(xr, ar, Br, Cr, chunk=Q, interpret=interpret)
    y = y.reshape(b, h, S, p).transpose(0, 2, 1, 3)[:, :s]
    return y.astype(x.dtype), st.reshape(b, h, p, n)
