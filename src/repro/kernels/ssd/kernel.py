"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid: (B*H, num_chunks) — chunks innermost so the inter-chunk SSM state
(P, N) persists in VMEM scratch. Each grid step computes the intra-chunk
quadratic term ((C B^T) ⊙ decay) @ x plus the inter-chunk contribution from
the carried state, then advances the state. Chunk size Q is a multiple of 128
so the (Q, Q) and (Q, N) tiles are MXU-aligned on the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr, *,
                chunk, num_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    a = a_ref[0].astype(jnp.float32)          # (Q, 1)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)

    a_cs = jnp.cumsum(a[:, 0])                # (Q,)
    # intra-chunk decay: L[i,j] = exp(a_cs[i]-a_cs[j]) for i>=j else 0
    seg = a_cs[:, None] - a_cs[None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(i >= j, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    y = jax.lax.dot_general(scores * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,P)

    # inter-chunk contribution from carried state: exp(a_cs) * C @ state^T
    state = state_scr[...]                    # (P, N)
    y_off = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (Q,P)
    y = y + y_off * jnp.exp(a_cs)[:, None]
    # every grid step is a live chunk (S % chunk == 0): the per-chunk output
    # store and state advance are unconditional by design, not dead steps
    y_ref[0] = y.astype(y_ref.dtype)  # firstlint: disable=pallas-kernel-safety -- grid has no dead steps; each ci writes its own block

    # state update: state' = exp(a_sum)*state + (x * exp(a_sum - a_cs))^T @ B
    a_sum = a_cs[-1]
    decay_in = jnp.exp(a_sum - a_cs)          # (Q,)
    xw = x * decay_in[:, None]                # (Q, P)
    upd = jax.lax.dot_general(xw, B, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (P, N)
    state_scr[...] = state * jnp.exp(a_sum) + upd  # firstlint: disable=pallas-kernel-safety -- carried SSM state must advance on every chunk

    @pl.when(ci == num_chunks - 1)
    def _final():
        st_ref[0] = state_scr[...]


def ssd_fwd(x, a, B, C, *, chunk, interpret=False):
    """x: (BH, S, P); a: (BH, S, 1); B, C: (BH, S, N). S % chunk == 0.
    Returns (y (BH, S, P), final_state (BH, P, N))."""
    BH, S, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    # renamed across jax releases: CompilerParams <-> TPUCompilerParams
    params_cls = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
    try:
        compiler_params = params_cls(
            dimension_semantics=("parallel", "arbitrary"))
    except TypeError:
        compiler_params = None

    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, P, N), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, a, B, C)
