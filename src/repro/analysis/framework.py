"""firstlint core: rule protocol, suppressions, file walking, reporting.

A :class:`Rule` inspects one parsed module (:class:`ModuleInfo`) and yields
:class:`Finding` objects. The framework owns everything around that:
discovering files, parsing, matching ``# firstlint: disable=...`` comments,
and rendering text/JSON reports. Rules never filter suppressions
themselves — they report every violation and the framework drops the
suppressed ones (counting them, so reports can say what was waived).

Suppression syntax (one rule name, a comma list, or ``all``)::

    bad_call()          # firstlint: disable=<rule>[,<rule>...] -- <reason>
    # firstlint: disable-next-line=<rule> -- <reason>
    # firstlint: disable-file=<rule> -- <reason>        (anywhere in file)

The ``-- reason`` tail is free text; reviews should treat a reasonless
suppression the way they treat a bare ``type: ignore``.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator

SUPPRESS_RE = re.compile(
    r"#\s*firstlint:\s*(disable|disable-next-line|disable-file)"
    r"\s*=\s*([A-Za-z0-9_\-, ]+?)\s*(?:--\s*(?P<reason>.*))?$")

DEFAULT_EXCLUDE_PARTS = ("fixtures", "__pycache__", ".git")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"

    @property
    def sort_key(self):
        return (self.path, self.line, self.col, self.rule)


class ModuleInfo:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self.lines = source.splitlines()
        # line -> set of rule names (or {"all"}) waived on that line
        self._line_waivers: dict[int, set[str]] = {}
        self._file_waivers: set[str] = set()
        self._parse_suppressions()

    def _parse_suppressions(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            if "firstlint" not in line:
                continue
            m = SUPPRESS_RE.search(line)
            if m is None:
                continue
            kind = m.group(1)
            rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
            if kind == "disable-file":
                self._file_waivers |= rules
            elif kind == "disable-next-line":
                self._line_waivers.setdefault(i + 1, set()).update(rules)
            else:
                self._line_waivers.setdefault(i, set()).update(rules)

    def suppressed(self, finding: Finding) -> bool:
        if {"all", finding.rule} & self._file_waivers:
            return True
        waived = self._line_waivers.get(finding.line, set())
        return bool({"all", finding.rule} & waived)


class Rule:
    """Base class: subclasses set ``name``/``description`` and implement
    :meth:`check`."""
    name = "abstract"
    description = ""

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod: ModuleInfo, node: ast.AST, message: str) -> Finding:
        return Finding(rule=self.name, path=mod.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


@dataclass
class Report:
    """Outcome of one analysis run (post-suppression)."""
    findings: list[Finding] = field(default_factory=list)
    suppressed: int = 0
    files_checked: int = 0
    errors: list[Finding] = field(default_factory=list)

    @property
    def all_findings(self) -> list[Finding]:
        out = sorted(self.findings + self.errors, key=lambda f: f.sort_key)
        return out

    def to_dict(self) -> dict:
        counts: dict[str, int] = {}
        for f in self.all_findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "version": 1,
            "tool": "firstlint",
            "files_checked": self.files_checked,
            "suppressed": self.suppressed,
            "counts": counts,
            "findings": [f.to_dict() for f in self.all_findings],
        }

    def render_text(self) -> str:
        lines = [f.render() for f in self.all_findings]
        n = len(lines)
        lines.append(f"firstlint: {self.files_checked} files, "
                     f"{n} finding{'s' if n != 1 else ''}, "
                     f"{self.suppressed} suppressed")
        return "\n".join(lines)


def analyze_source(source: str, path: str,
                   rules: Iterable[Rule]) -> tuple[list[Finding], int]:
    """Run ``rules`` over one source string. Returns (findings kept,
    findings suppressed). A syntax error yields a single ``parse-error``
    finding (unsuppressable — a file that does not parse checks nothing).
    """
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=path, line=e.lineno or 1,
                        col=e.offset or 0,
                        message=f"could not parse: {e.msg}")], 0
    mod = ModuleInfo(path, source, tree)
    kept: list[Finding] = []
    waived = 0
    for rule in rules:
        for f in rule.check(mod):
            if mod.suppressed(f):
                waived += 1
            else:
                kept.append(f)
    return kept, waived


def iter_python_files(paths: Iterable[str],
                      exclude_parts: tuple[str, ...] = DEFAULT_EXCLUDE_PARTS
                      ) -> Iterator[Path]:
    seen = set()
    for raw in paths:
        p = Path(raw)
        candidates = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in candidates:
            if f.suffix != ".py" or f in seen:
                continue
            # explicit file arguments bypass the exclude list (tests point
            # the analyzer straight at fixture snippets); directory walks
            # skip fixture/cache trees
            if p.is_dir() and set(f.parts) & set(exclude_parts):
                continue
            seen.add(f)
            yield f


def analyze_paths(paths: Iterable[str], rules: Iterable[Rule],
                  exclude_parts: tuple[str, ...] = DEFAULT_EXCLUDE_PARTS
                  ) -> Report:
    """Analyze every ``*.py`` under ``paths`` (files or directories)."""
    rules = list(rules)
    report = Report()
    for f in iter_python_files(paths, exclude_parts):
        try:
            source = f.read_text(encoding="utf-8")
        except OSError as e:
            report.errors.append(Finding(
                rule="io-error", path=str(f), line=1, col=0,
                message=f"could not read: {e}"))
            continue
        report.files_checked += 1
        kept, waived = analyze_source(source, str(f), rules)
        report.suppressed += waived
        for finding in kept:
            (report.errors if finding.rule == "parse-error"
             else report.findings).append(finding)
    return report


def render(report: Report, fmt: str) -> str:
    if fmt == "json":
        return json.dumps(report.to_dict(), indent=2, sort_keys=True)
    return report.render_text()
