"""Command-line entry point: ``python -m repro.analysis [paths...]``.

Exit status: 0 when clean, 1 when there are unsuppressed findings (or
unparsable files), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

from repro.analysis.framework import analyze_paths, render
from repro.analysis.rules import RULES_BY_NAME, get_rules

DEFAULT_PATHS = ("src", "tests", "benchmarks", "scripts", "examples")


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="firstlint: AST invariant checker for the FIRST "
                    "serving stack (hot-path syncs, cache invalidation, "
                    "Pallas kernel safety, donation, wire schemas)")
    parser.add_argument(
        "paths", nargs="*",
        help="files or directories to check (default: "
             f"{' '.join(DEFAULT_PATHS)}, skipping ones that don't exist)")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (default: text)")
    parser.add_argument(
        "--rules", metavar="NAME[,NAME...]",
        help="comma-separated subset of rules to run")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the available rules and exit")
    return parser


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for name, cls in sorted(RULES_BY_NAME.items()):
            print(f"{name}: {cls.description}")
        return 0
    try:
        rules = get_rules(
            [n.strip() for n in args.rules.split(",") if n.strip()]
            if args.rules else None)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2
    paths = args.paths or [p for p in DEFAULT_PATHS]
    report = analyze_paths(paths, rules)
    if report.files_checked == 0 and not report.findings:
        print("firstlint: no python files found under "
              f"{' '.join(paths)}", file=sys.stderr)
    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        print(render(report, "text"))
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
