"""Shared AST helpers for firstlint rules.

Rules resolve names *canonically* (``np.asarray`` -> ``numpy.asarray``,
``jit`` imported from jax -> ``jax.jit``) via :class:`ImportMap`, and the
two hot-path rules share :class:`JitRegistry` — the per-module inventory
of which local functions are jitted (and with which ``donate_argnums``),
whether via decorator, ``jax.jit(f, ...)`` assignment, or a
``partial(...)`` wrapper.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Iterator


def dotted(node: ast.AST) -> str | None:
    """Render a Name/Attribute chain as ``a.b.c`` (None for anything else)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ImportMap:
    """alias -> canonical dotted module/object path for one module."""

    def __init__(self, tree: ast.Module):
        self.aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def resolve(self, name: str | None) -> str | None:
        """Canonicalize a dotted name through the module's import aliases."""
        if name is None:
            return None
        head, _, rest = name.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{rest}" if rest else base


def resolves_to(imports: ImportMap, node: ast.AST, *targets: str) -> bool:
    got = imports.resolve(dotted(node))
    return got is not None and got in targets


def is_self_attr(node: ast.AST, name: str | None = None) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self"
            and (name is None or node.attr == name))


def call_key(func: ast.AST) -> str | None:
    """Bare key a call target is registered under: ``f(...)`` -> "f",
    ``self.f(...)`` / ``self.f[k](...)`` -> "f". None when unresolvable."""
    if isinstance(func, ast.Subscript):
        func = func.value
    if isinstance(func, ast.Name):
        return func.id
    if is_self_attr(func):
        return func.attr
    return None


def literal_argnums(node: ast.AST | None) -> frozenset[int] | None:
    """Evaluate a ``donate_argnums``-style literal; None if not static."""
    if node is None:
        return frozenset()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return frozenset({node.value})
    if isinstance(node, (ast.Tuple, ast.List)):
        out = set()
        for el in node.elts:
            if not (isinstance(el, ast.Constant)
                    and isinstance(el.value, int)):
                return None
            out.add(el.value)
        return frozenset(out)
    return None


@dataclass
class JitTarget:
    """One jitted callable registered in a module."""
    key: str                     # name it is callable under ("_fused", "fn")
    func_name: str | None        # local function the jit wraps (if resolved)
    lambda_node: ast.Lambda | None
    donated: frozenset[int] | None   # None = donates, positions unknown
    node: ast.AST                # registration site (for diagnostics)


def _unwrap_partial(imports: ImportMap, node: ast.AST) -> ast.AST:
    """partial(f, ...) / functools.partial(f, ...) -> f (recursively)."""
    while (isinstance(node, ast.Call)
           and resolves_to(imports, node.func, "functools.partial")
           and node.args):
        node = node.args[0]
    return node


def _jit_call_parts(imports: ImportMap, call: ast.Call):
    """For a ``jax.jit(target, ...)`` call, return (target_expr, donated)."""
    if not resolves_to(imports, call.func, "jax.jit"):
        return None
    donate = None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            donate = kw.value
    donated = literal_argnums(donate)
    target = _unwrap_partial(imports, call.args[0]) if call.args else None
    return target, donated


class JitRegistry:
    """Per-module inventory of jitted callables.

    ``targets``: every registration found.  ``by_key``: callable key ->
    list of registrations (a dict-of-jits like ``self._fused[K]`` collects
    one per branch).  ``root_funcs``: names of local functions whose bodies
    execute under jit (the seed set for hot-path reachability).
    ``root_lambdas``: jitted inline lambdas.
    """

    def __init__(self, tree: ast.Module, imports: ImportMap):
        self.targets: list[JitTarget] = []
        self.by_key: dict[str, list[JitTarget]] = {}
        self.root_funcs: set[str] = set()
        self.root_lambdas: list[ast.Lambda] = []
        self._collect(tree, imports)

    def _add(self, t: JitTarget) -> None:
        self.targets.append(t)
        self.by_key.setdefault(t.key, []).append(t)
        if t.func_name:
            self.root_funcs.add(t.func_name)
        if t.lambda_node is not None:
            self.root_lambdas.append(t.lambda_node)

    def _collect(self, tree: ast.Module, imports: ImportMap) -> None:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    donated: frozenset[int] | None = frozenset()
                    if resolves_to(imports, dec, "jax.jit"):
                        pass
                    elif isinstance(dec, ast.Call):
                        if resolves_to(imports, dec.func, "jax.jit"):
                            donated = literal_argnums(next(
                                (kw.value for kw in dec.keywords
                                 if kw.arg == "donate_argnums"), None))
                        elif (resolves_to(imports, dec.func,
                                          "functools.partial")
                              and dec.args
                              and resolves_to(imports, dec.args[0],
                                              "jax.jit")):
                            donated = literal_argnums(next(
                                (kw.value for kw in dec.keywords
                                 if kw.arg == "donate_argnums"), None))
                        else:
                            continue
                    else:
                        continue
                    self._add(JitTarget(key=node.name, func_name=node.name,
                                        lambda_node=None, donated=donated,
                                        node=node))
                    break
            elif isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                parts = _jit_call_parts(imports, node.value)
                if parts is None:
                    continue
                target, donated = parts
                for tgt in node.targets:
                    key = call_key(tgt)
                    if key is None:
                        continue
                    fn, lam = None, None
                    if isinstance(target, ast.Lambda):
                        lam = target
                    else:
                        fn = call_key(target) if not isinstance(
                            target, ast.Call) else None
                    self._add(JitTarget(key=key, func_name=fn,
                                        lambda_node=lam, donated=donated,
                                        node=node))

    def donated_at(self, key: str) -> frozenset[int] | None:
        """Argument positions donated for calls through ``key``.

        When several registrations share a key (per-K jit dicts), only the
        positions donated under EVERY registration are reported — a
        position donated on one branch but live on another cannot be
        checked statically without knowing which branch the call hits.
        Returns None when the key is unregistered or any registration has
        non-literal donate_argnums.
        """
        regs = self.by_key.get(key)
        if not regs:
            return None
        out: frozenset[int] | None = None
        for r in regs:
            if r.donated is None:
                return None
            out = r.donated if out is None else (out & r.donated)
        return out


def collect_functions(tree: ast.Module) -> dict[str, list[ast.FunctionDef]]:
    """Every (possibly nested) function/method in the module, by bare name."""
    out: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(node)
    return out


def called_keys(fn: ast.AST) -> Iterator[str]:
    """Bare keys of every call inside ``fn`` (names and self.X methods)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            key = call_key(node.func)
            if key is not None:
                yield key


@dataclass
class HotSet:
    """Transitive closure of functions reachable from the module's jit
    roots through same-module calls (by bare name — conservative, but
    cross-module calls are out of scope for a per-module pass)."""
    funcs: dict[str, list[ast.FunctionDef]] = field(default_factory=dict)
    lambdas: list[ast.Lambda] = field(default_factory=list)

    def subtrees(self) -> Iterator[tuple[str, ast.AST]]:
        for name, defs in self.funcs.items():
            for d in defs:
                yield name, d
        for lam in self.lambdas:
            yield "<lambda>", lam


def hot_set(tree: ast.Module, imports: ImportMap,
            registry: JitRegistry | None = None) -> HotSet:
    registry = registry or JitRegistry(tree, imports)
    table = collect_functions(tree)
    hot = HotSet(lambdas=list(registry.root_lambdas))
    frontier = [n for n in registry.root_funcs if n in table]
    for lam in registry.root_lambdas:
        frontier.extend(k for k in called_keys(lam) if k in table)
    while frontier:
        name = frontier.pop()
        if name in hot.funcs:
            continue
        hot.funcs[name] = table[name]
        for d in table[name]:
            for key in called_keys(d):
                if key in table and key not in hot.funcs:
                    frontier.append(key)
    return hot
