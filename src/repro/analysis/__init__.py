"""firstlint — AST-based invariant checker for the serving stack.

The engine's hot paths rest on contracts that are cheap to break and
expensive to debug at runtime: the zero-logits-transfer rule on the fused
decode path, the XLA twin's cached context view that must be invalidated
at every ``PagedKVCache``/pool mutation site, Pallas kernel bodies that
silently miscompile when branched on tracers or left unguarded on dead
grid steps, buffer donation, and the typed /v1 wire envelope. ``firstlint``
walks the repo's ASTs with a shared visitor framework and enforces those
contracts at review time — the static complement of what the parity
matrix and ``TRANSFER_STATS`` only catch dynamically.

Usage::

    python -m repro.analysis src tests [--format=json]

Findings are suppressed inline with a reason::

    np.asarray(x)  # firstlint: disable=host-sync-in-hot-path -- host wrapper

See docs/ANALYSIS.md for the rule catalogue.
"""
from repro.analysis.framework import (Finding, ModuleInfo, Rule,
                                      analyze_paths, analyze_source)
from repro.analysis.rules import ALL_RULES, get_rules

__all__ = ["Finding", "ModuleInfo", "Rule", "ALL_RULES", "get_rules",
           "analyze_paths", "analyze_source"]
