"""host-sync-in-hot-path: no device->host transfers inside jitted code.

The fused decode path's contract is that logits (and everything else big)
never cross to the host mid-loop — ``TRANSFER_STATS`` asserts it at run
time for one path; this rule enforces the whole class statically. Any
function whose body executes under ``jax.jit`` (decorated, registered via
``jax.jit(f, ...)`` / ``partial`` wrappers, or reachable from one through
same-module calls) must not:

* call ``.item()`` or ``.block_until_ready()`` on anything,
* call ``numpy.asarray`` / ``numpy.array`` / ``jax.device_get`` (tracer
  -> host copy, or a silent constant-fold + transfer at trace time),
* coerce a traced value with ``int(...)`` / ``float(...)`` (flagged for
  bare-name / simple-subscript arguments; shape arithmetic on constants
  is fine and not matched).

Inside jit these either crash at trace time in the best case or, worse,
silently pin a once-per-call sync on the hot path when jax manages to
constant-fold them. Host wrappers (``fused_decode`` itself, the legacy
``decode_batch`` sync points) are outside the hot set and stay free to
sync — that is where the intended O(max_slots) payload crosses.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import ImportMap, dotted, hot_set
from repro.analysis.framework import Finding, ModuleInfo, Rule

_HOST_CALLS = {
    "numpy.asarray": "numpy.asarray materializes the value on the host",
    "numpy.array": "numpy.array materializes the value on the host",
    "numpy.ascontiguousarray":
        "numpy.ascontiguousarray materializes the value on the host",
    "jax.device_get": "jax.device_get is an explicit device->host transfer",
}

_HOST_METHODS = {
    "item": ".item() synchronizes and copies to the host",
    "block_until_ready": ".block_until_ready() stalls the dispatch queue",
    "tolist": ".tolist() synchronizes and copies to the host",
}


def _is_simple_coercion_arg(node: ast.AST) -> bool:
    """int(x) / float(x[i]) style args that plausibly coerce a tracer."""
    if isinstance(node, ast.Name):
        return True
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        return True
    return False


class HostSyncRule(Rule):
    name = "host-sync-in-hot-path"
    description = ("no .item()/np.asarray/device_get/int()/float() host "
                   "syncs inside jit-reachable code")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        imports = ImportMap(mod.tree)
        hot = hot_set(mod.tree, imports)
        for fname, subtree in hot.subtrees():
            yield from self._check_subtree(mod, imports, fname, subtree)

    def _check_subtree(self, mod: ModuleInfo, imports: ImportMap,
                       fname: str, subtree: ast.AST) -> Iterator[Finding]:
        where = f"jit-reachable function '{fname}'"
        for node in ast.walk(subtree):
            if not isinstance(node, ast.Call):
                continue
            callee = imports.resolve(dotted(node.func))
            if callee in _HOST_CALLS:
                yield self.finding(
                    mod, node, f"{_HOST_CALLS[callee]} — forbidden in "
                    f"{where}")
                continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _HOST_METHODS:
                yield self.finding(
                    mod, node, f"{_HOST_METHODS[node.func.attr]} — "
                    f"forbidden in {where}")
                continue
            if callee in ("int", "float") and len(node.args) == 1 \
                    and not node.keywords \
                    and _is_simple_coercion_arg(node.args[0]):
                yield self.finding(
                    mod, node, f"{callee}() on a traced value forces a "
                    f"host sync — forbidden in {where}")
