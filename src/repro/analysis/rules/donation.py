"""donation-safety: never read a buffer after donating it.

The serving backends donate their big buffers (KV pools, slot caches,
decode state) into every jitted step so XLA reuses the memory in place.
On TPU a donated buffer is *gone* after the call — reading it afterwards
returns garbage or raises, and on CPU (where donation is silently
ignored) the bug hides until the code first runs on real hardware.

The rule collects every ``jax.jit(..., donate_argnums=...)`` registration
(decorator, plain assignment, per-shape jit dicts like
``self._fused[K] = jax.jit(...)``) scoped to its class, then checks each
call site: an argument in a donated position that is a plain variable or
``self.`` attribute must be rebound by the call statement itself (the
``x, self.pools = f(self.params, self.pools, ...)`` idiom) — otherwise
any later read of it in the same function is flagged.

When one key holds several registrations (per-K dicts), only positions
donated under every registration are enforced; non-literal
``donate_argnums`` disables checking for that key (nothing provable).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import (ImportMap, call_key, is_self_attr,
                                    literal_argnums, resolves_to)
from repro.analysis.framework import Finding, ModuleInfo, Rule

# identity of a donated operand: ("name", x) for locals, ("self", x) for
# instance attributes
Ident = tuple[str, str]


def _ident(node: ast.AST) -> Ident | None:
    if isinstance(node, ast.Name):
        return ("name", node.id)
    if is_self_attr(node):
        return ("self", node.attr)
    return None


def _unwrap_partial(imports: ImportMap, node: ast.AST) -> ast.AST:
    while (isinstance(node, ast.Call)
           and resolves_to(imports, node.func, "functools.partial")
           and node.args):
        node = node.args[0]
    return node


def _donate_kw(call: ast.Call) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return kw.value
    return None


class _Registry:
    """(class name | None, callable key) -> donated positions."""

    def __init__(self) -> None:
        self._regs: dict[tuple[str | None, str], list] = {}

    def add(self, cls: str | None, key: str,
            donated: frozenset[int] | None) -> None:
        self._regs.setdefault((cls, key), []).append(donated)

    def donated(self, cls: str | None, key: str) -> frozenset[int]:
        regs = self._regs.get((cls, key)) or self._regs.get((None, key))
        if not regs:
            return frozenset()
        out: frozenset[int] | None = None
        for d in regs:
            if d is None:            # non-literal donate_argnums: unprovable
                return frozenset()
            out = d if out is None else (out & d)
        return out or frozenset()


def _collect_registry(tree: ast.Module, imports: ImportMap) -> _Registry:
    reg = _Registry()

    def visit(node: ast.AST, cls: str | None) -> None:
        for child in ast.iter_child_nodes(node):
            child_cls = child.name if isinstance(child, ast.ClassDef) else cls
            if isinstance(child, ast.Assign) \
                    and isinstance(child.value, ast.Call) \
                    and resolves_to(imports, child.value.func, "jax.jit"):
                donated = literal_argnums(_donate_kw(child.value))
                if donated:                       # frozenset() -> no donation
                    for tgt in child.targets:
                        key = call_key(tgt)
                        if key is not None:
                            reg.add(child_cls, key, donated)
                elif donated is None:
                    for tgt in child.targets:
                        key = call_key(tgt)
                        if key is not None:
                            reg.add(child_cls, key, None)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in child.decorator_list:
                    dec = _unwrap_partial(imports, dec) if not isinstance(
                        dec, ast.Call) else dec
                    if isinstance(dec, ast.Call) and (
                            resolves_to(imports, dec.func, "jax.jit")
                            or (resolves_to(imports, dec.func,
                                            "functools.partial") and dec.args
                                and resolves_to(imports, dec.args[0],
                                                "jax.jit"))):
                        donated = literal_argnums(_donate_kw(dec))
                        if donated or donated is None:
                            reg.add(child_cls, child.name, donated)
            visit(child, child_cls)

    visit(tree, None)
    return reg


# simple (non-compound) statements: the unit a donating call belongs to
_SIMPLE_STMTS = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Expr,
                 ast.Return, ast.Raise, ast.Assert, ast.Delete)


def _simple_statements(fn: ast.FunctionDef):
    for node in ast.walk(fn):
        if isinstance(node, _SIMPLE_STMTS):
            yield node


def _stmt_rebinds(stmt: ast.stmt) -> set[Ident]:
    """Identities (re)bound by a statement's assignment targets."""
    out: set[Ident] = set()
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    while targets:
        t = targets.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            targets.extend(t.elts)
        elif isinstance(t, ast.Starred):
            targets.append(t.value)
        else:
            ident = _ident(t)
            if ident is not None:
                out.add(ident)
    return out


class DonationSafetyRule(Rule):
    name = "donation-safety"
    description = ("no reads of a buffer after it was passed through a "
                   "donate_argnums call in the same scope")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        imports = ImportMap(mod.tree)
        registry = _collect_registry(mod.tree, imports)

        def visit(node: ast.AST, cls: str | None) -> Iterator[Finding]:
            for child in ast.iter_child_nodes(node):
                child_cls = child.name if isinstance(child, ast.ClassDef) \
                    else cls
                if isinstance(child,
                              (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from self._check_function(mod, registry,
                                                    child_cls, child)
                yield from visit(child, child_cls)

        yield from visit(mod.tree, None)

    def _check_function(self, mod: ModuleInfo, registry: _Registry,
                        cls: str | None,
                        fn: ast.FunctionDef) -> Iterator[Finding]:
        # map each call node to its enclosing simple statement
        stmt_of: dict[int, ast.stmt] = {}
        for stmt in _simple_statements(fn):
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Call):
                    stmt_of[id(sub)] = stmt
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            key = call_key(call.func)
            if key is None:
                continue
            donated = registry.donated(cls, key)
            if not donated:
                continue
            stmt = stmt_of.get(id(call))
            if stmt is None:
                continue
            rebound = _stmt_rebinds(stmt)
            end = getattr(stmt, "end_lineno", stmt.lineno)
            for pos in sorted(donated):
                if pos >= len(call.args):
                    continue
                ident = _ident(call.args[pos])
                if ident is None or ident in rebound:
                    continue
                read = self._first_read_after(fn, ident, end)
                if read is not None:
                    label = ident[1] if ident[0] == "name" \
                        else f"self.{ident[1]}"
                    yield self.finding(
                        mod, read,
                        f"'{label}' is read after being donated to "
                        f"'{key}' (donate_argnums position {pos}, line "
                        f"{stmt.lineno}) — donated buffers are dead after "
                        "the call; rebind the result or copy first")

    @staticmethod
    def _first_read_after(fn: ast.FunctionDef, ident: Ident,
                          after_line: int) -> ast.AST | None:
        """First load of ``ident`` past ``after_line`` that is not preceded
        by a rebinding store (linear source order — loops are approximated,
        which is the conservative direction for straight-line jit glue)."""
        events: list[tuple[int, int, str, ast.AST]] = []
        for node in ast.walk(fn):
            found = None
            if ident[0] == "name" and isinstance(node, ast.Name) \
                    and node.id == ident[1]:
                found = node
            elif ident[0] == "self" and is_self_attr(node, ident[1]):
                found = node
            if found is None:
                continue
            ctx = getattr(found, "ctx", None)
            kind = "store" if isinstance(ctx, (ast.Store, ast.Del)) \
                else "load"
            events.append((found.lineno, found.col_offset, kind, found))
        for line, _, kind, node in sorted(events, key=lambda e: (e[0], e[1])):
            if line <= after_line:
                continue
            if kind == "store":
                return None
            return node
        return None
