"""wire-schema: no ad-hoc wire envelopes outside api/schemas.py.

Everything that crosses a federation link is versioned: ``to_wire`` wraps
each dataclass as ``{"v": API_VERSION, "kind": ..., "data": ...}`` and
``from_wire`` refuses envelopes from the future. A hand-built dict that
mimics the envelope bypasses that versioning — it keeps working until the
schema evolves, then breaks only against mixed-version peers, the
hardest environment to reproduce.

The rule flags dict literals that look like wire envelopes outside
``api/schemas.py`` itself:

* a ``"v"`` key whose value is a string literal (``{"v": "v1", ...}``)
  or the ``API_VERSION`` constant, or
* both a ``"kind"`` and a ``"data"`` key.

KV-literals like ``{"k": ..., "v": ...}`` (the cache pools) bind ``"v"``
to arrays, not version strings, and are not matched.
"""
from __future__ import annotations

import ast
from pathlib import PurePath
from typing import Iterator

from repro.analysis.framework import Finding, ModuleInfo, Rule

SCHEMAS_SUFFIX = ("api", "schemas.py")


def _is_schemas_module(mod: ModuleInfo) -> bool:
    parts = PurePath(mod.path).parts
    return len(parts) >= 2 and parts[-2:] == SCHEMAS_SUFFIX


def _str_keys(node: ast.Dict) -> dict[str, ast.AST]:
    out: dict[str, ast.AST] = {}
    for k, v in zip(node.keys, node.values):
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            out[k.value] = v
    return out


def _is_version_value(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    if isinstance(node, ast.Name) and node.id == "API_VERSION":
        return True
    return isinstance(node, ast.Attribute) and node.attr == "API_VERSION"


class WireSchemaRule(Rule):
    name = "wire-schema"
    description = ("gateway/endpoint code must build wire payloads via "
                   "api/schemas.py, not ad-hoc dict literals")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        if _is_schemas_module(mod):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Dict):
                continue
            keys = _str_keys(node)
            envelope = ("kind" in keys and "data" in keys) or (
                "v" in keys and _is_version_value(keys["v"]))
            if envelope:
                yield self.finding(
                    mod, node,
                    "ad-hoc wire envelope dict bypasses api/schemas.py — "
                    "use to_wire()/a schema helper so the payload carries "
                    "the negotiated API_VERSION and survives schema "
                    "evolution")
