"""pallas-kernel-safety: kernel bodies that lower correctly on TPU.

Pallas kernels miscompile *silently* when these are broken — interpret
mode (the CI stand-in) happily runs code the Mosaic lowering would reject
or, worse, compile to garbage:

1. **No Python branches on traced values** — ``if``/``while`` on anything
   derived from ``pl.program_id``, a ref read, or ``pl.load`` takes one
   side at trace time. Use ``pl.when`` / ``jnp.where``.
2. **Guard ref stores with pl.when** — kernel grids here include dead
   steps (pages past a sequence's context length, the init/finalize
   steps of an online-softmax accumulator). A store to any ``*_ref`` /
   ``*_scr`` parameter outside a ``pl.when``-guarded region runs on every
   grid step, clobbering accumulators or committing garbage from absent
   pages. Helper functions only ever called from guarded regions count
   as guarded.
3. **BlockSpec tiles align to the dtype tile** — literal block dims must
   be multiples of 8 on the sublane (second-to-last) axis and 128 on the
   lane (last) axis (the f32 minimum; bf16 needs 16 sublanes — the rule
   checks the weaker bound it can know statically). Size-1 dims are
   squeezed axes and exempt; symbolic dims are trusted (the wrappers pad
   them via ``_pad_axis``/``_sublane``).

Only modules that import ``jax.experimental.pallas`` are checked; kernel
bodies are recognized by their ``*_ref``/``*_scr`` parameter convention
or by being passed to ``pl.pallas_call``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import ImportMap, resolves_to
from repro.analysis.framework import Finding, ModuleInfo, Rule

PALLAS = "jax.experimental.pallas"
REF_SUFFIXES = ("_ref", "_scr")
SUBLANE, LANE = 8, 128


def _imports_pallas(imports: ImportMap) -> bool:
    return any(v.startswith(PALLAS) for v in imports.aliases.values())


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _ref_params(fn: ast.FunctionDef) -> set[str]:
    return {n for n in _param_names(fn) if n.endswith(REF_SUFFIXES)}


def _kernel_bodies(mod: ModuleInfo,
                   imports: ImportMap) -> list[ast.FunctionDef]:
    """Functions with >=2 ref-convention params, plus anything passed (via
    a local ``partial`` alias) as the first argument of pl.pallas_call."""
    fns = [n for n in ast.walk(mod.tree)
           if isinstance(n, ast.FunctionDef)]
    by_name = {f.name: f for f in fns}
    bodies = {id(f): f for f in fns if len(_ref_params(f)) >= 2}
    # name -> wrapped function, from `kernel = functools.partial(_fn, ...)`
    partial_of: dict[str, str] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and resolves_to(imports, node.value.func,
                                "functools.partial") \
                and node.value.args \
                and isinstance(node.value.args[0], ast.Name):
            partial_of[node.targets[0].id] = node.value.args[0].id
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) \
                and resolves_to(imports, node.func,
                                f"{PALLAS}.pallas_call") and node.args:
            first = node.args[0]
            name = first.id if isinstance(first, ast.Name) else None
            name = partial_of.get(name, name)
            fn = by_name.get(name or "")
            if fn is not None:
                bodies[id(fn)] = fn
    return list(bodies.values())


def _is_pl_when(node: ast.AST, imports: ImportMap) -> bool:
    return isinstance(node, ast.Call) \
        and resolves_to(imports, node.func, f"{PALLAS}.when")


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _reads_ref_or_grid(node: ast.AST, refs: set[str],
                       imports: ImportMap) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name) \
                and n.value.id in refs:
            return True
        if isinstance(n, ast.Call) and resolves_to(
                imports, n.func, f"{PALLAS}.program_id",
                f"{PALLAS}.num_programs", f"{PALLAS}.load"):
            return True
    return False


class PallasKernelSafetyRule(Rule):
    name = "pallas-kernel-safety"
    description = ("no Python branches on tracers, pl.when-guarded ref "
                   "stores, sublane/lane-aligned literal BlockSpec tiles")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        imports = ImportMap(mod.tree)
        if not _imports_pallas(imports):
            return
        for fn in _kernel_bodies(mod, imports):
            yield from self._check_tracer_branches(mod, imports, fn)
            yield from self._check_guarded_stores(mod, imports, fn)
        yield from self._check_blockspecs(mod, imports)

    # -- check 1: Python branches on traced values ---------------------------
    def _check_tracer_branches(self, mod: ModuleInfo, imports: ImportMap,
                               fn: ast.FunctionDef) -> Iterator[Finding]:
        refs = _ref_params(fn)
        tainted: set[str] = set()
        assigns = [n for n in ast.walk(fn) if isinstance(n, ast.Assign)]
        for _ in range(2):                      # cheap fixpoint, 2 passes
            for node in assigns:
                value_tainted = (
                    _reads_ref_or_grid(node.value, refs, imports)
                    or bool(_names_in(node.value) & tainted))
                if value_tainted:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            tainted.add(t.id)
        for node in ast.walk(fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if _reads_ref_or_grid(node.test, refs, imports) \
                    or (_names_in(node.test) & tainted):
                kind = "while" if isinstance(node, ast.While) else "if"
                yield self.finding(
                    mod, node,
                    f"Python `{kind}` on a traced value in kernel body "
                    f"'{fn.name}' — resolved once at trace time, not per "
                    "grid step; use pl.when or jnp.where")

    # -- check 2: unguarded ref stores ---------------------------------------
    def _check_guarded_stores(self, mod: ModuleInfo, imports: ImportMap,
                              fn: ast.FunctionDef) -> Iterator[Finding]:
        refs = _ref_params(fn)
        nested = {n.name: n for n in ast.walk(fn)
                  if isinstance(n, ast.FunctionDef) and n is not fn}
        guarded: set[str] = {
            name for name, d in nested.items()
            if any(_is_pl_when(dec, imports) for dec in d.decorator_list)}
        # helper defs count as guarded once every call site sits inside an
        # already-guarded def (fixpoint)
        changed = True
        while changed:
            changed = False
            for name, d in nested.items():
                if name in guarded:
                    continue
                sites = self._call_sites(fn, name, nested)
                if sites and all(s in guarded for s in sites):
                    guarded.add(name)
                    changed = True
        owner: dict[int, str | None] = {}
        self._map_owners(fn, None, nested, owner)
        for node in ast.walk(fn):
            target = None
            if isinstance(node, ast.Assign):
                target = next((t for t in node.targets
                               if self._is_ref_store(t, refs)), None)
            elif isinstance(node, ast.AugAssign) \
                    and self._is_ref_store(node.target, refs):
                target = node.target
            if target is None:
                continue
            home = owner.get(id(node))
            if home is not None and home in guarded:
                continue
            yield self.finding(
                mod, node,
                f"unguarded ref store in kernel body '{fn.name}': runs on "
                "every grid step (absent pages / accumulator init included)"
                " — wrap in a pl.when-guarded region")

    @staticmethod
    def _is_ref_store(target: ast.AST, refs: set[str]) -> bool:
        return isinstance(target, ast.Subscript) \
            and isinstance(target.value, ast.Name) \
            and target.value.id in refs

    def _call_sites(self, fn: ast.FunctionDef, name: str,
                    nested: dict) -> set[str | None]:
        """Names of the nested defs (or None for top level) that call
        ``name``."""
        owner: dict[int, str | None] = {}
        self._map_owners(fn, None, nested, owner)
        sites: set[str | None] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == name:
                sites.add(owner.get(id(node)))
        return sites

    def _map_owners(self, node: ast.AST, home: str | None, nested: dict,
                    owner: dict) -> None:
        """Tag every node with the innermost nested def containing it."""
        for child in ast.iter_child_nodes(node):
            child_home = home
            if isinstance(child, ast.FunctionDef) and child.name in nested:
                child_home = child.name
            owner[id(child)] = child_home
            self._map_owners(child, child_home, nested, owner)

    # -- check 3: BlockSpec literal tile alignment ---------------------------
    def _check_blockspecs(self, mod: ModuleInfo,
                          imports: ImportMap) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and resolves_to(imports, node.func,
                                    f"{PALLAS}.BlockSpec")):
                continue
            shape = node.args[0] if node.args else None
            if not isinstance(shape, (ast.Tuple, ast.List)) \
                    or len(shape.elts) < 2:
                continue
            dims = [e.value if isinstance(e, ast.Constant)
                    and isinstance(e.value, int) else None
                    for e in shape.elts]
            lane, sub = dims[-1], dims[-2]
            if lane is not None and lane > 1 and lane % LANE:
                yield self.finding(
                    mod, node,
                    f"BlockSpec lane (last) dim {lane} is not a multiple "
                    f"of {LANE} — the TPU lowering pads or rejects "
                    "misaligned lane tiles")
            if sub is not None and sub > 1 and sub % SUBLANE:
                yield self.finding(
                    mod, node,
                    f"BlockSpec sublane dim {sub} is not a multiple of "
                    f"{SUBLANE} (f32 tile; bf16 needs 16) — pad the axis "
                    "like ops._pad_axis does")
