"""firstlint rule registry."""
from __future__ import annotations

from repro.analysis.framework import Rule
from repro.analysis.rules.cache_invalidation import CacheInvalidationRule
from repro.analysis.rules.donation import DonationSafetyRule
from repro.analysis.rules.host_sync import HostSyncRule
from repro.analysis.rules.pallas_safety import PallasKernelSafetyRule
from repro.analysis.rules.wire_schema import WireSchemaRule

ALL_RULES: tuple[type[Rule], ...] = (
    HostSyncRule,
    CacheInvalidationRule,
    PallasKernelSafetyRule,
    DonationSafetyRule,
    WireSchemaRule,
)

RULES_BY_NAME = {cls.name: cls for cls in ALL_RULES}


def get_rules(names: list[str] | None = None) -> list[Rule]:
    """Instantiate the selected rules (all of them by default)."""
    if not names:
        return [cls() for cls in ALL_RULES]
    unknown = [n for n in names if n not in RULES_BY_NAME]
    if unknown:
        known = ", ".join(sorted(RULES_BY_NAME))
        raise KeyError(f"unknown rule(s) {unknown}; known rules: {known}")
    return [RULES_BY_NAME[n]() for n in names]
