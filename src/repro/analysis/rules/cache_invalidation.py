"""cache-invalidation: pool/table mutations must invalidate derived state.

Two contracts from the paged serving stack (PR 9's XLA twin):

1. **Allocator version bump** — in a class that maintains a
   ``table_version`` counter (``PagedKVCache``), every method that mutates
   the block tables (``self._tables`` — directly, through a subscript, or
   through a local alias) must bump ``self.table_version`` in the same
   method. The fused decode path caches device-resident tables keyed on
   that counter; an unbumped mutation serves stale tables silently.

2. **Cached-view invalidation** — in a class that defines an
   ``_invalidate_view`` hook (``PagedBackend``), every method that mutates
   the page pools (``self.pools`` / ``self.cache``) or re-uploads the
   device table pair (``self._dev_tables``) must either call
   ``self._invalidate_view()`` or maintain ``self._ctx_view`` in place
   (assign it from the mutating call, the fused-loop contract) in the same
   method. ``__init__`` (no committed KV yet) and the hook itself are
   exempt. This keeps the hand-enumerated mutation-site inventory in
   ``serving/backends.py`` from drifting: deleting any one invalidation
   call makes this rule fail.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import is_self_attr
from repro.analysis.framework import Finding, ModuleInfo, Rule

POOL_ATTRS = ("pools", "cache", "_dev_tables")
VIEW_ATTR = "_ctx_view"
INVALIDATE_HOOK = "_invalidate_view"
TABLES_ATTR = "_tables"
VERSION_ATTR = "table_version"
MUTATOR_METHODS = {"append", "pop", "insert", "extend", "remove", "clear",
                   "setdefault", "update"}


def _assign_target_attrs(stmt: ast.stmt) -> set[str]:
    """self.X attributes assigned by a statement (incl. tuple targets)."""
    out: set[str] = set()
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    flat: list[ast.AST] = []
    while targets:
        t = targets.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            targets.extend(t.elts)
        else:
            flat.append(t)
    for t in flat:
        if is_self_attr(t):
            out.add(t.attr)
    return out


def _methods(cls: ast.ClassDef) -> list[ast.FunctionDef]:
    return [n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _method_assigns(method: ast.AST, attr: str) -> bool:
    for node in ast.walk(method):
        if isinstance(node, ast.stmt) and attr in _assign_target_attrs(node):
            return True
    return False


def _calls_hook(method: ast.AST, hook: str) -> bool:
    for node in ast.walk(method):
        if isinstance(node, ast.Call) and is_self_attr(node.func, hook):
            return True
    return False


def _rooted_at(node: ast.AST, attr: str, aliases: set[str]) -> bool:
    """Does the access chain bottom out at ``self.<attr>`` or an alias?"""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if is_self_attr(node, attr):
            return True
        node = node.value
    if isinstance(node, ast.Name):
        return node.id in aliases
    return is_self_attr(node, attr)


def _tables_aliases(method: ast.AST) -> set[str]:
    """Local names bound to ``self._tables`` or an element of it."""
    aliases: set[str] = set()
    for node in ast.walk(method):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            value = node.value
            # table = self._tables[x]  |  t = self._tables.get(x, ...)
            if isinstance(value, ast.Call):
                value = value.func
            if _rooted_at(value, TABLES_ATTR, set()):
                aliases.add(node.targets[0].id)
    return aliases


def _mutates_tables(method: ast.AST) -> ast.AST | None:
    """First node that mutates the block tables, else None."""
    aliases = _tables_aliases(method)
    for node in ast.walk(method):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and _rooted_at(t, TABLES_ATTR, aliases):
                    return node
                if is_self_attr(t, TABLES_ATTR):
                    return node
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if _rooted_at(t, TABLES_ATTR, aliases):
                    return node
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATOR_METHODS \
                and _rooted_at(node.func.value, TABLES_ATTR, aliases):
            # .get() and reads are not mutations; only the mutator set
            return node
    return None


class CacheInvalidationRule(Rule):
    name = "cache-invalidation"
    description = ("block-table mutations must bump table_version; pool "
                   "mutations must invalidate the cached context view")

    def check(self, mod: ModuleInfo) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(mod, node)

    def _check_class(self, mod: ModuleInfo,
                     cls: ast.ClassDef) -> Iterator[Finding]:
        methods = _methods(cls)
        names = {m.name for m in methods}
        init = next((m for m in methods if m.name == "__init__"), None)

        # contract 1: table_version bump
        has_version = init is not None and _method_assigns(init, VERSION_ATTR)
        if has_version:
            for m in methods:
                if m.name == "__init__":
                    continue
                site = _mutates_tables(m)
                if site is not None and not _method_assigns(m, VERSION_ATTR):
                    yield self.finding(
                        mod, site,
                        f"{cls.name}.{m.name} mutates self.{TABLES_ATTR} "
                        f"without bumping self.{VERSION_ATTR} — "
                        "device-resident block tables go stale silently")

        # contract 2: cached-view invalidation
        if INVALIDATE_HOOK not in names:
            return
        for m in methods:
            if m.name in ("__init__", INVALIDATE_HOOK):
                continue
            touched = sorted(
                a for a in POOL_ATTRS if _method_assigns(m, a))
            if not touched:
                continue
            if _calls_hook(m, INVALIDATE_HOOK):
                continue
            if _method_assigns(m, VIEW_ATTR):
                continue        # fused-loop contract: view advanced in place
            yield self.finding(
                mod, m,
                f"{cls.name}.{m.name} mutates self.{' / self.'.join(touched)} "
                f"without calling self.{INVALIDATE_HOOK}() (or maintaining "
                f"self.{VIEW_ATTR} in place) — the XLA twin's cached "
                "context view would serve stale KV")
