"""ShareGPT-like workload generator (paper §5.2.2: benchmarks use ShareGPT
prompt/response length distributions). Deterministic given a seed."""
from __future__ import annotations

import math
import random
from dataclasses import dataclass


@dataclass
class WorkloadRequest:
    request_id: str
    prompt_tokens: int
    max_tokens: int
    arrival: float
    user: str = "bench"


def sharegpt_lengths(rng: random.Random, n: int,
                     prompt_mu: float = 5.1, prompt_sigma: float = 0.9,
                     out_mu: float = 5.0, out_sigma: float = 0.8,
                     lo: int = 4, hi: int = 2048):
    """Lognormal fits to the filtered ShareGPT distribution used by the vLLM
    benchmark (mean prompt ~220 tok, mean output ~190 tok, clipped 4..2048)."""
    pairs = []
    for _ in range(n):
        p = int(min(hi, max(lo, math.exp(rng.gauss(prompt_mu, prompt_sigma)))))
        o = int(min(hi, max(lo, math.exp(rng.gauss(out_mu, out_sigma)))))
        pairs.append((p, o))
    return pairs


def make_workload(n: int, rate: float, seed: int = 0, user: str = "bench",
                  prefix: str = "r", **length_kw) -> list[WorkloadRequest]:
    """``rate`` req/s Poisson arrivals; rate=inf sends everything at t=0
    (the paper's 'infinite request rate' saturation mode)."""
    rng = random.Random(seed)
    lengths = sharegpt_lengths(rng, n, **length_kw)
    t = 0.0
    out = []
    for i, (p, o) in enumerate(lengths):
        if math.isinf(rate):
            arr = 0.0
        else:
            t += rng.expovariate(rate)
            arr = t
        out.append(WorkloadRequest(request_id=f"{prefix}{i}", prompt_tokens=p,
                                   max_tokens=o, arrival=arr, user=user))
    return out


def token_ids_for(req: WorkloadRequest, vocab: int, seed: int = 0) -> list[int]:
    """Materialize synthetic prompt token ids (for real-engine runs)."""
    rng = random.Random(hash((req.request_id, seed)) & 0x7FFFFFFF)
    return [rng.randrange(2, vocab) for _ in range(req.prompt_tokens)]
