"""ShareGPT-like workload generator (paper §5.2.2: benchmarks use ShareGPT
prompt/response length distributions). Deterministic given a seed."""
from __future__ import annotations

import math
import random
import zlib
from dataclasses import dataclass


@dataclass
class WorkloadRequest:
    request_id: str
    prompt_tokens: int
    max_tokens: int
    arrival: float
    user: str = "bench"


def sharegpt_lengths(rng: random.Random, n: int,
                     prompt_mu: float = 5.1, prompt_sigma: float = 0.9,
                     out_mu: float = 5.0, out_sigma: float = 0.8,
                     lo: int = 4, hi: int = 2048):
    """Lognormal fits to the filtered ShareGPT distribution used by the vLLM
    benchmark (mean prompt ~220 tok, mean output ~190 tok, clipped 4..2048)."""
    pairs = []
    for _ in range(n):
        p = int(min(hi, max(lo, math.exp(rng.gauss(prompt_mu, prompt_sigma)))))
        o = int(min(hi, max(lo, math.exp(rng.gauss(out_mu, out_sigma)))))
        pairs.append((p, o))
    return pairs


def make_workload(n: int, rate: float, seed: int = 0, user: str = "bench",
                  prefix: str = "r", **length_kw) -> list[WorkloadRequest]:
    """``rate`` req/s Poisson arrivals; rate=inf sends everything at t=0
    (the paper's 'infinite request rate' saturation mode)."""
    rng = random.Random(seed)
    lengths = sharegpt_lengths(rng, n, **length_kw)
    t = 0.0
    out = []
    for i, (p, o) in enumerate(lengths):
        if math.isinf(rate):
            arr = 0.0
        else:
            t += rng.expovariate(rate)
            arr = t
        out.append(WorkloadRequest(request_id=f"{prefix}{i}", prompt_tokens=p,
                                   max_tokens=o, arrival=arr, user=user))
    return out


def make_bursty_workload(n_bursts: int, burst_n: int, rate: float,
                         gap: float, seed: int = 0, user: str = "bench",
                         prefix: str = "b",
                         **length_kw) -> list[WorkloadRequest]:
    """Diurnal replay trace: ``n_bursts`` active phases of ``burst_n``
    Poisson arrivals at ``rate`` req/s, separated by ``gap`` seconds of
    silence — the arrival shape that makes hot pools matter (a
    cold-start-on-demand policy pays a spin-up at every burst front)."""
    out: list[WorkloadRequest] = []
    t0 = 0.0
    for b in range(n_bursts):
        seg = make_workload(burst_n, rate, seed=seed + b, user=user,
                            prefix=f"{prefix}{b}-", **length_kw)
        for w in seg:
            w.arrival += t0
        t0 = (seg[-1].arrival if seg else t0) + gap
        out.extend(seg)
    return out


def _stable_seed(request_id: str, seed: int) -> int:
    """Process-independent digest for per-request RNG seeding. The builtin
    ``hash`` is randomized per process by PYTHONHASHSEED, which silently
    broke this module's 'deterministic given a seed' contract across
    runs/CI — crc32 gives the same stream everywhere."""
    return zlib.crc32(f"{request_id}/{seed}".encode()) & 0x7FFFFFFF


def token_ids_for(req: WorkloadRequest, vocab: int, seed: int = 0) -> list[int]:
    """Materialize synthetic prompt token ids (for real-engine runs)."""
    rng = random.Random(_stable_seed(req.request_id, seed))
    return [rng.randrange(2, vocab) for _ in range(req.prompt_tokens)]
