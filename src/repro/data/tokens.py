"""Deterministic synthetic LM data pipeline with a checkpointable cursor.

The stream is a seeded Zipfian token process with induced bigram structure so
tiny models have something learnable (loss decreases measurably within a few
hundred steps). ``state()``/``restore()`` make the pipeline resumable —
restarting from a checkpoint replays the exact same batch sequence.
"""
from __future__ import annotations

import numpy as np


class TokenDataset:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, input_kind: str = "tokens",
                 d_model: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.input_kind = input_kind
        self.d_model = d_model
        self._step = 0
        # learnable structure: each token deterministically prefers a
        # successor; noise makes it a distribution
        rng = np.random.default_rng(seed)
        self._succ = rng.permutation(vocab_size)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._zipf = (1.0 / ranks) / np.sum(1.0 / ranks)

    # -- cursor (for fault-tolerant resume) ------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: dict):
        assert state["seed"] == self.seed, "dataset seed mismatch"
        self._step = int(state["step"])

    # -- batches ------------------------------------------------------------------
    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(V, size=B, p=self._zipf)
        follow = rng.random((B, S)) < 0.6     # 60% bigram-following
        fresh = rng.choice(V, size=(B, S), p=self._zipf)
        for t in range(S):
            nxt = self._succ[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t])
        batch = {"labels": toks[:, 1:].astype(np.int32)}
        if self.input_kind == "embeds":
            emb_rng = np.random.default_rng((self.seed, self._step, 7))
            batch["embeds"] = emb_rng.standard_normal(
                (B, S, self.d_model)).astype(np.float32) * 0.02
        else:
            batch["tokens"] = toks[:, :-1].astype(np.int32)
        return batch
