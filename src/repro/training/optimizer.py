"""AdamW in pure JAX. Moments are fp32 regardless of param dtype; the update
math runs in fp32 and casts back (bf16 params + fp32 m/v is the deployment
configuration assumed by the dry-run memory analysis)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
