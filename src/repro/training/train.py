"""Training step: remat'd scan-over-layers forward/backward with gradient
accumulation over microbatches, then a fused AdamW update."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import LM
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(model: LM, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1, remat: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``batch`` leaves have leading dim global_batch; it is split into
    ``num_microbatches`` sequential accumulation steps."""

    def loss_fn(params, mb):
        loss, metrics = model.train_loss(params, mb, remat=remat)
        return loss, metrics

    def train_step(params, opt_state, batch):
        n = num_microbatches

        if n == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

            def micro(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = lax.scan(micro, (gz, jnp.float32(0.0)),
                                            mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n

        params, opt_state, opt_metrics = adamw_update(params, grads,
                                                      opt_state, opt_cfg)
        out = {"loss": loss, **opt_metrics}
        return params, opt_state, out

    return train_step


def init_training(model: LM, rng):
    params = model.init_params(rng)
    opt_state = adamw_init(params)
    return params, opt_state
