"""Configuration dataclasses for models, shapes, and dry-run cells.

Every assigned architecture gets one module in this package defining CONFIG.
The registry in __init__.py maps the public ``--arch`` id to that config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int
    qkv_bias: bool = False
    causal: bool = True
    is_encoder: bool = False
    input_kind: str = "tokens"   # tokens | embeds (modality frontend stub)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0          # hybrid: shared attention block after every N ssm layers
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention window applied for contexts beyond 32k (hybrid long-context
    # adaptation, see DESIGN.md §4); 0 = always full attention.
    sliding_window_long: int = 4096
    param_dtype: str = "bfloat16"
    source: str = ""             # provenance tag from the assignment table

    # ---- derived ----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def has_attention(self) -> bool:
        return self.family != "ssm"

    def attn_layer_count(self) -> int:
        """Number of distinct attention cache slots."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return self.num_layers // self.attn_every
        return self.num_layers

    def ssm_layer_count(self) -> int:
        if self.family == "ssm":
            return self.num_layers
        if self.family == "hybrid":
            return self.num_layers
        return 0

    # ---- parameter counting (exact, mirrors models/*.py init) ----
    def param_counts(self) -> dict:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d
        head = 0 if self.tie_embeddings else d * v
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        per_mlp = 3 * d * f  # SwiGLU: w1, w3 (d->f), w2 (f->d)
        per_norms = 2 * d
        expert_total = 0
        n_layers_attn = 0
        n_layers_mlp = 0
        ssm_total = 0
        if self.family in ("dense", "vlm", "audio"):
            n_layers_attn = self.num_layers
            n_layers_mlp = self.num_layers
        elif self.family == "moe":
            n_layers_attn = self.num_layers
            router = d * self.moe.num_experts
            expert_total = self.num_layers * (self.moe.num_experts * per_mlp + router)
        elif self.family in ("ssm", "hybrid"):
            di, n = self.d_inner, self.ssm.d_state
            h = self.ssm_heads
            # in_proj: d -> (2*di + 2*n + h)   [x, z, B, C, dt]
            # out_proj: di -> d ; conv over (di + 2n); A_log, D, dt_bias: h each; norm d
            per_ssm = (d * (2 * di + 2 * n + h) + di * d
                       + (di + 2 * n) * self.ssm.conv_kernel
                       + 3 * h + di + d)
            ssm_total = self.num_layers * per_ssm
            if self.family == "hybrid":
                # one SHARED attn+mlp block (params reused at each application)
                ssm_total += per_attn + per_mlp + per_norms
        body = (n_layers_attn * (per_attn + per_norms)
                + n_layers_mlp * per_mlp
                + expert_total + ssm_total + d)  # final norm
        total = emb + head + body
        active = total
        if self.family == "moe":
            inactive = self.num_layers * (self.moe.num_experts - self.moe.top_k) * per_mlp
            active = total - inactive
        return {"total": total, "active": active, "embedding": emb + head}

    @property
    def num_params(self) -> int:
        return self.param_counts()["total"]

    @property
    def num_active_params(self) -> int:
        return self.param_counts()["active"]


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


def cells_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """Runnable (arch x shape) cells, applying the principled skips (DESIGN.md §4)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if not cfg.is_encoder:
        cells.append(SHAPES["decode_32k"])
        if cfg.family in ("ssm", "hybrid"):
            cells.append(SHAPES["long_500k"])
    return cells


def skipped_cells_for(cfg: ModelConfig) -> dict[str, str]:
    out = {}
    if cfg.is_encoder:
        out["decode_32k"] = "encoder-only arch: no autoregressive decode step"
        out["long_500k"] = "encoder-only + full attention"
    elif cfg.family not in ("ssm", "hybrid"):
        out["long_500k"] = "pure full-attention arch: 500k context needs sub-quadratic attention"
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    kv = 1 if cfg.num_kv_heads == 1 else (4 if cfg.num_kv_heads == cfg.num_heads else 2)
    changes = dict(
        num_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        param_dtype="float32",
    )
    if cfg.moe:
        changes["moe"] = replace(cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2))
    if cfg.ssm:
        changes["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.attn_every:
        changes["attn_every"] = 2
    return replace(cfg, **changes)
