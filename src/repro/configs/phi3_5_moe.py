"""phi3.5-moe-42b-a6.6b — MoE, 16 experts top-2. 32L d4096 32H (kv=8) d_ff 6400
vocab 32064. [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, top_k=2),
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)
