"""llava-next-34b — VLM; anyres-tiled vision frontend is a STUB (precomputed patch
embeddings enter via ``embeds``). Backbone per assignment: 60L d7168 56H (GQA kv=8)
d_ff 20480 vocab 64000. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    input_kind="embeds",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
