"""mamba2-130m — attention-free SSM with SSD (state-space duality).
24L d768, ssm_state 128, vocab 50280. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    source="arXiv:2405.21060; unverified",
)
