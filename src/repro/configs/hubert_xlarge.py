"""hubert-xlarge — encoder-only audio transformer (wav2vec2 arch); conv frame
frontend is a STUB (precomputed frame embeddings enter via ``embeds``).
48L d1280 16H (kv=16, head_dim 80) d_ff 5120 vocab 504 (cluster targets).
[arXiv:2106.07447; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    is_encoder=True,
    input_kind="embeds",
    source="arXiv:2106.07447; unverified",
)
