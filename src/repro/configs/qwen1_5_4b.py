"""qwen1.5-4b — dense MHA (kv=20) with QKV bias and a very large vocab.
40L d2560 20H d_ff 6912 vocab 151936. [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
