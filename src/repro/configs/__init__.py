"""Architecture registry: public ``--arch`` id -> ModelConfig."""
from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig,
    SHAPES, cells_for, skipped_cells_for, reduced,
)
from repro.configs import (
    llava_next_34b, granite_34b, qwen1_5_4b, yi_34b, llama3_2_3b,
    phi3_5_moe, dbrx_132b, zamba2_2_7b, mamba2_130m, hubert_xlarge,
)

_MODULES = [
    llava_next_34b, granite_34b, qwen1_5_4b, yi_34b, llama3_2_3b,
    phi3_5_moe, dbrx_132b, zamba2_2_7b, mamba2_130m, hubert_xlarge,
]

REGISTRY: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# short aliases (module-style ids)
ALIASES = {
    "llava-next-34b": "llava-next-34b",
    "granite-34b": "granite-34b",
    "qwen1.5-4b": "qwen1.5-4b",
    "yi-34b": "yi-34b",
    "llama3.2-3b": "llama3.2-3b",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "dbrx-132b": "dbrx-132b",
    "zamba2-2.7b": "zamba2-2.7b",
    "mamba2-130m": "mamba2-130m",
    "hubert-xlarge": "hubert-xlarge",
}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch)
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[key]


def list_archs() -> list[str]:
    return sorted(REGISTRY)


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "cells_for", "skipped_cells_for", "reduced", "get_config", "list_archs",
    "REGISTRY",
]
