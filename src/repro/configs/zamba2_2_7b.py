"""zamba2-2.7b — hybrid: Mamba2 backbone + a SHARED attention+MLP block applied every
6 layers (params reused at each application, the Zamba trick). 54L d2560, attn 32H
(kv=32, head_dim 80), d_ff 10240, vocab 32000, ssm_state 64. [arXiv:2411.15242; hf]

Long-context adaptation: the shared attention uses a 4096-token sliding window for
contexts > 32k (DESIGN.md §4); <=32k stays full attention.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    attn_every=6,
    sliding_window_long=4096,
    source="arXiv:2411.15242; hf",
)
