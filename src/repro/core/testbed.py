"""Testbed builder: assembles the full FIRST system (clusters, endpoints,
compute client, federation, auth, gateway, batch service) in one call.
Mirrors the paper's deployment: the Sophia-like cluster hosts the LLMs; a
second Polaris-like cluster joins for federation experiments.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.auth import AccessPolicy, AuthService, CachingAuthClient
from repro.core.autoscale import AutoScalePolicy
from repro.core.batch import BatchService
from repro.core.clock import EventLoop, VirtualClock
from repro.core.compute import ComputeClient, ComputeEndpoint, ModelDeployment
from repro.core.faults import FailureInjector, HealthMonitor
from repro.core.federation import FederationRouter
from repro.core.gateway import GatewayConfig, InferenceGateway
from repro.core.metrics import MetricsLog
from repro.core.scheduler import ClusterScheduler
from repro.serving.costmodel import InstanceCost

# Cost-model stand-ins for the paper's benchmark models (llama-arch configs
# from public literature; used ONLY by the DES control-plane benchmarks —
# the 10 assigned architectures are served through the same machinery).
LLAMA70B = ModelConfig(
    name="llama3.3-70b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=28672,
    vocab_size=128256, source="arXiv:2407.21783")
LLAMA8B = ModelConfig(
    name="llama3.1-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=128256, source="arXiv:2407.21783")
GEMMA27B = ModelConfig(
    name="gemma-27b", family="dense", num_layers=46, d_model=4608,
    num_heads=32, num_kv_heads=16, head_dim=128, d_ff=36864,
    vocab_size=256000, source="arXiv:2408.00118")


@dataclass
class System:
    loop: EventLoop
    auth_service: AuthService
    auth: CachingAuthClient
    schedulers: dict
    endpoints: dict
    compute: ComputeClient
    router: FederationRouter
    gateway: InferenceGateway
    metrics: MetricsLog
    batch: BatchService
    health: HealthMonitor
    faults: FailureInjector
    tokens: dict = field(default_factory=dict)

    def token_for(self, user: str) -> str:
        if user not in self.tokens:
            self.auth_service.add_user(user, groups=("users",))
            self.tokens[user] = self.auth_service.issue_token(user)
        return self.tokens[user]


def default_deployment(cfg: ModelConfig, *, chips_per_instance: int = 8,
                       nodes_per_instance: int = 1, max_slots: int = 48,
                       max_instances: int = 1, idle_timeout: float = 7200.0,
                       model_shards: int = 1,
                       mfu: float = 0.5,
                       storage_bw: float = 2e9,
                       scale_cooldown: float = 30.0,
                       role: str = "unified",
                       min_hot: int = 0,
                       keepalive: float | None = None,
                       scale_in_cooldown: float = 30.0,
                       queue_threshold: int = 4,
                       result_cpu: float = 0.0,
                       prefix_cache_hit_rate: float = 0.0,
                       chunked_prefill_budget: int | None = None,
                       decode_steps_per_sync: int = 1,
                       scheduling_policy: str = "fcfs",
                       enable_preemption: bool = False,
                       restore_hit_rate: float = 1.0,
                       hw: dict | None = None) -> ModelDeployment:
    """``hw``: optional InstanceCost overrides, e.g. A100 constants
    ``dict(peak_flops=312e12, hbm_bw=1555e9)`` for paper-validation runs.
    ``model_shards``: tensor-parallel width per instance (must divide
    ``chips_per_instance``; InstanceCost validates) — adds the per-layer
    all-reduce terms to every service time, exactly as the real engine's
    ``EngineConfig.mesh`` shards its forward.
    ``role`` / ``min_hot`` / ``keepalive``: hot-pool + disaggregated
    serving knobs — see ``ModelDeployment`` and ``AutoScalePolicy``."""
    return ModelDeployment(
        model=cfg.name,
        role=role,
        cost=InstanceCost(cfg=cfg, chips=chips_per_instance, mfu=mfu,
                          storage_bw=storage_bw, model_shards=model_shards,
                          **(hw or {})),
        nodes_per_instance=nodes_per_instance,
        model_shards=model_shards,
        max_slots=max_slots,
        idle_timeout=idle_timeout,
        result_cpu=result_cpu,
        prefix_cache_hit_rate=prefix_cache_hit_rate,
        chunked_prefill_budget=chunked_prefill_budget,
        decode_steps_per_sync=decode_steps_per_sync,
        scheduling_policy=scheduling_policy,
        enable_preemption=enable_preemption,
        restore_hit_rate=restore_hit_rate,
        autoscale=AutoScalePolicy(max_instances=max_instances,
                                  cooldown=scale_cooldown,
                                  queue_threshold=queue_threshold,
                                  min_hot=min_hot,
                                  keepalive=keepalive,
                                  scale_in_cooldown=scale_in_cooldown),
    )


def build_system(
    deployments_by_cluster: dict[str, dict[str, ModelDeployment]] | None = None,
    *,
    nodes_per_cluster: int = 24,
    gateway_config: GatewayConfig | None = None,
    auth_latency: float = 2.0,
    auth_cache: bool = True,
    dispatch_latency: float = 0.15,
    connection_cache: bool = True,
    registry: dict[str, list[str]] | None = None,
    startup_delay: float = 20.0,
) -> System:
    """deployments_by_cluster: cluster -> {model_name: ModelDeployment}.
    Defaults to the paper's single-cluster Sophia deployment of Llama-70B."""
    loop = EventLoop(VirtualClock())
    if deployments_by_cluster is None:
        deployments_by_cluster = {
            "sophia": {LLAMA70B.name: default_deployment(LLAMA70B)}}

    auth_service = AuthService(loop, introspection_latency=auth_latency)
    auth = CachingAuthClient(loop, auth_service, enabled=auth_cache)
    compute = ComputeClient(loop, dispatch_latency=dispatch_latency,
                            result_latency=dispatch_latency,
                            connection_cache=connection_cache)
    schedulers = {}
    endpoints = {}
    for cluster, deps in deployments_by_cluster.items():
        sched = ClusterScheduler(loop, cluster, num_nodes=nodes_per_cluster,
                                 startup_delay=startup_delay)
        ep = ComputeEndpoint(loop, f"{cluster}-ep", sched, deps)
        schedulers[cluster] = sched
        endpoints[ep.endpoint_id] = ep
        compute.register_endpoint(ep)

    if registry is None:
        registry = {}
        for cluster, deps in deployments_by_cluster.items():
            for model in deps:
                registry.setdefault(model, []).append(f"{cluster}-ep")

    router = FederationRouter(endpoints, registry)
    for ep in endpoints.values():
        ep.attach_federation(router)   # prefill->decode handoff targeting
    metrics = MetricsLog()
    batch = BatchService(loop, router, endpoints)
    gateway = InferenceGateway(loop, auth, router, compute,
                               policy=AccessPolicy(),
                               config=gateway_config or GatewayConfig(),
                               metrics=metrics, batch=batch)
    health = HealthMonitor(loop, router)
    for ep in endpoints.values():
        health.watch(ep)          # endpoints emit real heartbeats
    faults = FailureInjector(loop)
    return System(loop=loop, auth_service=auth_service, auth=auth,
                  schedulers=schedulers, endpoints=endpoints, compute=compute,
                  router=router, gateway=gateway, metrics=metrics,
                  batch=batch, health=health, faults=faults)


def warm_up(system: System, model: str, instances: int = 1,
            user: str = "warm") -> None:
    """Bring ``instances`` hot instances up (and populate auth caches) before
    measuring — the paper's steady-state numbers are for hot models."""
    token = system.token_for(user)
    ep_id = system.router.select_endpoint(model)
    ep = system.endpoints[ep_id]
    for _ in range(instances - len(ep._alive_instances(model))):
        ep._spawn_instance(model)
    fut = system.gateway.submit(token, {
        "request_id": f"warm-{model}", "model": model,
        "prompt_tokens": 8, "max_tokens": 1})
    system.loop.run_until_idle()
    assert fut.done() and fut.error is None, f"warmup failed: {fut.error}"
    # drop the warmup from the metrics log
    system.metrics.records.clear()


def drive_workload(system: System, workload, model: str,
                   user: str = "bench") -> dict:
    """Submit a WorkloadRequest list through the gateway at their arrival
    times; run the loop until everything resolves. Returns metrics summary."""
    token = system.token_for(user)
    results = {}

    def _submit(w):
        fut = system.gateway.submit(token, {
            "request_id": w.request_id, "model": model,
            "prompt_tokens": w.prompt_tokens, "max_tokens": w.max_tokens,
        })
        fut.add_done_callback(lambda f: results.__setitem__(
            w.request_id, f.error or f.result()))

    for w in workload:
        system.loop.call_at(w.arrival, _submit, w)
    system.loop.run_until_idle()
    summary = system.metrics.summary()
    summary["errors"] = sum(1 for v in results.values()
                            if isinstance(v, Exception))
    return summary
