"""The Inference Gateway: async OpenAI-compatible front end (paper §3.1).

Responsibilities reproduced from the paper: identity validation (with the
Optimization-2 introspection cache), request validation, per-user rate
limiting, response caching, conversion of API requests into compute tasks,
activity logging, and the /jobs status endpoint.

The worker pool models the Gunicorn/Uvicorn capacity. Three paper
optimizations are config toggles so benchmarks can ablate them:
  * Optimization 1 — ``poll_interval=0`` uses futures; ``>0`` polls task
    status on a timer (adds up to one interval of latency per request).
  * Optimization 2 — ``auth_cache`` on the CachingAuthClient +
    ``connection_cache`` on the ComputeClient.
  * Optimization 3 — ``blocking_workers=False`` (async Django-Ninja style:
    workers release after dispatch) vs ``True`` (sync Django-REST style:
    a worker is held for the request's whole lifetime; the paper's original
    deployment processed only nine requests at a time).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field

from repro.core.auth import AccessPolicy, AuthError, CachingAuthClient, Identity
from repro.core.clock import Future
from repro.core.metrics import MetricsLog

VALID_ENDPOINTS = ("chat/completions", "completions", "embeddings")


class GatewayError(Exception):
    pass


@dataclass
class GatewayConfig:
    workers: int = 64                  # gunicorn workers x threads
    request_cpu_time: float = 0.002    # per-request gateway handling cost (s)
    blocking_workers: bool = False     # Optimization 3 toggle (True = sync)
    poll_interval: float = 0.0         # Optimization 1 toggle (>0 = polling)
    rate_limit_per_user: float = float("inf")   # req/s token bucket
    rate_burst: float = 100.0
    response_cache_size: int = 4096
    max_queue: int = 1_000_000
    # straggler mitigation (off by default): if a dispatched request has not
    # completed after this many seconds, hedge a duplicate to a DIFFERENT
    # endpoint; first completion wins (inference is idempotent)
    hedge_after: float | None = None


class RateLimiter:
    """Per-user token bucket."""

    def __init__(self, loop, rate: float, burst: float):
        self.loop = loop
        self.rate = rate
        self.burst = burst
        self._state: dict[str, tuple[float, float]] = {}   # user -> (tokens, t)

    def allow(self, user: str) -> bool:
        if self.rate == float("inf"):
            return True
        now = self.loop.now()
        tokens, t = self._state.get(user, (self.burst, now))
        tokens = min(self.burst, tokens + (now - t) * self.rate)
        if tokens < 1.0:
            self._state[user] = (tokens, now)
            return False
        self._state[user] = (tokens - 1.0, now)
        return True


class ResponseCache:
    """LRU cache for deterministic (temperature=0) repeated requests."""

    def __init__(self, size: int):
        self.size = size
        self._d: dict = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(req: dict):
        if req.get("temperature", 0.0) != 0.0:
            return None
        return (req["model"], req.get("prompt_hash", req.get("prompt_tokens")),
                req.get("max_tokens"))

    def get(self, key):
        if key is None:
            return None
        v = self._d.get(key)
        if v is not None:
            self.hits += 1
            self._d.pop(key)
            self._d[key] = v          # move to back
        else:
            self.misses += 1
        return v

    def put(self, key, value):
        if key is None:
            return
        if len(self._d) >= self.size:
            self._d.pop(next(iter(self._d)))
        self._d[key] = value


class WorkerPool:
    """M/D/c model of the API server's worker capacity."""

    def __init__(self, loop, workers: int, service_time: float,
                 max_queue: int = 1_000_000):
        self.loop = loop
        self.workers = workers
        self.service_time = service_time
        self.max_queue = max_queue
        self.busy = 0
        self.queue: deque = deque()
        self.rejected = 0
        self.max_depth = 0

    def submit(self, fn) -> bool:
        """fn(release) runs when a worker is free; fn MUST eventually call
        release() to return the worker."""
        if len(self.queue) >= self.max_queue:
            self.rejected += 1
            return False
        self.queue.append(fn)
        self.max_depth = max(self.max_depth, len(self.queue))
        self._pump()
        return True

    def _pump(self):
        while self.busy < self.workers and self.queue:
            fn = self.queue.popleft()
            self.busy += 1

            def _run(fn=fn):
                done = {"v": False}

                def release():
                    if not done["v"]:
                        done["v"] = True
                        self.busy -= 1
                        self._pump()

                fn(release)

            # the worker spends service_time of CPU before the handler logic
            self.loop.call_after(self.service_time, _run)


class InferenceGateway:
    def __init__(self, loop, auth: CachingAuthClient, router, compute,
                 policy: AccessPolicy | None = None,
                 config: GatewayConfig | None = None,
                 metrics: MetricsLog | None = None):
        self.loop = loop
        self.auth = auth
        self.router = router
        self.compute = compute
        self.policy = policy or AccessPolicy()
        self.config = config or GatewayConfig()
        self.metrics = metrics or MetricsLog()
        self.pool = WorkerPool(loop, self.config.workers,
                               self.config.request_cpu_time,
                               self.config.max_queue)
        self.rate = RateLimiter(loop, self.config.rate_limit_per_user,
                                self.config.rate_burst)
        self.cache = ResponseCache(self.config.response_cache_size)
        self._ids = itertools.count(1)
        self.hedges = 0

    # -- public API -------------------------------------------------------------
    def submit(self, token: str, request: dict) -> Future:
        """request: {model, prompt_tokens, max_tokens, api (optional),
        user hint ignored — identity comes from the token}."""
        fut = Future()
        rid = request.get("request_id") or f"gw-{next(self._ids)}"
        request = dict(request, request_id=rid)
        arrival = self.loop.now()

        api = request.get("api", "chat/completions")
        if api not in VALID_ENDPOINTS:
            fut.set_error(GatewayError(f"unknown endpoint {api!r}"))
            return fut
        if not self._validate(request):
            fut.set_error(GatewayError("invalid request payload"))
            return fut

        def handler(release):
            def finish_ok(result, cached=False):
                self.metrics.on_finish(
                    rid, self.loop.now(), result.get("output_tokens", 0),
                    cached=cached,
                    cached_prompt_tokens=result.get("cached_prompt_tokens",
                                                    0),
                    prefill_chunks=result.get("prefill_chunks", 0))
                if self.config.blocking_workers:
                    release()
                fut.set_result(result)

            def finish_err(err):
                self.metrics.on_finish(rid, self.loop.now(), ok=False,
                                       error=str(err))
                release()
                fut.set_error(err)

            def after_auth(ident):
                if isinstance(ident, AuthError):
                    return finish_err(ident)
                model = request["model"]
                self.metrics.on_arrival(rid, ident.user, model, arrival,
                                        request.get("prompt_tokens", 0))
                if not self.policy.allowed(ident, model):
                    return finish_err(GatewayError(
                        f"user {ident.user} lacks access to {model}"))
                if not self.rate.allow(ident.user):
                    return finish_err(GatewayError("rate limited"))
                ck = self.cache.key(request)
                hit = self.cache.get(ck)
                if hit is not None:
                    return finish_ok(dict(hit), cached=True)
                qos = request.get("qos", "interactive")
                payload = {"request_id": rid, "model": model,
                           "user": ident.user,
                           "prompt_tokens": request["prompt_tokens"],
                           "max_tokens": request["max_tokens"],
                           "qos": qos,
                           "priority": int(request.get("priority", 0)),
                           "deadline": request.get("deadline")}
                fn = "embed" if api == "embeddings" else "generate"
                state = {"done": False}

                def dispatch(exclude=()):
                    try:
                        ep = self.router.select_endpoint(model,
                                                         exclude=exclude,
                                                         qos=qos)
                    except Exception as e:
                        if not exclude:
                            finish_err(e)
                        return None
                    self.metrics.on_dispatch(rid, ep, self.loop.now())
                    pl = dict(payload) if exclude else payload
                    if exclude:     # hedge copies get distinct task ids
                        pl["request_id"] = f"{rid}~hedge"
                    task = self.compute.submit(ep, fn, pl)

                    def on_task(f):
                        if state["done"]:
                            return              # a racer already finished
                        state["done"] = True
                        if f.error is not None:
                            return finish_err(f.error)
                        res = f.result()
                        self.metrics.on_first_token(
                            rid, res.get("first_token_time",
                                         self.loop.now()))
                        self.cache.put(ck, res)
                        finish_ok(res)

                    if self.config.poll_interval > 0:
                        self._poll(task, on_task)   # pre-Optimization-1 mode
                    else:
                        task.add_done_callback(on_task)
                    return ep

                first_ep = dispatch()
                # Optimization 3: async workers release after dispatch
                if not self.config.blocking_workers:
                    release()
                if first_ep is not None and self.config.hedge_after:
                    def maybe_hedge():
                        if not state["done"]:
                            self.hedges += 1
                            dispatch(exclude=(first_ep,))

                    self.loop.call_after(self.config.hedge_after,
                                         maybe_hedge, daemon=True)

            self.auth.validate(token, after_auth)

        if not self.pool.submit(handler):
            fut.set_error(GatewayError("gateway queue full"))
        return fut

    def jobs_status(self) -> dict:
        """The /jobs endpoint (paper §4.3)."""
        return self.router.jobs_status()

    # -- helpers ---------------------------------------------------------------
    @staticmethod
    def _validate(request: dict) -> bool:
        try:
            return (request["model"]
                    and int(request["prompt_tokens"]) >= 0
                    and int(request["max_tokens"]) >= 1)
        except (KeyError, TypeError, ValueError):
            return False

    def _poll(self, task: Future, cb):
        """Pre-Optimization-1 result retrieval: check task status every
        ``poll_interval`` seconds."""
        def tick():
            if task.done():
                cb(task)
            else:
                self.loop.call_after(self.config.poll_interval, tick)
        self.loop.call_after(self.config.poll_interval, tick)
