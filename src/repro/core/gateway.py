"""The Inference Gateway: async OpenAI-compatible front end (paper §3.1).

Responsibilities reproduced from the paper: identity validation (with the
Optimization-2 introspection cache), request validation, per-user rate
limiting, response caching, conversion of API requests into compute tasks,
activity logging, and the /jobs status endpoint.

The public surface is the typed /v1 contract (``repro.api``): ``submit``
accepts a typed request (or a legacy dict, parsed through the same
schemas), resolves its future with a typed response carrying OpenAI
``usage`` accounting, and rejects with the stable ``APIError`` taxonomy —
``rate_limit_error`` denials compute a retry-after from the token bucket,
capacity exhaustion is ``overloaded``, unknown models are
``model_not_found``.

Streaming: a ``stream=true`` request takes an ``on_delta`` callback and
receives incremental ``StreamDelta`` frames as the engine emits tokens —
so first-token and inter-token latency are OBSERVED AT THE GATEWAY
(recorded per-request in ``MetricsLog``), not inferred from completion
records. ``cancel()`` propagates a client disconnect to the endpoint's
pre-registered abort function, freeing the engine slot. Hedged duplicates
race to the FIRST TOKEN: the loser is cancelled through the same abort
path instead of running to completion.

The worker pool models the Gunicorn/Uvicorn capacity. Three paper
optimizations are config toggles so benchmarks can ablate them:
  * Optimization 1 — ``poll_interval=0`` uses futures; ``>0`` polls task
    status on a timer (adds up to one interval of latency per request).
  * Optimization 2 — ``auth_cache`` on the CachingAuthClient +
    ``connection_cache`` on the ComputeClient.
  * Optimization 3 — ``blocking_workers=False`` (async Django-Ninja style:
    workers release after dispatch) vs ``True`` (sync Django-REST style:
    a worker is held for the request's whole lifetime; the paper's original
    deployment processed only nine requests at a time).
"""
from __future__ import annotations

import itertools
import random
from collections import deque
from dataclasses import dataclass, replace

from repro.api import schemas
from repro.api.errors import (APIError, AuthenticationError, DegradedError,
                              InvalidRequestError, ModelNotFoundError,
                              OverloadedError, RateLimitError,
                              RequestCancelled, UpstreamTimeoutError)
from repro.core.auth import AccessPolicy, AuthError, CachingAuthClient
from repro.core.clock import Future
from repro.core.compute import ComputeError
from repro.core.metrics import MetricsLog
from repro.core.resilience import (BreakerPolicy, BrownoutController,
                                   BrownoutPolicy, CircuitBreaker,
                                   RetryBudget, RetryPolicy)

VALID_ENDPOINTS = schemas.VALID_ENDPOINTS

# legacy alias: pre-/v1 callers caught GatewayError; every error the
# gateway raises now is an APIError subclass
GatewayError = APIError


@dataclass
class GatewayConfig:
    workers: int = 64                  # gunicorn workers x threads
    request_cpu_time: float = 0.002    # per-request gateway handling cost (s)
    blocking_workers: bool = False     # Optimization 3 toggle (True = sync)
    poll_interval: float = 0.0         # Optimization 1 toggle (>0 = polling)
    rate_limit_per_user: float = float("inf")   # req/s token bucket
    rate_burst: float = 100.0
    response_cache_size: int = 4096
    max_queue: int = 1_000_000
    # straggler mitigation (off by default): if a dispatched request has not
    # completed after this many seconds, hedge a duplicate to a DIFFERENT
    # endpoint; the duplicates race to the FIRST TOKEN and the loser is
    # cancelled (its engine slot frees instead of decoding to completion)
    hedge_after: float | None = None
    # resilience layer (all off by default; see repro.core.resilience):
    # retry = per-request retry budget with backoff+jitter and per-attempt
    # timeouts; a failed/timed-out attempt re-dispatches elsewhere, and a
    # stream that already delivered tokens RESUMES (resume_tokens) instead
    # of regenerating. breaker = per-endpoint circuit breakers feeding
    # select_endpoint exclusions. brownout = graceful degradation ladder.
    retry: RetryPolicy | None = None
    breaker: BreakerPolicy | None = None
    brownout: BrownoutPolicy | None = None
    retry_budget_ratio: float = 0.2    # global deposit per admitted request
    retry_seed: int = 0                # jitter rng (deterministic replays)


class RateLimiter:
    """Per-user token bucket."""

    def __init__(self, loop, rate: float, burst: float):
        self.loop = loop
        self.rate = rate
        self.burst = burst
        self._state: dict[str, tuple[float, float]] = {}   # user -> (tokens, t)
        self.denied = 0

    def acquire(self, user: str) -> tuple[bool, float]:
        """(allowed, retry_after): on denial, retry_after is the time until
        the bucket accrues the next whole request token. A zero rate is a
        valid drain-only config (burst requests, then nothing): once the
        burst is spent the bucket never refills, so retry_after is inf."""
        if self.rate == float("inf"):
            return True, 0.0
        now = self.loop.now()
        tokens, t = self._state.get(user, (self.burst, now))
        tokens = min(self.burst, tokens + (now - t) * self.rate)
        if tokens < 1.0:
            self._state[user] = (tokens, now)
            self.denied += 1
            wait = float("inf") if self.rate <= 0.0 \
                else (1.0 - tokens) / self.rate
            return False, wait
        self._state[user] = (tokens - 1.0, now)
        return True, 0.0

    def allow(self, user: str) -> bool:
        return self.acquire(user)[0]


class ResponseCache:
    """LRU cache for deterministic (temperature=0) repeated requests.

    Keys REQUIRE a content identity (an explicit ``prompt_hash`` or a hash
    of materialized token ids): two different prompts that merely share a
    token count must never share an entry, so count-only DES requests are
    uncacheable by construction."""

    def __init__(self, size: int):
        self.size = size
        self._d: dict = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(req):
        """``req`` is a typed /v1 request."""
        if req.temperature != 0.0:
            return None
        content = req.content_hash
        if content is None:          # no content identity -> not cacheable
            return None
        return (req.model, req.endpoint, content, req.max_tokens)

    def get(self, key):
        if key is None:
            return None
        v = self._d.get(key)
        if v is not None:
            self.hits += 1
            self._d.pop(key)
            self._d[key] = v          # move to back
        else:
            self.misses += 1
        return v

    def put(self, key, value):
        if key is None:
            return
        if len(self._d) >= self.size:
            self._d.pop(next(iter(self._d)))
        self._d[key] = value


class WorkerPool:
    """M/D/c model of the API server's worker capacity."""

    def __init__(self, loop, workers: int, service_time: float,
                 max_queue: int = 1_000_000):
        self.loop = loop
        self.workers = workers
        self.service_time = service_time
        self.max_queue = max_queue
        self.busy = 0
        self.queue: deque = deque()
        self.rejected = 0
        self.max_depth = 0

    def submit(self, fn) -> bool:
        """fn(release) runs when a worker is free; fn MUST eventually call
        release() to return the worker."""
        if len(self.queue) >= self.max_queue:
            self.rejected += 1
            return False
        self.queue.append(fn)
        self.max_depth = max(self.max_depth, len(self.queue))
        self._pump()
        return True

    def _pump(self):
        while self.busy < self.workers and self.queue:
            fn = self.queue.popleft()
            self.busy += 1

            def _run(fn=fn):
                done = {"v": False}

                def release():
                    if not done["v"]:
                        done["v"] = True
                        self.busy -= 1
                        self._pump()

                fn(release)

            # the worker spends service_time of CPU before the handler logic
            self.loop.call_after(self.service_time, _run)


class InferenceGateway:
    def __init__(self, loop, auth: CachingAuthClient, router, compute,
                 policy: AccessPolicy | None = None,
                 config: GatewayConfig | None = None,
                 metrics: MetricsLog | None = None,
                 batch=None):
        self.loop = loop
        self.auth = auth
        self.router = router
        self.compute = compute
        self.policy = policy or AccessPolicy()
        self.config = config or GatewayConfig()
        self.metrics = metrics or MetricsLog()
        self.batch = batch                 # BatchService for /v1/batches
        self.pool = WorkerPool(loop, self.config.workers,
                               self.config.request_cpu_time,
                               self.config.max_queue)
        self.rate = RateLimiter(loop, self.config.rate_limit_per_user,
                                self.config.rate_burst)
        self.cache = ResponseCache(self.config.response_cache_size)
        self._ids = itertools.count(1)
        self.hedges = 0
        # request_id -> in-flight race state (for cancel / hedging)
        self._active: dict[str, dict] = {}
        # resilience layer (see repro.core.resilience)
        self.retry_policy = self.config.retry
        self.retry_budget = (RetryBudget(self.config.retry_budget_ratio)
                             if self.config.retry is not None else None)
        self.breakers: dict[str, CircuitBreaker] = {}
        self._retry_rng = random.Random(self.config.retry_seed)
        self.brownout = (BrownoutController(self.config.brownout)
                         if self.config.brownout is not None else None)
        if self.brownout is not None:
            self._brownout_tick()

    # -- resilience helpers ------------------------------------------------------
    def _breaker(self, endpoint_id: str) -> CircuitBreaker | None:
        if self.config.breaker is None:
            return None
        b = self.breakers.get(endpoint_id)
        if b is None:
            b = self.breakers[endpoint_id] = \
                CircuitBreaker(endpoint_id, self.config.breaker)
        return b

    def _breaker_failure(self, endpoint_id: str, timeout: bool = False):
        b = self._breaker(endpoint_id)
        if b is None:
            return
        before = b.opens
        b.on_failure(self.loop.now(), timeout=timeout)
        if b.opens > before:
            self.metrics.on_breaker_open()

    def _breaker_success(self, endpoint_id: str):
        b = self._breaker(endpoint_id)
        if b is not None:
            b.on_success(self.loop.now())

    def _broken_endpoints(self) -> set:
        """Endpoints currently excluded by their breaker (side-effect-free:
        half-open probe slots are only consumed at dispatch)."""
        now = self.loop.now()
        return {e for e, b in self.breakers.items() if b.blocked(now)}

    def _brownout_tick(self):
        """Evaluate the degradation ladder: pressure is the max of the
        worker-pool backlog fraction and the unhealthy-capacity fraction."""
        backlog = len(self.pool.queue) / max(self.pool.workers * 4, 1)
        healthy = 1.0
        hf = getattr(self.router, "healthy_fraction", None)
        if callable(hf):
            healthy = hf()
        pressure = max(min(backlog, 1.0), 1.0 - healthy)
        self.brownout.observe(pressure, self.loop.now())
        self.loop.call_after(self.brownout.policy.eval_interval,
                             self._brownout_tick, daemon=True)

    # -- public API -------------------------------------------------------------
    def submit(self, token: str, request, on_delta=None) -> Future:
        """Serve one /v1 request. ``request`` is a typed
        ``repro.api.schemas`` request (or a legacy dict, parsed through the
        same schemas — unknown endpoints / malformed fields reject with
        ``invalid_request_error``). With ``stream=true``, ``on_delta``
        receives incremental ``StreamDelta`` frames; the returned future
        still resolves with the full typed response."""
        fut = Future()
        try:
            if isinstance(request, dict):
                request = schemas.parse_request(request)
            else:
                request = request.validate()
        except APIError as e:
            self.metrics.on_reject(e.code)
            fut.set_error(e)
            return fut
        rid = request.request_id or f"gw-{next(self._ids)}"
        request = replace(request, request_id=rid)
        arrival = self.loop.now()

        registry = getattr(self.router, "registry", None)
        if registry is not None and request.model not in registry:
            self.metrics.on_reject(ModelNotFoundError.code)
            fut.set_error(ModelNotFoundError(
                f"model {request.model!r} is not configured on any "
                "endpoint"))
            return fut

        if self.brownout is not None:
            # graceful degradation, declared steps: batch QoS is shed first
            # (level >= 1); at the deepest level admission tightens so work
            # cannot queue into a system that has lost its capacity
            if self.brownout.shed_batch() and request.qos == "batch":
                self.brownout.shed += 1
                self.metrics.on_brownout_shed()
                self.metrics.on_reject(DegradedError.code)
                fut.set_error(DegradedError(
                    f"gateway degraded (level {self.brownout.level}): "
                    "batch requests are shed until capacity recovers",
                    retry_after=self.brownout.policy.dwell))
                return fut
            cap = self.brownout.admission_cap(self.config.workers)
            if cap is not None and len(self.pool.queue) >= cap:
                self.brownout.shed += 1
                self.metrics.on_brownout_shed()
                self.metrics.on_reject(DegradedError.code)
                fut.set_error(DegradedError(
                    f"gateway degraded (level {self.brownout.level}): "
                    f"admission tightened to {cap} waiting requests",
                    retry_after=self.brownout.policy.dwell))
                return fut

        def handler(release):
            self._handle(release, token, request, fut, arrival, on_delta)

        if not self.pool.submit(handler):
            self.metrics.on_reject(OverloadedError.code)
            fut.set_error(OverloadedError(
                f"gateway queue full ({self.pool.max_queue} waiting)"))
        return fut

    def cancel(self, request_id: str) -> bool:
        """Client disconnect: abort the in-flight request everywhere it was
        dispatched (engine slots free immediately) and reject its future
        with ``request_cancelled``."""
        state = self._active.pop(request_id, None)
        if state is None or state["done"]:
            return False
        state["done"] = True
        if state.get("timer") is not None:
            self.loop.cancel(state["timer"])
            state["timer"] = None
        for ep, task_rid, _attempt in state["dispatched"]:
            self.compute.cancel(ep, task_rid)
        self.metrics.on_finish(request_id, self.loop.now(), ok=False,
                               error="client disconnected",
                               error_code=RequestCancelled.code)
        state["release"]()
        state["fut"].set_error(RequestCancelled(
            f"request {request_id} cancelled by the client"))
        return True

    # -- request pipeline -------------------------------------------------------
    def _handle(self, release, token, request, fut, arrival, on_delta):
        rid = request.request_id
        state = {"done": False, "winner": None, "dispatched": [],
                 "out_idx": 0, "delivered": 0, "fut": fut,
                 "release": release,
                 # retry layer: the CURRENT attempt number gates every
                 # event/completion callback, so a superseded attempt's
                 # stragglers can never corrupt the client stream
                 "attempt": 0, "tried": set(), "timer": None}

        def finish_ok(resp, cached=False):
            self._active.pop(rid, None)
            resp.cached = cached
            if cached:
                resp.id = rid          # the hit serves THIS request
            if cached and request.stream and on_delta is not None:
                # a response-cache hit streams back as one burst frame +
                # the finish frame (no engine was involved)
                now = self.loop.now()
                on_delta(schemas.StreamDelta(
                    id=rid, index=0, n_tokens=resp.usage.completion_tokens,
                    created=now))
                on_delta(schemas.StreamDelta(
                    id=rid, index=1, n_tokens=0, created=now, finished=True,
                    finish_reason="length"))
            self.metrics.on_finish(
                rid, self.loop.now(), resp.usage.completion_tokens,
                cached=cached,
                cached_prompt_tokens=resp.usage.cached_tokens,
                prefill_chunks=resp.prefill_chunks)
            if self.config.blocking_workers:
                release()
            fut.set_result(resp)

        def finish_err(err):
            if not isinstance(err, APIError):
                # taxonomy guarantee: a raw upstream failure (e.g. a
                # ComputeError from a crashed endpoint, retries exhausted)
                # still surfaces as a typed /v1 error
                err = APIError(f"upstream failure: {err}")
            self._active.pop(rid, None)
            code = err.code if isinstance(err, APIError) else ""
            self.metrics.on_finish(rid, self.loop.now(), ok=False,
                                   error=str(err), error_code=code)
            release()
            fut.set_error(err)

        def after_auth(ident):
            if isinstance(ident, AuthError):
                return finish_err(AuthenticationError(str(ident)))
            model = request.model
            self.metrics.on_arrival(rid, ident.user, model, arrival,
                                    request.prompt_token_count)
            if not self.policy.allowed(ident, model):
                return finish_err(AuthenticationError(
                    f"user {ident.user} lacks access to {model}"))
            allowed, wait = self.rate.acquire(ident.user)
            if not allowed:
                self.metrics.on_reject(RateLimitError.code)
                return finish_err(RateLimitError(
                    f"user {ident.user} exceeded "
                    f"{self.rate.rate:g} req/s", retry_after=wait))
            req = replace(request, user=ident.user)
            ck = self.cache.key(req)
            hit = self.cache.get(ck)
            if hit is not None:
                return finish_ok(hit.copy(), cached=True)
            self._active[rid] = state
            fn = "embed" if req.endpoint == "embeddings" else "generate"
            # the live back-channel carries first-token events whenever a
            # race needs deciding (hedging) or the client asked to stream
            want_events = req.stream or bool(self.config.hedge_after)
            policy = self.retry_policy
            if policy is not None:
                self.retry_budget.on_request()

            def _clear_timer():
                if state["timer"] is not None:
                    self.loop.cancel(state["timer"])
                    state["timer"] = None

            def _arm_timer(ep, task_rid, attempt, timeout):
                """Per-attempt progress bound: before the first token it is
                the (deadline-derived) TTFT timeout; once frames flow it is
                re-armed per frame with the stall bound. Firing kills the
                attempt and retries — the only recovery path from a SILENT
                endpoint death. (For non-streaming requests without a live
                channel the bound covers the whole attempt.)"""
                _clear_timer()
                if timeout is None:
                    return

                def fire():
                    state["timer"] = None
                    if state["done"] or attempt != state["attempt"]:
                        return
                    self.metrics.on_timeout(rid)
                    self._breaker_failure(ep, timeout=True)
                    self.compute.cancel(ep, task_rid)
                    retry_or_fail(UpstreamTimeoutError(
                        f"attempt {attempt + 1} on {ep} made no progress "
                        f"within {timeout:g}s"))

                state["timer"] = self.loop.call_after(timeout, fire)

            def _rearm_stall(ep, task_rid, attempt):
                if policy is None:
                    return
                _arm_timer(ep, task_rid, attempt, policy.stall_timeout)

            def _effective_attempts() -> int:
                if policy is None:
                    return 1
                n = policy.max_attempts
                if self.brownout is not None:
                    n = self.brownout.effective_attempts(n)
                return n

            def retry_or_fail(err):
                """A dispatch attempt failed (task error, timeout, or no
                placeable endpoint): back off and re-dispatch if the
                per-request allowance AND the global retry budget permit,
                else surface the error."""
                if state["done"]:
                    return
                if policy is not None \
                        and state["attempt"] + 1 < _effective_attempts() \
                        and self.retry_budget.try_withdraw():
                    old = state["attempt"]
                    for ep_, trid_, att_ in state["dispatched"]:
                        if att_ == old:     # stale racers (e.g. a hedge)
                            self.compute.cancel(ep_, trid_)
                    resumed = state["delivered"]
                    state["attempt"] = attempt = old + 1
                    state["winner"] = None
                    _clear_timer()
                    self.metrics.on_retry(rid, resumed_tokens=resumed)
                    delay = policy.backoff(attempt - 1, self._retry_rng)

                    def _go():
                        if state["done"] or attempt != state["attempt"]:
                            return
                        dispatch(exclude=frozenset(state["tried"])
                                 | self._broken_endpoints(),
                                 attempt=attempt)

                    self.loop.call_after(delay, _go)
                    return
                state["done"] = True
                _clear_timer()
                finish_err(err)

            def on_first_event(ep, attempt):
                def cb(task_rid, t_engine):
                    if state["done"] or attempt != state["attempt"]:
                        return
                    if state["winner"] is None:
                        state["winner"] = ep
                        self.metrics.on_first_token(rid, self.loop.now())
                        self._cancel_losers(state, ep)
                    # losing racers are cancelled; their events are dropped
                    if ep == state["winner"]:
                        _rearm_stall(ep, task_rid, attempt)
                return cb

            def on_delta_event(ep, attempt):
                def cb(frame):
                    if attempt != state["attempt"]:
                        return              # a superseded attempt's frame
                    if state["done"] and not frame.finished:
                        return
                    if state["winner"] is None:
                        state["winner"] = ep
                        self._cancel_losers(state, ep)
                    if ep != state["winner"]:
                        return
                    if not frame.finished:
                        _rearm_stall(ep, frame.id, attempt)
                    if frame.n_tokens:
                        # dedupe by stream offset: a fault-tolerance
                        # requeue restarts generation from token 0, so
                        # drop (or trim) re-emitted positions — the
                        # client never sees a token twice
                        end = frame.offset + frame.n_tokens
                        fresh = end - max(frame.offset, state["delivered"])
                        if fresh <= 0:
                            return
                        if fresh < frame.n_tokens:
                            toks = frame.tokens[-fresh:] \
                                if frame.tokens is not None else None
                            frame = replace(frame, n_tokens=fresh,
                                            tokens=toks,
                                            offset=end - fresh)
                        state["delivered"] = end
                    # renumber: the client sees ONE contiguous stream even
                    # if endpoint-side restarts re-emitted frames
                    frame = replace(frame, id=rid, index=state["out_idx"])
                    state["out_idx"] += 1
                    if not frame.finished:
                        self.metrics.on_delta(rid, self.loop.now(),
                                              frame.n_tokens)
                    if on_delta is not None:
                        on_delta(frame)
                return cb

            def dispatch(exclude=(), attempt=0, hedge=False):
                try:
                    ep = self.router.select_endpoint(model, exclude=exclude,
                                                     qos=req.qos)
                except Exception as e:           # noqa: BLE001
                    # FederationError already carries the 'overloaded' code
                    if hedge:
                        return None          # a failed hedge changes nothing
                    retry_or_fail(e)         # capacity may come back
                    return None
                b = self._breaker(ep)
                if b is not None:
                    b.allow(self.loop.now())   # consume the half-open probe
                self.metrics.on_dispatch(rid, ep, self.loop.now())
                task_rid = rid if not (hedge or attempt) else \
                    (f"{rid}~hedge" if hedge else f"{rid}~r{attempt}")
                wire_req = req if task_rid == rid \
                    else replace(req, request_id=task_rid)
                if attempt and state["delivered"]:
                    # mid-stream failover: the new engine RESUMES from what
                    # the client already holds (restore via prefix cache)
                    # instead of regenerating — the client sees a gap,
                    # never a duplicated or lost token
                    wire_req = replace(wire_req,
                                       resume_tokens=state["delivered"])
                task = self.compute.submit(
                    ep, fn, schemas.to_wire(wire_req),
                    on_first_token=(on_first_event(ep, attempt)
                                    if want_events else None),
                    on_delta=(on_delta_event(ep, attempt) if req.stream
                              else None))
                state["dispatched"].append((ep, task_rid, attempt))
                state["tried"].add(ep)
                if policy is not None and not hedge:
                    _arm_timer(ep, task_rid, attempt,
                               policy.timeout_for(attempt, self.loop.now(),
                                                  req.deadline))

                def on_task(f):
                    if state["done"]:
                        return              # a racer already finished
                    if attempt != state["attempt"]:
                        return              # attempt superseded by a retry
                    if state["winner"] is not None \
                            and ep != state["winner"]:
                        return              # the loser was cancelled
                    if f.error is not None:
                        if isinstance(f.error, RequestCancelled):
                            return          # our own abort (timeout/hedge)
                        self._breaker_failure(ep)
                        if isinstance(f.error, (ComputeError,
                                                OverloadedError)):
                            return retry_or_fail(f.error)
                        state["done"] = True
                        _clear_timer()
                        return finish_err(f.error)
                    state["done"] = True
                    _clear_timer()
                    self._breaker_success(ep)
                    res = f.result()
                    if not req.stream and not want_events:
                        # no live channel: fall back to the engine-side
                        # first-token stamp off the completion record
                        self.metrics.on_first_token(
                            rid, res.get("first_token_time", self.loop.now()))
                    resp = schemas.response_from_result(req, res, arrival)
                    if state["attempt"] == 0:
                        # resumed responses are stitched across engines;
                        # only clean single-attempt outputs enter the cache
                        self.cache.put(ck, resp)
                    finish_ok(resp)

                if self.config.poll_interval > 0:
                    self._poll(task, on_task)   # pre-Optimization-1 mode
                else:
                    task.add_done_callback(on_task)
                return ep

            first_ep = dispatch(exclude=self._broken_endpoints())
            # Optimization 3: async workers release after dispatch
            if not self.config.blocking_workers:
                release()
            if first_ep is not None and self.config.hedge_after:
                def maybe_hedge():
                    if state["done"] or state["winner"] is not None \
                            or state["attempt"] != 0:
                        return
                    if self.brownout is not None \
                            and self.brownout.suppress_hedges():
                        return              # degraded: hedges are shed
                    self.hedges += 1
                    dispatch(exclude=(first_ep,), hedge=True)

                self.loop.call_after(self.config.hedge_after,
                                     maybe_hedge, daemon=True)

        self.auth.validate(token, after_auth)

    def _cancel_losers(self, state: dict, winner_ep):
        """First-token-wins: abort every dispatched duplicate of the CURRENT
        attempt that is not the winner, freeing its engine slot mid-decode.
        (Prior attempts' tasks were already cancelled when they retried.)"""
        for ep, task_rid, attempt in state["dispatched"]:
            if attempt == state["attempt"] and ep != winner_ep:
                self.compute.cancel(ep, task_rid)
                self.metrics.on_hedge_cancelled()

    # -- /v1/batches ------------------------------------------------------------
    def create_batch(self, token: str, request) -> Future:
        """Submit an OpenAI-shaped batch (``BatchRequest`` or a list of
        item dicts); resolves with the initial ``BatchStatus``."""
        fut = Future()
        if self.batch is None:
            fut.set_error(InvalidRequestError(
                "this gateway has no batch service attached"))
            return fut
        try:
            if isinstance(request, dict):
                request = schemas.BatchRequest.from_dict(request)
            elif isinstance(request, list):
                request = schemas.BatchRequest(
                    items=[schemas.BatchItem.from_dict(it)
                           if isinstance(it, dict) else it
                           for it in request])
            request = request.validate()
            if not request.items:
                raise InvalidRequestError("empty batch", param="items")
        except APIError as e:
            self.metrics.on_reject(e.code)
            fut.set_error(e)
            return fut

        def after_auth(ident):
            if isinstance(ident, AuthError):
                return fut.set_error(AuthenticationError(str(ident)))
            model = request.model
            if not self.policy.allowed(ident, model):
                return fut.set_error(AuthenticationError(
                    f"user {ident.user} lacks access to {model}"))
            registry = getattr(self.router, "registry", None)
            if registry is not None and model not in registry:
                return fut.set_error(ModelNotFoundError(
                    f"model {model!r} is not configured on any endpoint"))
            job = self.batch.create(request, user=ident.user)
            fut.set_result(job.batch_status())

        self.auth.validate(token, after_auth)
        return fut

    def batch_status(self, batch_id: str):
        """Poll /v1/batches/{id}."""
        if self.batch is None:
            raise InvalidRequestError("no batch service attached")
        return self.batch.status(batch_id)

    def batch_results(self, batch_id: str) -> list:
        """Retrieve per-request results/errors of a finished batch."""
        if self.batch is None:
            raise InvalidRequestError("no batch service attached")
        return self.batch.results(batch_id)

    # -- status -----------------------------------------------------------------
    def jobs_status(self) -> dict:
        """The /jobs endpoint (paper §4.3): per-model instance states from
        the federation plus the gateway's own admission-control counters."""
        out = self.router.jobs_status()
        out["_gateway"] = {
            "workers_busy": self.pool.busy,
            "queue_depth": len(self.pool.queue),
            "max_depth": self.pool.max_depth,
            "rejected_queue_full": self.pool.rejected,
            "rate_limited": self.rate.denied,
            "rejections": dict(self.metrics.rejections),
            "hedges": self.hedges,
            "hedges_cancelled": self.metrics.hedges_cancelled,
            # resilience layer
            "degradation_level": (self.brownout.level
                                  if self.brownout is not None else 0),
            "retries": self.metrics.retries,
            "timeouts": self.metrics.timeouts,
            "failovers_resumed": self.metrics.failovers_resumed,
            "resumed_tokens": self.metrics.resumed_tokens,
            "breaker_opens": self.metrics.breaker_opens,
        }
        if self.brownout is not None:
            out["_gateway"]["degradation"] = self.brownout.snapshot()
        if self.breakers:
            now = self.loop.now()
            out["_gateway"]["breakers"] = {
                e: b.snapshot(now) for e, b in self.breakers.items()}
        if self.retry_budget is not None:
            out["_gateway"]["retry_budget"] = round(
                self.retry_budget.balance, 3)
        return out

    # -- helpers ---------------------------------------------------------------
    def _poll(self, task: Future, cb):
        """Pre-Optimization-1 result retrieval: check task status every
        ``poll_interval`` seconds."""
        def tick():
            if task.done():
                cb(task)
            else:
                self.loop.call_after(self.config.poll_interval, tick)
        self.loop.call_after(self.config.poll_interval, tick)
