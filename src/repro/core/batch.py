"""Batch mode (paper §4.4): a batch job runs as a DEDICATED cluster job that
loads the model solely for that task and processes the whole input file
offline — no shared online server, cold start amortized over the batch.

The /v1/batches surface wraps this in the OpenAI Batch API shape: submit an
NDJSON-style list of ``BatchItem``s (each a typed /v1 request body), poll a
``BatchStatus`` object, and retrieve per-request results — completed items
carry a typed response with usage accounting, invalid items carry a typed
error instead of failing the whole batch."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum

from repro.api import schemas
from repro.api.errors import APIError, InvalidRequestError
from repro.core.clock import Future
from repro.core.instances import ModelInstance, SimRequest

_batch_ids = itertools.count(1)


class BatchState(str, Enum):
    VALIDATING = "validating"
    QUEUED = "queued"
    IN_PROGRESS = "in_progress"
    COMPLETED = "completed"
    FAILED = "failed"


@dataclass
class BatchJob:
    batch_id: str
    model: str
    total: int
    state: BatchState = BatchState.VALIDATING
    completed: int = 0
    failed: int = 0
    output_tokens: int = 0
    submit_time: float = 0.0
    start_time: float = 0.0
    finish_time: float = 0.0
    user: str = ""
    future: Future = field(default_factory=Future)
    # custom_id -> {"response": typed response} | {"error": APIError dict}
    results_by_id: dict = field(default_factory=dict)

    def batch_status(self) -> schemas.BatchStatus:
        return schemas.BatchStatus(
            id=self.batch_id, status=self.state.value, model=self.model,
            created_at=self.submit_time, in_progress_at=self.start_time,
            completed_at=self.finish_time, total=self.total,
            completed=self.completed, failed=self.failed,
            output_tokens=self.output_tokens)

    def status(self) -> schemas.BatchStatus:
        """Legacy name; the returned BatchStatus also supports the old
        dict keys (``state``/``completed``/``total``/``output_tokens``)."""
        return self.batch_status()


class BatchService:
    """The /v1/batches endpoint backend."""

    def __init__(self, loop, router, endpoints, offline_slots: int = 256):
        self.loop = loop
        self.router = router
        self.endpoints = endpoints
        self.offline_slots = offline_slots
        self.jobs: dict[str, BatchJob] = {}

    # -- legacy entry point -----------------------------------------------------
    def submit_batch(self, model: str, requests: list[dict],
                     endpoint_id: str | None = None) -> BatchJob:
        """Pre-/v1 shape: JSONL-like dicts with request_id / prompt_tokens /
        max_tokens. Converted into typed BatchItems and served by
        ``create``."""
        items = []
        for r in requests:
            items.append(schemas.BatchItem(
                custom_id=str(r.get("request_id", f"item-{len(items)}")),
                body=dict(r, model=model)))
        return self.create(schemas.BatchRequest(items=items),
                           endpoint_id=endpoint_id)

    # -- /v1/batches ------------------------------------------------------------
    def create(self, request: schemas.BatchRequest,
               endpoint_id: str | None = None, user: str = "") -> BatchJob:
        bid = f"batch-{next(_batch_ids)}"
        model = request.model
        job = BatchJob(batch_id=bid, model=model, total=len(request.items),
                       submit_time=self.loop.now(), user=user)
        self.jobs[bid] = job
        if not request.items:
            job.state = BatchState.FAILED
            job.future.set_error(InvalidRequestError("empty batch"))
            return job

        # per-item parse + validation (BatchItem defers both): broken
        # items become per-request errors, the rest of the batch still
        # runs (OpenAI batch semantics)
        valid: list[tuple[schemas.BatchItem, object]] = []
        for it in request.items:
            try:
                valid.append((it, it.parsed_body()))
            except APIError as e:
                job.failed += 1
                job.results_by_id[it.custom_id] = {"error": e.to_dict()}
        if not valid:
            job.state = BatchState.FAILED
            job.finish_time = self.loop.now()
            job.future.set_error(InvalidRequestError(
                "every batch item failed validation"))
            return job

        ep_id = endpoint_id or self.router.select_endpoint(model, qos="batch")
        ep = self.endpoints[ep_id]
        dep = ep.deployments[model]
        job.state = BatchState.QUEUED

        # Dedicated instance: no idle timeout (released explicitly at the end),
        # offline-sized batch slots, loads the model solely for this job.
        inst = ModelInstance(
            self.loop, model, dep.cost, ep.scheduler,
            num_nodes=dep.nodes_per_instance, max_slots=self.offline_slots,
            idle_timeout=None)

        def on_done(item, body, result):
            job.completed += 1
            job.output_tokens += result["output_tokens"]
            result = dict(result, endpoint=ep_id)
            resp = schemas.response_from_result(body, result,
                                                job.submit_time)
            job.results_by_id[item.custom_id] = {"response": resp}
            if job.state == BatchState.QUEUED:
                job.state = BatchState.IN_PROGRESS
            if job.completed + job.failed >= job.total:
                job.state = BatchState.COMPLETED
                job.finish_time = self.loop.now()
                inst.release()
                job.future.set_result(job.batch_status())

        def on_first(t):
            if not job.start_time:
                job.start_time = t
                job.state = BatchState.IN_PROGRESS

        for it, body in valid:
            # batch jobs carry the batch QoS class end-to-end: on a shared
            # online engine (priority/preemption policies) they yield to
            # interactive traffic; on this dedicated instance the tag is
            # inert but keeps the accounting uniform
            sreq = SimRequest(request_id=body.request_id or it.custom_id,
                              prompt_tokens=body.prompt_token_count,
                              max_tokens=int(body.max_tokens),
                              user=user or body.user or "anonymous",
                              qos="batch",
                              priority=body.priority)
            inst.submit(sreq, on_first,
                        lambda result, it=it, body=body:
                            on_done(it, body, result))
        return job

    def status(self, batch_id: str) -> schemas.BatchStatus:
        job = self.jobs.get(batch_id)
        if job is None:
            return schemas.BatchStatus(id=batch_id, status="not_found")
        return job.batch_status()

    def results(self, batch_id: str) -> list[dict]:
        """Per-request outcomes in submission order of completion:
        ``{"custom_id", "response"}`` or ``{"custom_id", "error"}``."""
        job = self.jobs.get(batch_id)
        if job is None:
            return []
        return [{"custom_id": cid, **outcome}
                for cid, outcome in job.results_by_id.items()]
