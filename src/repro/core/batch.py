"""Batch mode (paper §4.4): a batch job runs as a DEDICATED cluster job that
loads the model solely for that task and processes the whole input file
offline — no shared online server, cold start amortized over the batch."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum

from repro.core.clock import Future
from repro.core.instances import ModelInstance, SimRequest

_batch_ids = itertools.count(1)


class BatchState(str, Enum):
    VALIDATING = "validating"
    QUEUED = "queued"
    IN_PROGRESS = "in_progress"
    COMPLETED = "completed"
    FAILED = "failed"


@dataclass
class BatchJob:
    batch_id: str
    model: str
    total: int
    state: BatchState = BatchState.VALIDATING
    completed: int = 0
    output_tokens: int = 0
    submit_time: float = 0.0
    start_time: float = 0.0
    finish_time: float = 0.0
    future: Future = field(default_factory=Future)

    def status(self) -> dict:
        return {"batch_id": self.batch_id, "state": self.state.value,
                "completed": self.completed, "total": self.total,
                "output_tokens": self.output_tokens}


class BatchService:
    """The /v1/batches endpoint backend."""

    def __init__(self, loop, router, endpoints, offline_slots: int = 256):
        self.loop = loop
        self.router = router
        self.endpoints = endpoints
        self.offline_slots = offline_slots
        self.jobs: dict[str, BatchJob] = {}

    def submit_batch(self, model: str, requests: list[dict],
                     endpoint_id: str | None = None) -> BatchJob:
        """requests: JSONL-like dicts with request_id/prompt_tokens/max_tokens."""
        bid = f"batch-{next(_batch_ids)}"
        job = BatchJob(batch_id=bid, model=model, total=len(requests),
                       submit_time=self.loop.now())
        self.jobs[bid] = job
        if not requests:
            job.state = BatchState.FAILED
            job.future.set_error(ValueError("empty batch"))
            return job
        ep_id = endpoint_id or self.router.select_endpoint(model, qos="batch")
        ep = self.endpoints[ep_id]
        dep = ep.deployments[model]
        job.state = BatchState.QUEUED

        # Dedicated instance: no idle timeout (released explicitly at the end),
        # offline-sized batch slots, loads the model solely for this job.
        inst = ModelInstance(
            self.loop, model, dep.cost, ep.scheduler,
            num_nodes=dep.nodes_per_instance, max_slots=self.offline_slots,
            idle_timeout=None)

        def on_done(result):
            job.completed += 1
            job.output_tokens += result["output_tokens"]
            if job.state == BatchState.QUEUED:
                job.state = BatchState.IN_PROGRESS
            if job.completed >= job.total:
                job.state = BatchState.COMPLETED
                job.finish_time = self.loop.now()
                inst.release()
                job.future.set_result(job.status())

        def on_first(t):
            if not job.start_time:
                job.start_time = t
                job.state = BatchState.IN_PROGRESS

        for r in requests:
            # batch jobs carry the batch QoS class end-to-end: on a shared
            # online engine (priority/preemption policies) they yield to
            # interactive traffic; on this dedicated instance the tag is
            # inert but keeps the accounting uniform
            sreq = SimRequest(request_id=r["request_id"],
                              prompt_tokens=int(r["prompt_tokens"]),
                              max_tokens=int(r["max_tokens"]),
                              qos=r.get("qos", "batch"),
                              priority=int(r.get("priority", 0)))
            inst.submit(sreq, on_first, on_done)
        return job

    def status(self, batch_id: str) -> dict:
        job = self.jobs.get(batch_id)
        return job.status() if job else {"batch_id": batch_id,
                                         "state": "not_found"}
