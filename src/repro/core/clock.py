"""Virtual clock + discrete-event loop driving the FIRST control plane.

Every control-plane component (gateway, scheduler, endpoints, instances,
autoscaler, failure injector) schedules callbacks on one EventLoop, so whole
workload traces run deterministically and instantly on CPU, while the same
components can be driven by a real clock in live deployments.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field


class VirtualClock:
    def __init__(self, t0: float = 0.0):
        self._t = t0

    def now(self) -> float:
        return self._t

    def _advance_to(self, t: float):
        assert t >= self._t - 1e-12, f"time went backwards: {t} < {self._t}"
        self._t = max(self._t, t)


class RealClock:
    def now(self) -> float:
        return time.monotonic()


@dataclass(order=True)
class _Event:
    t: float
    seq: int
    fn: object = field(compare=False)
    args: tuple = field(compare=False, default=())
    cancelled: bool = field(compare=False, default=False)
    daemon: bool = field(compare=False, default=False)


class EventLoop:
    def __init__(self, clock: VirtualClock | None = None):
        self.clock = clock or VirtualClock()
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self._non_daemon = 0

    def now(self) -> float:
        return self.clock.now()

    def call_at(self, t: float, fn, *args, daemon: bool = False) -> _Event:
        ev = _Event(t=max(t, self.now()), seq=next(self._seq), fn=fn,
                    args=args, daemon=daemon)
        heapq.heappush(self._heap, ev)
        if not daemon:
            self._non_daemon += 1
        return ev

    def call_after(self, dt: float, fn, *args, daemon: bool = False) -> _Event:
        return self.call_at(self.now() + dt, fn, *args, daemon=daemon)

    def cancel(self, ev: _Event):
        if ev is not None and not ev.cancelled:
            ev.cancelled = True
            if not ev.daemon:
                self._non_daemon -= 1

    def _pop_run(self, ev: _Event):
        if not ev.daemon:
            self._non_daemon -= 1
        self.clock._advance_to(ev.t)
        ev.fn(*ev.args)

    # -- running ------------------------------------------------------------
    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0].t <= t_end:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self._pop_run(ev)
        self.clock._advance_to(t_end)

    def run_until_idle(self, max_t: float = float("inf")) -> None:
        """Run until only daemon events (periodic monitors) remain."""
        while self._heap and self._non_daemon > 0:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if ev.t > max_t:
                heapq.heappush(self._heap, ev)
                break
            self._pop_run(ev)

    @property
    def pending(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)


class Future:
    """DES-friendly future (paper Optimization 1: results propagate through
    callbacks the moment they complete — no polling)."""

    __slots__ = ("_done", "_result", "_error", "_callbacks")

    def __init__(self):
        self._done = False
        self._result = None
        self._error = None
        self._callbacks = []

    def done(self) -> bool:
        return self._done

    def set_result(self, value):
        assert not self._done, "future already resolved"
        self._done = True
        self._result = value
        for cb in self._callbacks:
            cb(self)
        self._callbacks.clear()

    def set_error(self, err):
        assert not self._done
        self._done = True
        self._error = err
        for cb in self._callbacks:
            cb(self)
        self._callbacks.clear()

    def result(self):
        if not self._done:
            raise RuntimeError("future not resolved")
        if self._error is not None:
            raise self._error if isinstance(self._error, Exception) \
                else RuntimeError(self._error)
        return self._result

    @property
    def error(self):
        return self._error

    def add_done_callback(self, cb):
        if self._done:
            cb(self)
        else:
            self._callbacks.append(cb)

    def chain(self, other: "Future"):
        """Resolve ``other`` with this future's outcome."""
        def _cb(f):
            if f._error is not None:
                other.set_error(f._error)
            else:
                other.set_result(f._result)
        self.add_done_callback(_cb)
