"""Gateway-side resilience primitives: retry policy with backoff + jitter,
a Finagle-style global retry budget, per-endpoint circuit breakers, and the
graceful-brownout controller.

These are pure state machines over the virtual clock — the gateway owns
the orchestration (``InferenceGateway._handle``), this module owns the
decisions. Everything here is deterministic given the seed, so the chaos
gates in ``benchmarks/chaos_soak.py`` can assert exact accounting.
"""
from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# retries
# ---------------------------------------------------------------------------

@dataclass
class RetryPolicy:
    """Per-request retry configuration (attempt 0 is the initial dispatch).

    ``attempt_timeout`` bounds each attempt's time-to-first-token; when the
    request carries an absolute TTFT ``deadline`` the per-attempt timeout is
    derived from it instead (remaining time split across remaining
    attempts), so the budget tightens as attempts burn.  ``stall_timeout``
    bounds the gap between stream frames once tokens are flowing — the only
    way to notice a *silent* mid-stream death.
    """
    max_attempts: int = 3              # total attempts, initial + retries
    base_backoff: float = 0.5          # seconds; doubles per retry
    max_backoff: float = 8.0
    attempt_timeout: float | None = 30.0   # TTFT bound per attempt
    stall_timeout: float | None = None     # inter-frame bound mid-stream
    min_attempt_timeout: float = 0.25  # floor when a deadline shrinks it

    def backoff(self, retry_index: int, rng: random.Random) -> float:
        """Exponential backoff with FULL jitter (uniform over [0, cap]):
        decorrelated waves of retries instead of synchronized stampedes."""
        cap = min(self.max_backoff,
                  self.base_backoff * (2.0 ** max(retry_index, 0)))
        return rng.uniform(0.0, cap)

    def timeout_for(self, attempt: int, now: float,
                    deadline: float | None) -> float | None:
        """Per-attempt TTFT timeout. With a deadline, split what is left of
        it across the attempts that remain; otherwise the flat bound."""
        if deadline is not None:
            left = deadline - now
            remaining = max(self.max_attempts - attempt, 1)
            t = left / remaining
            if self.attempt_timeout is not None:
                t = min(t, self.attempt_timeout)
            return max(t, self.min_attempt_timeout)
        return self.attempt_timeout


class RetryBudget:
    """Global (gateway-wide) retry budget: every initial request deposits
    ``ratio`` tokens, every retry withdraws one.  Bounds cluster-wide retry
    amplification to ~``ratio`` of offered load when everything is failing —
    the failure mode where naive per-request retries multiply an outage.
    ``floor`` seeds the balance so low-traffic periods can still retry."""

    def __init__(self, ratio: float = 0.2, floor: float = 5.0,
                 cap: float = 100.0):
        self.ratio = ratio
        self.floor = floor
        self.cap = cap
        self.balance = float(floor)
        self.deposits = 0
        self.withdrawals = 0
        self.denied = 0

    def on_request(self) -> None:
        self.deposits += 1
        self.balance = min(self.cap, self.balance + self.ratio)

    def try_withdraw(self) -> bool:
        if self.balance >= 1.0:
            self.balance -= 1.0
            self.withdrawals += 1
            return True
        self.denied += 1
        return False


# ---------------------------------------------------------------------------
# circuit breakers
# ---------------------------------------------------------------------------

@dataclass
class BreakerPolicy:
    fail_threshold: int = 3            # consecutive failures to trip
    timeout_rate: float = 0.5          # or: timeout fraction over the window
    window: float = 60.0               # seconds of samples for the rate trip
    min_samples: int = 4               # rate trip needs this many samples
    cooldown: float = 10.0             # open duration before half-open probe
    max_cooldown: float = 120.0        # escalation cap on repeated re-trips


class CircuitBreaker:
    """Per-endpoint breaker: closed -> open -> half-open -> closed.

    * trips OPEN on ``fail_threshold`` consecutive failures, or when the
      timeout fraction over the sliding window exceeds ``timeout_rate``;
    * after ``cooldown`` it lets ONE probe through (half-open); a probe
      success closes it, a probe failure re-opens with the cooldown
      doubled (capped at ``max_cooldown``);
    * ``blocked(now)`` is the router-exclusion view: it never consumes the
      half-open probe, so computing exclusions has no side effects.
    """

    def __init__(self, endpoint_id: str, policy: BreakerPolicy | None = None):
        self.endpoint_id = endpoint_id
        self.policy = policy or BreakerPolicy()
        self.state = "closed"              # closed | open | half_open
        self.open_until = 0.0
        self.opens = 0                     # trip count (for the gates)
        self._consec = 0
        self._cooldown = self.policy.cooldown
        self._probe_inflight = False
        self._events: deque = deque()      # (t, ok, was_timeout)

    # -- observations ------------------------------------------------------
    def _prune(self, now: float) -> None:
        w = self.policy.window
        while self._events and self._events[0][0] < now - w:
            self._events.popleft()

    def on_success(self, now: float) -> None:
        self._events.append((now, True, False))
        self._prune(now)
        self._consec = 0
        if self.state != "closed":
            self.state = "closed"
            self._probe_inflight = False
            self._cooldown = self.policy.cooldown   # de-escalate on recovery

    def on_failure(self, now: float, timeout: bool = False) -> None:
        self._events.append((now, False, timeout))
        self._prune(now)
        self._consec += 1
        if self.state == "half_open":
            self._probe_inflight = False
            self._cooldown = min(self._cooldown * 2.0,
                                 self.policy.max_cooldown)
            self._trip(now)
            return
        if self.state == "closed" and (
                self._consec >= self.policy.fail_threshold
                or self._timeout_rate_exceeded()):
            self._trip(now)

    def _timeout_rate_exceeded(self) -> bool:
        if len(self._events) < self.policy.min_samples:
            return False
        timeouts = sum(1 for _, _, to in self._events if to)
        return timeouts / len(self._events) > self.policy.timeout_rate

    def _trip(self, now: float) -> None:
        self.state = "open"
        self.open_until = now + self._cooldown
        self.opens += 1

    # -- queries -----------------------------------------------------------
    def allow(self, now: float) -> bool:
        """May a dispatch go to this endpoint right now?  Transitions
        open -> half-open when the cooldown has elapsed and consumes the
        single half-open probe slot."""
        if self.state == "closed":
            return True
        if self.state == "open":
            if now < self.open_until:
                return False
            self.state = "half_open"
            self._probe_inflight = True
            return True
        # half-open: one probe at a time
        if self._probe_inflight:
            return False
        self._probe_inflight = True
        return True

    def blocked(self, now: float) -> bool:
        """Side-effect-free exclusion view for the federation router."""
        if self.state == "open":
            return now < self.open_until
        if self.state == "half_open":
            return self._probe_inflight
        return False

    def snapshot(self, now: float) -> dict:
        return {"state": self.state, "opens": self.opens,
                "consecutive_failures": self._consec,
                "cooldown": self._cooldown,
                "open_for": max(self.open_until - now, 0.0)
                if self.state == "open" else 0.0}


# ---------------------------------------------------------------------------
# graceful brownout
# ---------------------------------------------------------------------------

@dataclass
class BrownoutPolicy:
    """Hysteresis thresholds on the gateway's pressure signal (max of the
    worker-pool backlog fraction and the unhealthy-capacity fraction)."""
    enter_pressure: float = 0.7        # step a level UP at/above this
    exit_pressure: float = 0.3         # step a level DOWN at/below this
    dwell: float = 10.0                # min seconds between level changes
    eval_interval: float = 5.0         # how often the gateway evaluates


class BrownoutController:
    """Declared degradation ladder, stepped one level at a time:

      level 0  normal operation
      level 1  shed batch QoS at admission (``degraded`` errors)
      level 2  + suppress hedging, halve the retry allowance
      level 3  + retries off, admission queue tightened

    ``observe(pressure, now)`` drives the ladder with hysteresis (distinct
    enter/exit thresholds + a dwell time) so the level cannot flap on a
    noisy signal.  Every transition is recorded for ``jobs_status()``."""

    MAX_LEVEL = 3
    STEPS = {0: "normal", 1: "shed-batch", 2: "no-hedge/half-retries",
             3: "no-retries/tight-admission"}

    def __init__(self, policy: BrownoutPolicy | None = None):
        self.policy = policy or BrownoutPolicy()
        self.level = 0
        self._last_change = float("-inf")
        self.transitions: list[tuple[float, int, float]] = []  # (t, lvl, p)
        self.shed = 0                       # requests rejected by brownout

    def observe(self, pressure: float, now: float) -> int:
        p = self.policy
        if now - self._last_change >= p.dwell:
            if pressure >= p.enter_pressure and self.level < self.MAX_LEVEL:
                self.level += 1
                self._last_change = now
                self.transitions.append((now, self.level, pressure))
            elif pressure <= p.exit_pressure and self.level > 0:
                self.level -= 1
                self._last_change = now
                self.transitions.append((now, self.level, pressure))
        return self.level

    # -- degradation queries (what each level actually sheds) --------------
    def shed_batch(self) -> bool:
        return self.level >= 1

    def suppress_hedges(self) -> bool:
        return self.level >= 2

    def effective_attempts(self, configured: int) -> int:
        """Retry allowance under degradation: full, halved, then none."""
        if self.level >= 3:
            return 1
        if self.level >= 2:
            return max(1 + (configured - 1) // 2, 1)
        return configured

    def admission_cap(self, workers: int) -> int | None:
        """Tightened gateway queue bound at the deepest level: a request
        that would wait behind more than a few service times is rejected
        up front instead of queueing into a dead system."""
        if self.level >= 3:
            return max(workers * 4, 8)
        return None

    def snapshot(self) -> dict:
        return {"level": self.level, "step": self.STEPS[self.level],
                "shed": self.shed,
                "transitions": len(self.transitions)}
