"""Federation layer: the §4.5 priority-based endpoint-selection algorithm.

  1. prefer an endpoint where the model is already running or queued/starting
     (low latency: hot instances exist);
  2. else an endpoint whose cluster has enough free nodes to start one;
  3. else the FIRST endpoint configured for the model (registry order).

Within rules 1 and 2 ties are broken by cluster load — shallowest
scheduler queue first, then most available nodes, then registry
(configuration) order — so a hot-but-drowning cluster no longer wins over
an equally hot idle one just by being listed first. Each decision records
``(model, endpoint, rule, detail)`` with the tie-break inputs (and the
request's QoS class when the caller supplies one) for the /jobs audit
trail.

Endpoint health (faults.py) filters dead endpoints out before the scan.
"""
from __future__ import annotations

from repro.api.errors import OverloadedError
from repro.core.compute import ComputeEndpoint


class FederationError(OverloadedError):
    """No healthy endpoint can serve the model right now. Part of the /v1
    taxonomy as ``overloaded`` (HTTP 503): the model is configured, but the
    federation cannot place the request — clients should back off and
    retry. (A model missing from the registry entirely is the gateway's
    ``model_not_found``.)"""


class FederationRouter:
    def __init__(self, endpoints: dict[str, ComputeEndpoint],
                 registry: dict[str, list[str]]):
        """registry: model -> endpoint ids in priority (configuration) order."""
        self.endpoints = endpoints
        self.registry = registry
        self._healthy: dict[str, bool] = {e: True for e in endpoints}
        self._slow: dict[str, bool] = {}
        # (model, endpoint, rule, detail) — detail holds the tie-break
        # inputs (queue depth / free nodes) and the request's QoS class
        self.decisions: list[tuple[str, str, str, str]] = []

    # -- health feed (from HealthMonitor) ----------------------------------------
    def set_healthy(self, endpoint_id: str, healthy: bool):
        self._healthy[endpoint_id] = healthy

    def set_slow(self, endpoint_id: str, slow: bool):
        """Straggler flag (beat-latency EWMA over threshold): slow endpoints
        stay eligible but lose every tie-break, so traffic drains to prompt
        replicas whenever one exists."""
        self._slow[endpoint_id] = slow

    def healthy_fraction(self) -> float:
        """Share of registered endpoints currently believed healthy — one of
        the gateway's brownout pressure signals."""
        if not self.endpoints:
            return 1.0
        return sum(1 for e in self.endpoints
                   if self._healthy.get(e, False)) / len(self.endpoints)

    def _candidates(self, model: str) -> list[str]:
        eps = [e for e in self.registry.get(model, ())
               if self._healthy.get(e, False)
               and self.endpoints[e].hosts(model)]
        if not eps:
            raise FederationError(f"no healthy endpoint hosts {model!r}")
        return eps

    # -- disaggregated roles ------------------------------------------------------
    def _role_of(self, e: str, model: str) -> str:
        dep = getattr(self.endpoints[e], "deployments", {}).get(model)
        return getattr(dep, "role", "unified")

    def _filter_roles(self, eps: list[str], model: str,
                      role: str | None) -> list[str]:
        """Role filter for disaggregated pools: fresh dispatches
        (role=None) need prefill capability, so decode-heavy endpoints are
        skipped while an alternative exists; handoffs (role='decode')
        prefer a dedicated decode pool, fall back to unified, and avoid
        prefill-heavy endpoints. With every candidate filtered out the
        original list survives — serving degraded beats not serving."""
        if role == "decode":
            capable = [e for e in eps
                       if self._role_of(e, model) != "prefill-heavy"]
            dedicated = [e for e in capable
                         if self._role_of(e, model) == "decode-heavy"]
            return dedicated or capable or eps
        capable = [e for e in eps
                   if self._role_of(e, model) != "decode-heavy"]
        return capable or eps

    def _warm(self, e: str, model: str) -> bool:
        return "running" in self.endpoints[e].model_states(model)

    def _cold_penalty(self, e: str, model: str) -> float:
        """Cold-start latency a request pays when routed to ``e`` with no
        hot instance: the scheduler's job startup plus the weight load
        (``cost.load_time``). Zero for a warm pool."""
        if self._warm(e, model):
            return 0.0
        ep = self.endpoints[e]
        dep = getattr(ep, "deployments", {}).get(model)
        cost = getattr(dep, "cost", None)
        load = cost.load_time() if cost is not None else 0.0
        return getattr(ep.scheduler, "startup_delay", 0.0) + load

    def _load_key(self, e: str) -> tuple[bool, int, int]:
        sched = self.endpoints[e].scheduler
        return (self._slow.get(e, False), sched.queue_depth(),
                -sched.available_nodes())

    def _pick(self, cands: list[str]) -> tuple[str, str]:
        """Tie-break within a rule: non-straggler first, then shallowest
        scheduler queue, then most free nodes, then registry order (strict
        < keeps the scan stable)."""
        best = cands[0]
        for e in cands[1:]:
            if self._load_key(e) < self._load_key(best):
                best = e
        slow, qd, neg_free = self._load_key(best)
        detail = f"queue_depth={qd},free_nodes={-neg_free}"
        if slow:
            detail += ",slow=1"
        return best, detail

    def _record(self, model: str, ep: str, rule: str, detail: str,
                qos: str | None, role: str | None = None) -> str:
        parts = [detail] if detail else []
        if qos:
            parts.append(f"qos={qos}")
        if role:
            parts.append(f"role={role}")
        self.decisions.append((model, ep, rule, ",".join(parts)))
        return ep

    # -- the §4.5 algorithm ---------------------------------------------------------
    def select_endpoint(self, model: str, exclude=(),
                        qos: str | None = None,
                        role: str | None = None) -> str:
        """``role``: None for a fresh dispatch (needs prefill capability),
        'decode' when placing the decode leg of a prefill->decode
        handoff."""
        eps = self._candidates(model)
        if exclude:
            eps = [e for e in eps if e not in exclude] or eps
        eps = self._filter_roles(eps, model, role)
        # rule 1: model already running or queued somewhere; ties broken
        # by cluster load (queue depth, then free nodes)
        active = [e for e in eps
                  if any(s in ("running", "starting", "queued")
                         for s in self.endpoints[e].model_states(model))]
        if active:
            if qos == "interactive":
                # TTFT-sensitive traffic prefers a WARM pool: a merely
                # starting/queued instance still costs the cold-start tail
                warm = [e for e in active if self._warm(e, model)]
                if warm and len(warm) < len(active):
                    pick, detail = self._pick(warm)
                    return self._record(model, pick, "active-instance",
                                        detail + ",warm=1", qos, role)
            pick, detail = self._pick(active)
            return self._record(model, pick, "active-instance", detail,
                                qos, role)
        # rule 2: a cluster with available nodes, same tie-break —
        # interactive requests first narrow to the cheapest cold start
        # (startup + cost.load_time), which every rule-2 placement pays
        free = []
        for e in eps:
            ep = self.endpoints[e]
            need = ep.deployments[model].nodes_per_instance
            if ep.scheduler.available_nodes() >= need:
                free.append(e)
        if free:
            if qos == "interactive" and len(free) > 1:
                best = min(self._cold_penalty(e, model) for e in free)
                free = [e for e in free
                        if self._cold_penalty(e, model) <= best + 1e-9]
            pick, detail = self._pick(free)
            if qos == "interactive":
                detail += (f",cold_penalty="
                           f"{self._cold_penalty(pick, model):.0f}s")
            return self._record(model, pick, "free-nodes", detail, qos,
                                role)
        # rule 3: first configured endpoint
        return self._record(model, eps[0], "configured-order", "", qos, role)

    # -- /jobs view across the federation -----------------------------------------
    def jobs_status(self) -> dict:
        """Per-model instance states, each entry annotated with the
        tie-break signals the §4.5 selection actually uses (cluster queue
        depth / free nodes) plus the endpoint's health flag."""
        out = {}
        for model, eps in self.registry.items():
            entries = []
            for e in eps:
                if e in self.endpoints:
                    ep = self.endpoints[e]
                    _slow, qd, neg_free = self._load_key(e)
                    for s in ep.model_states(model):
                        entries.append({"endpoint": e, "state": s,
                                        "healthy": self._healthy.get(e,
                                                                     False),
                                        "queue_depth": qd,
                                        "free_nodes": -neg_free,
                                        "load": ep.load_for(model)})
            if not entries:
                # cold model: same shape as live entries (consumers index
                # these keys unconditionally), zeros where nothing runs
                e0 = eps[0] if eps else "?"
                if e0 in self.endpoints:
                    _slow, qd, neg_free = self._load_key(e0)
                else:
                    qd, neg_free = 0, 0
                entries = [{"endpoint": e0, "state": "cold",
                            "healthy": self._healthy.get(e0, False),
                            "queue_depth": qd, "free_nodes": -neg_free,
                            "load": 0}]
            out[model] = entries
        return out
