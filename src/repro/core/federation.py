"""Federation layer: the §4.5 priority-based endpoint-selection algorithm.

  1. prefer an endpoint where the model is already running or queued/starting
     (low latency: hot instances exist);
  2. else an endpoint whose cluster has enough free nodes to start one;
  3. else the FIRST endpoint configured for the model (registry order).

Endpoint health (faults.py) filters dead endpoints out before the scan.
"""
from __future__ import annotations

from repro.core.compute import ComputeEndpoint


class FederationError(Exception):
    pass


class FederationRouter:
    def __init__(self, endpoints: dict[str, ComputeEndpoint],
                 registry: dict[str, list[str]]):
        """registry: model -> endpoint ids in priority (configuration) order."""
        self.endpoints = endpoints
        self.registry = registry
        self._healthy: dict[str, bool] = {e: True for e in endpoints}
        self.decisions: list[tuple[str, str, str]] = []   # (model, ep, rule)

    # -- health feed (from HealthMonitor) ----------------------------------------
    def set_healthy(self, endpoint_id: str, healthy: bool):
        self._healthy[endpoint_id] = healthy

    def _candidates(self, model: str) -> list[str]:
        eps = [e for e in self.registry.get(model, ())
               if self._healthy.get(e, False)
               and self.endpoints[e].hosts(model)]
        if not eps:
            raise FederationError(f"no healthy endpoint hosts {model!r}")
        return eps

    # -- the §4.5 algorithm ---------------------------------------------------------
    def select_endpoint(self, model: str, exclude=()) -> str:
        eps = self._candidates(model)
        if exclude:
            eps = [e for e in eps if e not in exclude] or eps
        # rule 1: model already running or queued somewhere
        for e in eps:
            states = self.endpoints[e].model_states(model)
            if any(s in ("running", "starting", "queued") for s in states):
                self.decisions.append((model, e, "active-instance"))
                return e
        # rule 2: a cluster with available nodes
        for e in eps:
            ep = self.endpoints[e]
            need = ep.deployments[model].nodes_per_instance
            if ep.scheduler.available_nodes() >= need:
                self.decisions.append((model, e, "free-nodes"))
                return e
        # rule 3: first configured endpoint
        self.decisions.append((model, eps[0], "configured-order"))
        return eps[0]

    # -- /jobs view across the federation -----------------------------------------
    def jobs_status(self) -> dict:
        out = {}
        for model, eps in self.registry.items():
            entries = []
            for e in eps:
                if e in self.endpoints:
                    for s in self.endpoints[e].model_states(model):
                        entries.append({"endpoint": e, "state": s})
            out[model] = entries or [{"endpoint": eps[0] if eps else "?",
                                      "state": "cold"}]
        return out
