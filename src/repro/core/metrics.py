"""Request metrics log + summary statistics (paper §5.1 metrics)."""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field


@dataclass
class RequestRecord:
    request_id: str
    user: str = ""
    model: str = ""
    endpoint: str = ""
    arrival: float = 0.0
    dispatched: float = 0.0
    first_token: float = 0.0
    finish: float = 0.0
    prompt_tokens: int = 0
    output_tokens: int = 0
    ok: bool = True
    error: str = ""
    error_code: str = ""            # stable /v1 taxonomy code, "" when ok
    cached: bool = False
    cached_prompt_tokens: int = 0   # engine prefix-cache reuse (partial hit)
    prefill_chunks: int = 0         # chunked-prefill steps for this prompt
    # streaming observability (only populated for stream=true requests):
    # frames received at the GATEWAY and the gaps between them — TTFT/ITL
    # as the API boundary sees them, network hop included
    streamed: bool = False
    stream_frames: int = 0
    itl: list = field(default_factory=list)
    # resilience accounting (gateway retry layer)
    attempts: int = 1               # dispatch attempts (1 = no retries)
    timeouts: int = 0               # attempts killed by the TTFT/stall bound
    resumed_tokens: int = 0         # tokens carried across a failover resume

    @property
    def e2e(self) -> float:
        return self.finish - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival


class MetricsLog:
    """The gateway's PostgreSQL-activity-log analogue + live dashboard stats."""

    def __init__(self):
        self.records: list[RequestRecord] = []
        self._open: dict[str, RequestRecord] = {}
        # gateway admission-control counters, keyed by /v1 error code
        # (rate_limit_error, overloaded, ...): rejections never reach an
        # endpoint, so they are visible ONLY here and in jobs_status()
        self.rejections: dict[str, int] = {}
        # hedged duplicates cancelled after losing the first-token race
        # (instead of running to completion and burning engine slots)
        self.hedges_cancelled = 0
        # resilience counters (gateway retry/breaker/brownout layer); the
        # chaos gates cross-check these against per-record accounting
        self.retries = 0                # re-dispatches after a failure
        self.timeouts = 0               # attempts killed by a timeout
        self.failovers_resumed = 0      # retries that resumed mid-stream
        self.resumed_tokens = 0         # tokens carried across failovers
        self.breaker_opens = 0          # circuit-breaker trips
        self.brownout_shed = 0          # requests shed by degradation

    # -- lifecycle hooks ------------------------------------------------------
    def on_arrival(self, request_id, user, model, t, prompt_tokens=0):
        r = RequestRecord(request_id=request_id, user=user, model=model,
                          arrival=t, prompt_tokens=prompt_tokens)
        self._open[request_id] = r
        return r

    def on_dispatch(self, request_id, endpoint, t):
        r = self._open.get(request_id)
        if r:
            r.dispatched = t
            r.endpoint = endpoint

    def on_first_token(self, request_id, t):
        r = self._open.get(request_id)
        if r and not r.first_token:
            r.first_token = t

    def on_delta(self, request_id, t, n_tokens=1):
        """A stream frame reached the gateway: record TTFT on the first and
        the inter-frame gap on every later one."""
        r = self._open.get(request_id)
        if r is None:
            return
        r.streamed = True
        if r.stream_frames > 0:
            r.itl.append(t - r._last_frame)
        elif not r.first_token:
            r.first_token = t
        r.stream_frames += 1
        r._last_frame = t

    def on_reject(self, code: str):
        """An admission-control rejection (never dispatched)."""
        self.rejections[code] = self.rejections.get(code, 0) + 1

    def on_hedge_cancelled(self):
        self.hedges_cancelled += 1

    # -- resilience hooks -------------------------------------------------------
    def on_retry(self, request_id, resumed_tokens: int = 0):
        """A failed/timed-out attempt is being re-dispatched; nonzero
        ``resumed_tokens`` means the retry resumes a live stream."""
        self.retries += 1
        if resumed_tokens > 0:
            self.failovers_resumed += 1
            self.resumed_tokens += resumed_tokens
        r = self._open.get(request_id)
        if r:
            r.attempts += 1
            r.resumed_tokens = max(r.resumed_tokens, resumed_tokens)

    def on_timeout(self, request_id):
        self.timeouts += 1
        r = self._open.get(request_id)
        if r:
            r.timeouts += 1

    def on_breaker_open(self):
        self.breaker_opens += 1

    def on_brownout_shed(self):
        self.brownout_shed += 1

    def on_finish(self, request_id, t, output_tokens=0, ok=True, error="",
                  cached=False, cached_prompt_tokens=0, prefill_chunks=0,
                  error_code=""):
        r = self._open.pop(request_id, None)
        if r is None:
            return
        r.finish = t
        r.output_tokens = output_tokens
        r.ok = ok
        r.error = error
        r.error_code = error_code
        r.cached = cached
        r.cached_prompt_tokens = cached_prompt_tokens
        r.prefill_chunks = prefill_chunks
        self.records.append(r)

    # -- summaries --------------------------------------------------------------
    def summary(self, t0: float | None = None, t1: float | None = None) -> dict:
        recs = [r for r in self.records if r.ok]
        if t0 is not None:
            recs = [r for r in recs if r.finish >= t0]
        if t1 is not None:
            recs = [r for r in recs if r.finish <= t1]
        if not recs:
            return {"completed": 0}
        start = t0 if t0 is not None else min(r.arrival for r in recs)
        end = t1 if t1 is not None else max(r.finish for r in recs)
        dur = max(end - start, 1e-9)
        toks = sum(r.output_tokens for r in recs)
        prompt_toks = sum(r.prompt_tokens for r in recs)
        cached_toks = sum(r.cached_prompt_tokens for r in recs)
        return {
            "prompt_tokens": prompt_toks,
            "cached_prompt_tokens": cached_toks,
            "prefix_cache_hit_rate": (cached_toks / prompt_toks
                                      if prompt_toks else 0.0),
            "completed": len(recs),
            "failed": sum(1 for r in self.records if not r.ok),
            "duration_s": dur,
            "req_per_s": len(recs) / dur,
            "output_tok_per_s": toks / dur,
            "median_e2e_s": statistics.median(r.e2e for r in recs),
            "mean_e2e_s": statistics.fmean(r.e2e for r in recs),
            "p90_e2e_s": sorted(r.e2e for r in recs)[int(0.9 * (len(recs) - 1))],
            "median_ttft_s": statistics.median(
                r.ttft for r in recs if r.first_token),
            "output_tokens": toks,
            **self._stream_stats(recs),
        }

    def _stream_stats(self, recs) -> dict:
        """Gateway-observed streaming latencies (stream=true requests)."""
        gaps = [g for r in recs if r.streamed for g in r.itl]
        streamed = [r for r in recs if r.streamed and r.first_token]
        out = {"streamed": sum(1 for r in recs if r.streamed),
               "hedges_cancelled": self.hedges_cancelled,
               "rejections": dict(self.rejections),
               "retries": self.retries,
               "timeouts": self.timeouts,
               "failovers_resumed": self.failovers_resumed,
               "resumed_tokens": self.resumed_tokens,
               "breaker_opens": self.breaker_opens}
        if streamed:
            out["stream_median_ttft_s"] = statistics.median(
                r.ttft for r in streamed)
        if gaps:
            gaps.sort()
            out["stream_median_itl_s"] = statistics.median(gaps)
            out["stream_p99_itl_s"] = gaps[int(0.99 * (len(gaps) - 1))]
        return out
