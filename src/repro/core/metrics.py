"""Request metrics log + summary statistics (paper §5.1 metrics)."""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field


@dataclass
class RequestRecord:
    request_id: str
    user: str = ""
    model: str = ""
    endpoint: str = ""
    arrival: float = 0.0
    dispatched: float = 0.0
    first_token: float = 0.0
    finish: float = 0.0
    prompt_tokens: int = 0
    output_tokens: int = 0
    ok: bool = True
    error: str = ""
    cached: bool = False
    cached_prompt_tokens: int = 0   # engine prefix-cache reuse (partial hit)
    prefill_chunks: int = 0         # chunked-prefill steps for this prompt

    @property
    def e2e(self) -> float:
        return self.finish - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival


class MetricsLog:
    """The gateway's PostgreSQL-activity-log analogue + live dashboard stats."""

    def __init__(self):
        self.records: list[RequestRecord] = []
        self._open: dict[str, RequestRecord] = {}

    # -- lifecycle hooks ------------------------------------------------------
    def on_arrival(self, request_id, user, model, t, prompt_tokens=0):
        r = RequestRecord(request_id=request_id, user=user, model=model,
                          arrival=t, prompt_tokens=prompt_tokens)
        self._open[request_id] = r
        return r

    def on_dispatch(self, request_id, endpoint, t):
        r = self._open.get(request_id)
        if r:
            r.dispatched = t
            r.endpoint = endpoint

    def on_first_token(self, request_id, t):
        r = self._open.get(request_id)
        if r and not r.first_token:
            r.first_token = t

    def on_finish(self, request_id, t, output_tokens=0, ok=True, error="",
                  cached=False, cached_prompt_tokens=0, prefill_chunks=0):
        r = self._open.pop(request_id, None)
        if r is None:
            return
        r.finish = t
        r.output_tokens = output_tokens
        r.ok = ok
        r.error = error
        r.cached = cached
        r.cached_prompt_tokens = cached_prompt_tokens
        r.prefill_chunks = prefill_chunks
        self.records.append(r)

    # -- summaries --------------------------------------------------------------
    def summary(self, t0: float | None = None, t1: float | None = None) -> dict:
        recs = [r for r in self.records if r.ok]
        if t0 is not None:
            recs = [r for r in recs if r.finish >= t0]
        if t1 is not None:
            recs = [r for r in recs if r.finish <= t1]
        if not recs:
            return {"completed": 0}
        start = t0 if t0 is not None else min(r.arrival for r in recs)
        end = t1 if t1 is not None else max(r.finish for r in recs)
        dur = max(end - start, 1e-9)
        toks = sum(r.output_tokens for r in recs)
        prompt_toks = sum(r.prompt_tokens for r in recs)
        cached_toks = sum(r.cached_prompt_tokens for r in recs)
        return {
            "prompt_tokens": prompt_toks,
            "cached_prompt_tokens": cached_toks,
            "prefix_cache_hit_rate": (cached_toks / prompt_toks
                                      if prompt_toks else 0.0),
            "completed": len(recs),
            "failed": sum(1 for r in self.records if not r.ok),
            "duration_s": dur,
            "req_per_s": len(recs) / dur,
            "output_tok_per_s": toks / dur,
            "median_e2e_s": statistics.median(r.e2e for r in recs),
            "mean_e2e_s": statistics.fmean(r.e2e for r in recs),
            "p90_e2e_s": sorted(r.e2e for r in recs)[int(0.9 * (len(recs) - 1))],
            "median_ttft_s": statistics.median(
                r.ttft for r in recs if r.first_token),
            "output_tokens": toks,
        }
