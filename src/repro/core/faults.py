"""Fault tolerance: failure injection (nodes, instances, endpoints,
heartbeat loss, latency, correlated racks) and the heartbeat-driven health
monitor that feeds endpoint liveness into the federation router.

Detection is OBSERVED, not scripted: ``ComputeEndpoint.attach_monitor``
makes each endpoint emit periodic beats over the (injectable) network; the
monitor derives liveness from missed beats, recovery from the first beat
seen again, and a straggler flag from the beat-latency EWMA. The router is
only ever told about *transitions* — the monitor never blanket-rewrites
health, so an outage injected directly into the router (or a manual
``mark_down``) persists until its owner lifts it.

Instance/process restart + in-flight resume lives in ComputeEndpoint;
gateway-side retries/breakers live in ``repro.core.resilience``; this
module provides the chaos and the detection.
"""
from __future__ import annotations

import random


class FailureInjector:
    def __init__(self, loop, seed: int = 0):
        self.loop = loop
        self.rng = random.Random(seed)
        self.injected: list[tuple[float, str]] = []

    # -- targeted ---------------------------------------------------------------
    def fail_node_at(self, scheduler, node_id: int, t: float,
                     restore_after: float | None = None):
        def _fail():
            self.injected.append((self.loop.now(), f"node:{scheduler.name}/{node_id}"))
            scheduler.fail_node(node_id)
            if restore_after is not None:
                self.loop.call_after(restore_after, scheduler.restore_node,
                                     node_id)
        self.loop.call_at(t, _fail)

    def fail_instance_at(self, endpoint, model: str, t: float,
                         which: int = 0):
        def _fail():
            alive = [i for i in endpoint.instances.get(model, []) if i.alive]
            if which < len(alive):
                self.injected.append(
                    (self.loop.now(), f"instance:{alive[which].instance_id}"))
                alive[which].fail()
        self.loop.call_at(t, _fail)

    def endpoint_outage(self, router, endpoint_id: str, t: float,
                        duration: float):
        """Router-level outage (e.g. a network partition the control plane
        learned about out of band): mark unhealthy now, healthy at
        ``t+duration``. The heartbeat monitor must NOT undo this — its own
        belief about the endpoint never changed, so it emits no transition."""
        def _down():
            self.injected.append((self.loop.now(), f"endpoint:{endpoint_id}"))
            router.set_healthy(endpoint_id, False)
            self.loop.call_after(duration, router.set_healthy, endpoint_id,
                                 True)
        self.loop.call_at(t, _down)

    def crash_endpoint(self, endpoint, t: float, duration: float,
                       silent: bool = False):
        """Real endpoint-process crash: beats stop (the monitor detects it),
        in-flight tasks error — or vanish when ``silent``, exercising the
        gateway's per-attempt timeout — and the process restarts cold at
        ``t+duration``."""
        def _crash():
            self.injected.append(
                (self.loop.now(),
                 f"crash{':silent' if silent else ''}:{endpoint.endpoint_id}"))
            endpoint.crash(duration, silent=silent)
        self.loop.call_at(t, _crash)

    def heartbeat_loss(self, endpoint, t: float, duration: float):
        """Beats vanish while the endpoint keeps serving: a detector
        false-positive. Liveness must recover from the first beat after the
        window without operator action."""
        def _lose():
            self.injected.append(
                (self.loop.now(), f"hb-loss:{endpoint.endpoint_id}"))
            endpoint.suppress_heartbeats(duration)
        self.loop.call_at(t, _lose)

    def latency_injection(self, endpoint, t: float, duration: float,
                          extra: float):
        """Straggler: beat latency inflated by ``extra`` seconds for
        ``duration`` — the monitor's EWMA should flag (and later clear) the
        endpoint as slow."""
        def _slow():
            self.injected.append(
                (self.loop.now(), f"latency:{endpoint.endpoint_id}+{extra:g}s"))
            endpoint.inject_latency(duration, extra)
        self.loop.call_at(t, _slow)

    def rack_outage(self, scheduler, t: float, nodes: list[int],
                    restore_after: float | None = None):
        """Correlated failure: a whole rack's nodes die at the same instant
        (shared PDU/switch), not as independent Poisson events."""
        def _fail():
            self.injected.append(
                (self.loop.now(),
                 f"rack:{scheduler.name}/{min(nodes)}-{max(nodes)}"))
            for n in nodes:
                scheduler.fail_node(n)
            if restore_after is not None:
                for n in nodes:
                    self.loop.call_after(restore_after,
                                         scheduler.restore_node, n)
        self.loop.call_at(t, _fail)

    # -- stochastic (MTBF-style, for scale studies) -------------------------------
    def random_node_failures(self, scheduler, rate_per_node_hour: float,
                             horizon: float, restore_after: float = 600.0):
        """Poisson failures: at 1000+ nodes even small per-node rates mean
        failures every few minutes — the control plane must absorb them."""
        lam = rate_per_node_hour * scheduler.num_nodes / 3600.0
        t = 0.0
        while True:
            t += self.rng.expovariate(lam) if lam > 0 else horizon
            if t >= horizon:
                break
            node = self.rng.randrange(scheduler.num_nodes)
            self.fail_node_at(scheduler, node, t, restore_after=restore_after)

    # -- seeded chaos schedule ----------------------------------------------------
    def _poisson_times(self, rate: float, start: float,
                       horizon: float) -> list[float]:
        ts, t = [], start
        while rate > 0:
            t += self.rng.expovariate(rate)
            if t >= horizon:
                break
            ts.append(t)
        return ts

    def plan_chaos(self, endpoints, schedulers, horizon: float, *,
                   start: float = 0.0,
                   node_rate: float = 0.0,
                   instance_rate: float = 0.0,
                   crash_rate: float = 0.0,
                   silent_crash_rate: float = 0.0,
                   hb_loss_rate: float = 0.0,
                   latency_rate: float = 0.0,
                   rack_rate: float = 0.0,
                   rack_size: int = 4,
                   mean_outage: float = 20.0,
                   latency_extra: float = 3.0) -> list[dict]:
        """Build and schedule a full chaos run: independent Poisson streams
        per fault class (rates are events/second across the federation),
        uniformly random targets, exponential outage durations. Everything
        derives from this injector's seed, so a schedule replays exactly —
        ``benchmarks/chaos_soak.py`` leans on that for its deterministic
        gates. Returns the plan (sorted by time) for logging/auditing."""
        eps = list(endpoints.values()) if isinstance(endpoints, dict) \
            else list(endpoints)
        scheds = list(schedulers.values()) if isinstance(schedulers, dict) \
            else list(schedulers)
        plan: list[dict] = []

        def _dur() -> float:
            return max(self.rng.expovariate(1.0 / mean_outage), 1.0)

        for t in self._poisson_times(node_rate, start, horizon):
            s = self.rng.choice(scheds)
            plan.append({"t": t, "kind": "node",
                         "target": s.name,
                         "node": self.rng.randrange(s.num_nodes),
                         "duration": _dur()})
        for t in self._poisson_times(instance_rate, start, horizon):
            ep = self.rng.choice(eps)
            model = self.rng.choice(sorted(ep.deployments))
            plan.append({"t": t, "kind": "instance",
                         "target": ep.endpoint_id, "model": model})
        for t in self._poisson_times(crash_rate, start, horizon):
            ep = self.rng.choice(eps)
            plan.append({"t": t, "kind": "crash",
                         "target": ep.endpoint_id, "duration": _dur()})
        for t in self._poisson_times(silent_crash_rate, start, horizon):
            ep = self.rng.choice(eps)
            plan.append({"t": t, "kind": "silent-crash",
                         "target": ep.endpoint_id, "duration": _dur()})
        for t in self._poisson_times(hb_loss_rate, start, horizon):
            ep = self.rng.choice(eps)
            plan.append({"t": t, "kind": "hb-loss",
                         "target": ep.endpoint_id, "duration": _dur()})
        for t in self._poisson_times(latency_rate, start, horizon):
            ep = self.rng.choice(eps)
            plan.append({"t": t, "kind": "latency",
                         "target": ep.endpoint_id, "duration": _dur(),
                         "extra": latency_extra})
        for t in self._poisson_times(rack_rate, start, horizon):
            s = self.rng.choice(scheds)
            base = self.rng.randrange(max(s.num_nodes - rack_size, 1))
            plan.append({"t": t, "kind": "rack", "target": s.name,
                         "nodes": list(range(base, base + rack_size)),
                         "duration": _dur()})
        plan.sort(key=lambda e: e["t"])

        ep_by_id = {e.endpoint_id: e for e in eps}
        sched_by_name = {s.name: s for s in scheds}
        for ev in plan:
            if ev["kind"] == "node":
                self.fail_node_at(sched_by_name[ev["target"]], ev["node"],
                                  ev["t"], restore_after=ev["duration"])
            elif ev["kind"] == "instance":
                self.fail_instance_at(ep_by_id[ev["target"]], ev["model"],
                                      ev["t"])
            elif ev["kind"] == "crash":
                self.crash_endpoint(ep_by_id[ev["target"]], ev["t"],
                                    ev["duration"])
            elif ev["kind"] == "silent-crash":
                self.crash_endpoint(ep_by_id[ev["target"]], ev["t"],
                                    ev["duration"], silent=True)
            elif ev["kind"] == "hb-loss":
                self.heartbeat_loss(ep_by_id[ev["target"]], ev["t"],
                                    ev["duration"])
            elif ev["kind"] == "latency":
                self.latency_injection(ep_by_id[ev["target"]], ev["t"],
                                       ev["duration"], ev["extra"])
            elif ev["kind"] == "rack":
                self.rack_outage(sched_by_name[ev["target"]], ev["t"],
                                 ev["nodes"], restore_after=ev["duration"])
        return plan


class HealthMonitor:
    """Heartbeat-driven failure detector.

    Endpoints registered via ``watch()`` emit beats (``on_beat``); the
    periodic ``_tick`` marks an endpoint down only after
    ``miss_threshold`` beat intervals of silence, the next observed beat
    marks it up again, and the beat-latency EWMA over ``slow_latency``
    raises the router's straggler flag. All router updates are edge-
    triggered: the monitor never rewrites health it has no new evidence
    about, so externally injected outages persist (see
    ``FailureInjector.endpoint_outage``).

    ``mark_down``/``mark_up`` remain as manual operator overrides: a
    marked-down endpoint stays down in the router even while its beats
    flow."""

    def __init__(self, loop, router, interval: float = 15.0,
                 miss_threshold: float = 3.0, slow_latency: float = 1.0,
                 ewma_alpha: float = 0.3):
        self.loop = loop
        self.router = router
        self.interval = interval
        self.miss_threshold = miss_threshold
        self.slow_latency = slow_latency
        self.ewma_alpha = ewma_alpha
        self._beats: dict[str, dict] = {}   # ep -> belief state
        self._down: set[str] = set()        # manual overrides
        self.checks = 0
        # (t, endpoint, event) for event in down|up|slow|recovered-speed
        self.transitions: list[tuple[float, str, str]] = []
        self._tick()

    # -- wiring -----------------------------------------------------------------
    def watch(self, endpoint) -> None:
        """Subscribe to an endpoint's heartbeats (starts its beat loop)."""
        self._beats[endpoint.endpoint_id] = {
            "last": self.loop.now(),
            "interval": endpoint.heartbeat_interval,
            "ewma": None, "up": True, "slow": False}
        endpoint.attach_monitor(self)

    # -- observations ------------------------------------------------------------
    def on_beat(self, endpoint_id: str, sent_t: float) -> None:
        st = self._beats.get(endpoint_id)
        if st is None:
            return
        now = self.loop.now()
        st["last"] = now
        lat = now - sent_t
        a = self.ewma_alpha
        st["ewma"] = lat if st["ewma"] is None \
            else (1 - a) * st["ewma"] + a * lat
        if not st["up"]:
            st["up"] = True
            self.transitions.append((now, endpoint_id, "up"))
            if endpoint_id not in self._down:
                self.router.set_healthy(endpoint_id, True)
        slow = st["ewma"] > self.slow_latency
        if slow != st["slow"]:
            st["slow"] = slow
            self.transitions.append(
                (now, endpoint_id, "slow" if slow else "recovered-speed"))
            if hasattr(self.router, "set_slow"):
                self.router.set_slow(endpoint_id, slow)

    # -- manual overrides ---------------------------------------------------------
    def mark_down(self, endpoint_id: str):
        self._down.add(endpoint_id)
        self.router.set_healthy(endpoint_id, False)

    def mark_up(self, endpoint_id: str):
        self._down.discard(endpoint_id)
        st = self._beats.get(endpoint_id)
        if st is None or st["up"]:
            self.router.set_healthy(endpoint_id, True)

    # -- liveness from missed beats ------------------------------------------------
    def is_up(self, endpoint_id: str) -> bool:
        st = self._beats.get(endpoint_id)
        return bool(st and st["up"]) and endpoint_id not in self._down

    def _tick(self):
        self.checks += 1
        now = self.loop.now()
        for ep_id, st in self._beats.items():
            silent_for = now - st["last"]
            if st["up"] and silent_for > self.miss_threshold * st["interval"]:
                st["up"] = False
                self.transitions.append((now, ep_id, "down"))
                self.router.set_healthy(ep_id, False)
        self.loop.call_after(self.interval, self._tick, daemon=True)
