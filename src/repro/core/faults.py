"""Fault tolerance: failure injection (nodes, instances, endpoints) and the
health monitor that feeds endpoint liveness into the federation router.

Instance/process restart + in-flight requeue lives in ComputeEndpoint
(idempotent inference tasks make re-execution safe); this module provides the
chaos and the detection."""
from __future__ import annotations

import random


class FailureInjector:
    def __init__(self, loop, seed: int = 0):
        self.loop = loop
        self.rng = random.Random(seed)
        self.injected: list[tuple[float, str]] = []

    # -- targeted ---------------------------------------------------------------
    def fail_node_at(self, scheduler, node_id: int, t: float,
                     restore_after: float | None = None):
        def _fail():
            self.injected.append((self.loop.now(), f"node:{scheduler.name}/{node_id}"))
            scheduler.fail_node(node_id)
            if restore_after is not None:
                self.loop.call_after(restore_after, scheduler.restore_node,
                                     node_id)
        self.loop.call_at(t, _fail)

    def fail_instance_at(self, endpoint, model: str, t: float,
                         which: int = 0):
        def _fail():
            alive = [i for i in endpoint.instances.get(model, []) if i.alive]
            if which < len(alive):
                self.injected.append(
                    (self.loop.now(), f"instance:{alive[which].instance_id}"))
                alive[which].fail()
        self.loop.call_at(t, _fail)

    def endpoint_outage(self, router, endpoint_id: str, t: float,
                        duration: float):
        def _down():
            self.injected.append((self.loop.now(), f"endpoint:{endpoint_id}"))
            router.set_healthy(endpoint_id, False)
            self.loop.call_after(duration, router.set_healthy, endpoint_id,
                                 True)
        self.loop.call_at(t, _down)

    # -- stochastic (MTBF-style, for scale studies) -------------------------------
    def random_node_failures(self, scheduler, rate_per_node_hour: float,
                             horizon: float, restore_after: float = 600.0):
        """Poisson failures: at 1000+ nodes even small per-node rates mean
        failures every few minutes — the control plane must absorb them."""
        lam = rate_per_node_hour * scheduler.num_nodes / 3600.0
        t = 0.0
        while True:
            t += self.rng.expovariate(lam) if lam > 0 else horizon
            if t >= horizon:
                break
            node = self.rng.randrange(scheduler.num_nodes)
            self.fail_node_at(scheduler, node, t, restore_after=restore_after)


class HealthMonitor:
    """Heartbeat poller: marks endpoints unhealthy in the router when their
    scheduler stops responding (simulated via mark_down) and spawns
    replacement capacity checks."""

    def __init__(self, loop, router, interval: float = 15.0):
        self.loop = loop
        self.router = router
        self.interval = interval
        self._down: set[str] = set()
        self.checks = 0
        self._tick()

    def mark_down(self, endpoint_id: str):
        self._down.add(endpoint_id)

    def mark_up(self, endpoint_id: str):
        self._down.discard(endpoint_id)

    def _tick(self):
        self.checks += 1
        for ep_id in list(self.router.endpoints):
            self.router.set_healthy(ep_id, ep_id not in self._down)
        self.loop.call_after(self.interval, self._tick, daemon=True)
