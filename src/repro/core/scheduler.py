"""PBS/Slurm-analogue cluster scheduler (discrete-event simulation).

Models what FIRST sees from an HPC batch system: a fixed pool of accelerator
nodes, a FIFO job queue with optional backfill, node-acquisition delay, and a
public status API (used by the federation layer, paper §4.5: "queries the
publicly available status of each cluster")."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum

_job_ids = itertools.count(1)


class JobState(str, Enum):
    QUEUED = "queued"
    STARTING = "starting"
    RUNNING = "running"
    ENDED = "ended"
    FAILED = "failed"


@dataclass
class Job:
    num_nodes: int
    walltime: float | None
    on_start: object
    on_end: object = None
    job_id: int = field(default_factory=lambda: next(_job_ids))
    state: JobState = JobState.QUEUED
    nodes: list = field(default_factory=list)
    submit_time: float = 0.0
    start_time: float = 0.0
    end_time: float = 0.0

    @property
    def queue_wait(self) -> float:
        return self.start_time - self.submit_time


class ClusterScheduler:
    def __init__(self, loop, name: str, num_nodes: int,
                 chips_per_node: int = 8, startup_delay: float = 20.0,
                 backfill: bool = True):
        self.loop = loop
        self.name = name
        self.num_nodes = num_nodes
        self.chips_per_node = chips_per_node
        self.startup_delay = startup_delay   # node boot + env setup
        self.backfill = backfill
        self._free_nodes = list(range(num_nodes))
        self._queue: list[Job] = []
        self.jobs: dict[int, Job] = {}
        self._down_nodes: set[int] = set()

    # -- public API (what FIRST's endpoint calls) ------------------------------
    def submit(self, num_nodes: int, on_start, on_end=None,
               walltime: float | None = None) -> Job:
        job = Job(num_nodes=num_nodes, walltime=walltime, on_start=on_start,
                  on_end=on_end)
        job.submit_time = self.loop.now()
        self.jobs[job.job_id] = job
        self._queue.append(job)
        self._try_schedule()
        return job

    def release(self, job: Job):
        """Job gives back its nodes (endpoint idle-release or shutdown)."""
        if job.state in (JobState.ENDED, JobState.FAILED):
            return
        if job.state == JobState.QUEUED:
            # a queued job must leave the queue when released, or
            # _try_schedule would later zombie-start an ENDED job and leak
            # its nodes forever (caught by the hypothesis scheduler
            # property: terminal states are terminal)
            self._end_queued(job)
            return
        self._finish(job, JobState.ENDED)

    def cancel(self, job: Job):
        if job.state == JobState.QUEUED:
            self._end_queued(job)

    def _end_queued(self, job: Job):
        """Terminal transition for a job that never started: dequeue, mark
        ENDED, and fire on_end — one code path for release() and cancel()."""
        self._queue.remove(job)
        job.state = JobState.ENDED
        job.end_time = self.loop.now()
        if job.on_end:
            job.on_end(job)

    # -- status (federation reads this) ------------------------------------------
    def available_nodes(self) -> int:
        return len(self._free_nodes)

    def queue_depth(self) -> int:
        return len(self._queue)

    def status(self) -> dict:
        return {
            "cluster": self.name,
            "nodes_total": self.num_nodes,
            "nodes_free": self.available_nodes(),
            "nodes_down": len(self._down_nodes),
            "queue_depth": self.queue_depth(),
        }

    # -- fault hooks -----------------------------------------------------------
    def fail_node(self, node_id: int):
        """Hard node failure: kills the job running on it."""
        self._down_nodes.add(node_id)
        if node_id in self._free_nodes:
            self._free_nodes.remove(node_id)
            return None
        for job in self.jobs.values():
            if job.state in (JobState.STARTING, JobState.RUNNING) \
                    and node_id in job.nodes:
                self._finish(job, JobState.FAILED, lost_node=node_id)
                return job
        return None

    def restore_node(self, node_id: int):
        if node_id in self._down_nodes:
            self._down_nodes.remove(node_id)
            self._free_nodes.append(node_id)
            self._try_schedule()

    # -- internals -----------------------------------------------------------
    def _try_schedule(self):
        i = 0
        while i < len(self._queue):
            job = self._queue[i]
            if job.num_nodes <= len(self._free_nodes):
                self._queue.pop(i)
                self._start(job)
                continue
            if not self.backfill:
                break
            i += 1

    def _start(self, job: Job):
        job.nodes = [self._free_nodes.pop() for _ in range(job.num_nodes)]
        job.state = JobState.STARTING
        job.start_time = self.loop.now()

        def _running():
            if job.state != JobState.STARTING:
                return
            job.state = JobState.RUNNING
            if job.on_start:
                job.on_start(job)
            if job.walltime is not None:
                self.loop.call_after(job.walltime, self._walltime_end, job)

        self.loop.call_after(self.startup_delay, _running)

    def _walltime_end(self, job: Job):
        if job.state == JobState.RUNNING:
            self._finish(job, JobState.ENDED)

    def _finish(self, job: Job, state: JobState, lost_node: int | None = None):
        job.state = state
        job.end_time = self.loop.now()
        for n in job.nodes:
            if n != lost_node and n not in self._down_nodes:
                self._free_nodes.append(n)
        job.nodes = []
        if job.on_end:
            job.on_end(job)
        self._try_schedule()
