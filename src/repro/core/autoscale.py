"""Auto-scaling policy (paper §3.2.2 / Fig. 4): launch additional instances of
a model when existing ones are saturated; scale-in happens via hot-node idle
timeouts on the instances themselves."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class AutoScalePolicy:
    max_instances: int = 1            # admin cap: max parallel jobs per model
    queue_threshold: int = 4          # queued reqs per instance that triggers scale-up
    cooldown: float = 30.0            # min seconds between scale-ups per model


class AutoScaler:
    def __init__(self, loop, policy: AutoScalePolicy | None = None):
        self.loop = loop
        self.policy = policy or AutoScalePolicy()
        self._last_scale: dict[str, float] = {}
        self.scale_events: list[tuple[float, str, int]] = []

    def should_scale_up(self, model: str, instances: list, cluster_free_nodes,
                        nodes_per_instance: int) -> bool:
        pol = self.policy
        alive = [i for i in instances if i.alive]
        if not alive or len(alive) >= pol.max_instances:
            return False
        if cluster_free_nodes < nodes_per_instance:
            return False
        now = self.loop.now()
        if now - self._last_scale.get(model, -1e18) < pol.cooldown:
            return False
        hot = [i for i in alive if i.state.value == "running"]
        if not hot:
            return False  # still cold-starting the first one
        queued = sum(i.engine.queue_depth for i in hot) + \
            sum(len(i._pending) for i in alive)
        saturated = all(i.engine.saturated() for i in hot)
        trigger = queued >= pol.queue_threshold * len(hot) or saturated
        return trigger

    def record_scale(self, model: str, n_instances: int):
        self._last_scale[model] = self.loop.now()
        self.scale_events.append((self.loop.now(), model, n_instances))
