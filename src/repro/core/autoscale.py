"""Auto-scaling policy engine (paper §3.2.2 / Fig. 4): launch additional
instances of a model when existing ones are saturated, and manage the hot
pool on the way down — a pinned ``min_hot`` floor of warm instances plus a
per-model ``keepalive`` window that replaces the instances' flat idle
timeout. With ``keepalive`` unset, scale-in stays where it was before: the
instances' own ``idle_timeout`` timers."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class AutoScalePolicy:
    max_instances: int = 1            # admin cap: max parallel jobs per model
    queue_threshold: int = 4          # queued reqs per instance that triggers scale-up
    cooldown: float = 30.0            # min seconds between scale-ups per model
    # hot-pool targets: a pinned floor of warm instances that survives zero
    # demand, and a per-model keepalive window after which idle instances
    # above the floor are released. keepalive=None leaves scale-in to the
    # instances' own flat idle_timeout (legacy behavior); when set, the
    # POOL owns scale-in and instances never self-release.
    min_hot: int = 0                  # pinned floor of provisioned instances
    keepalive: float | None = None    # idle seconds before scale-in
    scale_in_cooldown: float = 30.0   # min seconds between scale-ins per model


class AutoScaler:
    def __init__(self, loop, policy: AutoScalePolicy | None = None):
        self.loop = loop
        self.policy = policy or AutoScalePolicy()
        self._last_scale: dict[str, float] = {}
        self._last_scale_in: dict[str, float] = {}
        self.scale_events: list[tuple[float, str, int]] = []
        self.scale_in_events: list[tuple[float, str, int]] = []

    def should_scale_up(self, model: str, instances: list, cluster_free_nodes,
                        nodes_per_instance: int) -> bool:
        pol = self.policy
        alive = [i for i in instances if i.alive]
        if not alive or len(alive) >= pol.max_instances:
            return False
        if cluster_free_nodes < nodes_per_instance:
            return False
        now = self.loop.now()
        if now - self._last_scale.get(model, -1e18) < pol.cooldown:
            return False
        hot = [i for i in alive if i.state.value == "running"]
        if not hot:
            return False  # still cold-starting the first one
        queued = sum(i.engine.queue_depth for i in hot) + \
            sum(len(i._pending) for i in alive)
        saturated = all(i.engine.saturated() for i in hot)
        trigger = queued >= pol.queue_threshold * len(hot) or saturated
        return trigger

    def pool_deficit(self, model: str, instances: list, cluster_free_nodes,
                     nodes_per_instance: int) -> int:
        """Instances to spawn right now to restore the pinned ``min_hot``
        floor (bounded by the cluster's free nodes). The floor is demand-
        independent and not cooldown-gated: a pool hole left by a failure
        or release must refill promptly to keep TTFT flat."""
        pol = self.policy
        alive = [i for i in instances if i.alive]
        want = min(pol.min_hot, pol.max_instances) - len(alive)
        if want <= 0:
            return 0
        fit = int(cluster_free_nodes) // max(int(nodes_per_instance), 1)
        return max(min(want, fit), 0)

    def pick_scale_in(self, model: str, instances: list):
        """The instance to release now, or None: hot, zero in-flight work,
        idle past the keepalive window, longest-idle first — and only while
        the pool stays above the ``min_hot`` floor. Instances holding any
        queued/running work are never eviction candidates."""
        pol = self.policy
        if pol.keepalive is None:
            return None               # legacy: instances self-release
        alive = [i for i in instances if i.alive]
        if len(alive) <= max(pol.min_hot, 0):
            return None
        now = self.loop.now()
        if now - self._last_scale_in.get(model, -1e18) < pol.scale_in_cooldown:
            return None
        idle = [i for i in alive
                if i.state.value == "running" and i.load == 0
                and getattr(i, "idle_since", None) is not None
                and now - i.idle_since >= pol.keepalive]
        if not idle:
            return None
        return min(idle, key=lambda i: i.idle_since)   # longest idle

    def record_scale(self, model: str, n_instances: int):
        self._last_scale[model] = self.loop.now()
        self.scale_events.append((self.loop.now(), model, n_instances))

    def record_scale_in(self, model: str, n_instances: int):
        self._last_scale_in[model] = self.loop.now()
        self.scale_in_events.append((self.loop.now(), model, n_instances))
