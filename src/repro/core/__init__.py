"""FIRST control plane — the paper's primary contribution: gateway,
FaaS compute layer, scheduling (hot nodes, auto-scaling, batch mode),
federation, and fault tolerance, all driven by one discrete-event loop."""
from repro.core.clock import EventLoop, Future, RealClock, VirtualClock
from repro.core.auth import (AccessPolicy, AuthError, AuthService,
                             CachingAuthClient, Identity)
from repro.core.metrics import MetricsLog, RequestRecord
from repro.core.scheduler import ClusterScheduler, Job, JobState
from repro.core.instances import (InstanceState, ModelInstance, SimEngine,
                                  SimRequest)
from repro.core.autoscale import AutoScalePolicy, AutoScaler
from repro.core.compute import (ComputeClient, ComputeEndpoint, ComputeError,
                                ModelDeployment)
from repro.core.federation import FederationError, FederationRouter
from repro.core.gateway import (GatewayConfig, GatewayError, InferenceGateway,
                                RateLimiter, ResponseCache, WorkerPool)
from repro.core.batch import BatchJob, BatchService, BatchState
from repro.core.faults import FailureInjector, HealthMonitor

__all__ = [
    "EventLoop", "Future", "RealClock", "VirtualClock",
    "AccessPolicy", "AuthError", "AuthService", "CachingAuthClient", "Identity",
    "MetricsLog", "RequestRecord",
    "ClusterScheduler", "Job", "JobState",
    "InstanceState", "ModelInstance", "SimEngine", "SimRequest",
    "AutoScalePolicy", "AutoScaler",
    "ComputeClient", "ComputeEndpoint", "ComputeError", "ModelDeployment",
    "FederationError", "FederationRouter",
    "GatewayConfig", "GatewayError", "InferenceGateway", "RateLimiter",
    "ResponseCache", "WorkerPool",
    "BatchJob", "BatchService", "BatchState",
    "FailureInjector", "HealthMonitor",
]
