"""Globus-Compute analogue: a FaaS layer between the gateway and clusters.

* ``ComputeEndpoint`` runs at a cluster: it executes ONLY pre-registered
  functions (paper §3.2.2 security), acquires nodes through the cluster's
  scheduler, manages model instances (cold start, hot nodes, auto-scaling,
  restart-on-failure) and distributes tasks across instances.
* ``ComputeClient`` is the cloud service: it relays tasks to endpoints and
  results back, with a network hop each way, a connection cache
  (paper Optimization 2), and futures instead of polling (Optimization 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.api import schemas
from repro.api.errors import RequestCancelled
from repro.core.autoscale import AutoScaler, AutoScalePolicy
from repro.core.clock import Future
from repro.core.instances import InstanceState, ModelInstance, SimRequest
from repro.serving.costmodel import InstanceCost


class ComputeError(Exception):
    pass


class StreamChannel:
    """The gateway's live back-channel for one task (the DES analogue of a
    held-open SSE connection): first-token notifications and incremental
    ``StreamDelta`` frames ride it back with one network-hop latency each,
    independent of the task's completion future."""

    def __init__(self, loop, latency: float, on_first_token=None,
                 on_delta=None):
        self.loop = loop
        self.latency = latency
        self.on_first_token = on_first_token
        self.on_delta = on_delta
        self._idx = 0

    def first_token(self, request_id: str, t: float):
        if self.on_first_token is not None:
            self.loop.call_after(self.latency, self.on_first_token,
                                 request_id, t)

    def delta(self, request_id: str, n_tokens: int, t: float,
              offset: int = 0, finished: bool = False,
              finish_reason: str = ""):
        if self.on_delta is None:
            return
        frame = schemas.StreamDelta(id=request_id, index=self._idx,
                                    n_tokens=n_tokens, offset=offset,
                                    created=t, finished=finished,
                                    finish_reason=finish_reason)
        self._idx += 1
        self.loop.call_after(self.latency, self.on_delta, frame)


@dataclass
class ModelDeployment:
    """Admin configuration of one model on one endpoint."""
    model: str
    cost: InstanceCost
    # disaggregated serving role: 'prefill-heavy' instances ingest prompts
    # and emit first tokens only, then hand sequences to a 'decode-heavy'
    # (or unified) pool elsewhere in the federation; 'unified' does both
    role: str = "unified"
    nodes_per_instance: int = 1
    model_shards: int = 1                  # TP width per instance (must match
    #                                        cost.model_shards; the real
    #                                        engine's EngineConfig.mesh mirror)
    max_slots: int = 48                    # max parallel tasks within a node
    idle_timeout: float = 7200.0           # paper: release after 2 h idle
    autoscale: AutoScalePolicy = field(default_factory=AutoScalePolicy)
    walltime: float | None = None
    result_cpu: float = 0.0                # per-instance result serialization
    # engine data-plane toggles (see repro.core.instances.SimEngine)
    prefix_cache_hit_rate: float = 0.0     # warm-cache shared-prefix fraction
    chunked_prefill_budget: int | None = None  # prompt tokens per engine step
    decode_steps_per_sync: int = 1         # fused decode tokens per host sync
    spec_tokens: int = 0                   # draft tokens per speculative round
    spec_accept_rate: float = 0.8          # steady-state draft acceptance
    draft_cost: InstanceCost | None = None  # draft model (required for spec)
    # QoS scheduling mirror (see repro.serving.scheduler)
    scheduling_policy: str = "fcfs"        # fcfs | priority | edf
    enable_preemption: bool = False        # evict batch for blocked urgent
    restore_hit_rate: float = 1.0          # prefix-cache share of a restore


class ComputeEndpoint:
    def __init__(self, loop, endpoint_id: str, scheduler,
                 deployments: dict[str, ModelDeployment],
                 heartbeat_interval: float = 5.0,
                 heartbeat_latency: float = 0.05):
        self.loop = loop
        self.endpoint_id = endpoint_id
        self.scheduler = scheduler
        self.deployments = deployments
        for m, d in deployments.items():
            if d.role not in ("unified", "prefill-heavy", "decode-heavy"):
                raise ValueError(f"unknown role {d.role!r} for {m!r} "
                                 f"on {endpoint_id}")
        self.instances: dict[str, list[ModelInstance]] = \
            {m: [] for m in deployments}
        self._functions: dict[str, object] = {}
        # request_id -> (model, sreq, fut, channel) while a task is here
        self._inflight: dict[str, tuple] = {}
        # request_id -> decode endpoint, after a prefill->decode handoff
        # moved the task there (aborts forward through this)
        self._handoffs: dict[str, ComputeEndpoint] = {}
        self._router = None           # federation, for handoff targeting
        self._autoscalers = {m: AutoScaler(loop, d.autoscale)
                             for m, d in deployments.items()}
        self.stats = {"tasks": 0, "restarts": 0, "requeued": 0,
                      "aborted": 0, "crashes": 0, "recoveries": 0,
                      "scale_ins": 0, "handoffs_out": 0, "handoffs_in": 0,
                      "handoff_fallbacks": 0}
        self.register_function("generate", self._fn_generate)
        self.register_function("embed", self._fn_embed)
        self.register_function("abort", self._fn_abort)
        self.autoscale_interval = 5.0
        # liveness: the endpoint process itself (not its instances). While
        # down it stops heartbeating, rejects work and drops events.
        self.up = True
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_latency = heartbeat_latency
        self._monitor = None
        self._hb_suppress_until = 0.0     # heartbeat-loss injection window
        self._slow_until = 0.0            # latency injection window ...
        self._slow_extra = 0.0            # ... and its added beat latency
        self._autoscale_tick()

    # -- security: pre-registered functions only ---------------------------------
    def register_function(self, name: str, fn):
        self._functions[name] = fn

    def execute(self, fn_name: str, payload: dict,
                channel: StreamChannel | None = None) -> Future:
        if not self.up:
            fut = Future()
            fut.set_error(ComputeError(
                f"endpoint {self.endpoint_id} is unreachable"))
            return fut
        fn = self._functions.get(fn_name)
        if fn is None:
            fut = Future()
            fut.set_error(ComputeError(
                f"function {fn_name!r} is not registered on {self.endpoint_id}"))
            return fut
        self.stats["tasks"] += 1
        return fn(payload, channel)

    # -- liveness: heartbeats + crash/recover ------------------------------------
    def attach_monitor(self, monitor) -> None:
        """Start emitting heartbeats to a ``HealthMonitor``. Each beat rides
        to the monitor with ``heartbeat_latency`` (plus any injected extra),
        so the monitor OBSERVES liveness and latency instead of being told."""
        self._monitor = monitor
        self._heartbeat_tick()

    def _heartbeat_tick(self):
        if self._monitor is None:
            return
        now = self.loop.now()
        if self.up and now >= self._hb_suppress_until:
            lat = self.heartbeat_latency
            if now < self._slow_until:
                lat += self._slow_extra
            self.loop.call_after(lat, self._monitor.on_beat,
                                 self.endpoint_id, now, daemon=True)
        self.loop.call_after(self.heartbeat_interval, self._heartbeat_tick,
                             daemon=True)

    def suppress_heartbeats(self, duration: float) -> None:
        """Heartbeat-loss injection: the endpoint stays up and keeps serving
        but its beats vanish — the detector must (wrongly) mark it down and
        recover it from the first beat after the window."""
        self._hb_suppress_until = max(self._hb_suppress_until,
                                      self.loop.now() + duration)

    def inject_latency(self, duration: float, extra: float) -> None:
        """Straggler injection: beats (and only beats — the detector's view)
        arrive ``extra`` seconds late for ``duration``."""
        self._slow_until = max(self._slow_until, self.loop.now() + duration)
        self._slow_extra = extra

    def crash(self, duration: float | None = None, silent: bool = False):
        """The endpoint process dies: heartbeats stop, new work is rejected,
        every in-flight task errors with a retryable ``ComputeError`` (or is
        silently dropped when ``silent`` — the caller's per-attempt timeout
        must catch that), instances are torn down WITHOUT local requeue (the
        gateway's retry layer re-routes), and their nodes are released.
        ``duration`` schedules ``recover`` automatically."""
        if not self.up:
            return
        self.up = False
        self.stats["crashes"] += 1
        inflight = list(self._inflight.values())
        self._inflight.clear()
        for model in self.instances:
            for inst in list(self.instances[model]):
                if inst.alive:
                    inst.fail()      # requeue no-ops: _inflight is cleared
            self.instances[model] = []
        # requests already handed to a decode endpoint keep running there;
        # only the abort-forwarding breadcrumbs die with this process
        self._handoffs.clear()
        if not silent:
            for _model, sreq, fut, _chan in inflight:
                if not fut.done():
                    fut.set_error(ComputeError(
                        f"endpoint {self.endpoint_id} crashed with "
                        f"{sreq.request_id} in flight"))
        if duration is not None:
            self.loop.call_after(duration, self.recover, daemon=True)

    def recover(self):
        if self.up:
            return
        self.up = True
        self.stats["recoveries"] += 1

    # -- status (for /jobs and federation) -----------------------------------------
    def model_states(self, model: str) -> list[str]:
        return [i.state.value for i in self.instances.get(model, [])
                if i.alive]

    def hosts(self, model: str) -> bool:
        return model in self.deployments

    def load_for(self, model: str) -> int:
        return sum(i.load for i in self.instances.get(model, []) if i.alive)

    # -- handlers --------------------------------------------------------------------
    def _fn_generate(self, payload: dict,
                     channel: StreamChannel | None = None) -> Future:
        fut = Future()
        req = schemas.from_wire(payload)     # typed /v1 request off the wire
        model = req.model
        if model not in self.deployments:
            fut.set_error(ComputeError(
                f"model {model!r} not deployed on {self.endpoint_id}"))
            return fut
        sreq = SimRequest(request_id=req.request_id,
                          prompt_tokens=req.prompt_token_count,
                          max_tokens=int(req.max_tokens),
                          user=req.user or "anonymous",
                          qos=req.qos,
                          priority=req.priority,
                          deadline=req.deadline,
                          stream=bool(req.stream),
                          resume_tokens=int(getattr(req, "resume_tokens",
                                                    0) or 0))
        self._inflight[sreq.request_id] = (model, sreq, fut, channel)
        self._dispatch(model, sreq, fut, channel)
        return fut

    def _fn_embed(self, payload: dict,
                  channel: StreamChannel | None = None) -> Future:
        """Embeddings are one-step tasks: modeled as generate with exactly
        ONE output token. The clamp lives at the pre-registered function
        (not only in schema validation) so any wire payload routed to
        'embed' is costed and slotted as an embedding, never as a full
        generation."""
        payload = dict(payload)
        if isinstance(payload.get("data"), dict):   # version-tagged envelope
            payload["data"] = dict(payload["data"], max_tokens=1)
        else:                                       # legacy untagged dict
            payload["max_tokens"] = 1
        return self._fn_generate(payload, channel)

    def _fn_abort(self, payload: dict,
                  channel: StreamChannel | None = None) -> Future:
        """Pre-registered cancellation: a client disconnect (or a losing
        hedge) propagates here and frees the engine slot immediately."""
        fut = Future()
        rid = payload.get("request_id", "")
        entry = self._inflight.pop(rid, None)
        if entry is None:
            # the sequence may have moved to a decode endpoint: forward
            target = self._handoffs.pop(rid, None)
            if target is not None and target.up:
                return target.execute("abort", payload)
            # already finished (or unknown)
            fut.set_result({"request_id": rid, "aborted": False})
            return fut
        model, sreq, task_fut, _chan = entry
        for inst in self.instances.get(model, []):
            if inst.alive and inst.abort(rid):
                break
        self.stats["aborted"] += 1
        if not task_fut.done():
            task_fut.set_error(RequestCancelled(
                f"request {rid} aborted on {self.endpoint_id}"))
        fut.set_result({"request_id": rid, "aborted": True})
        return fut

    # -- instance management ------------------------------------------------------
    def _autoscale_tick(self):
        """Periodic policy pass: hot-pool floor maintenance, demand
        scale-up, keepalive scale-in, then queue balancing. Scaling must
        also react while requests sit queued on saturated/loading
        instances (not only at dispatch time)."""
        for model in self.deployments:
            scaler = self._autoscalers[model]
            dep = self.deployments[model]
            # pinned floor: keep min_hot instances provisioned even with
            # zero demand, as far as the cluster's free nodes allow
            deficit = scaler.pool_deficit(
                model, self._alive_instances(model),
                self.scheduler.available_nodes(), dep.nodes_per_instance)
            for _ in range(deficit):
                self._spawn_instance(model)
            alive = self._alive_instances(model)
            if not alive:
                continue
            if scaler.should_scale_up(model, alive,
                                      self.scheduler.available_nodes(),
                                      dep.nodes_per_instance):
                self._spawn_instance(model)
            victim = scaler.pick_scale_in(model,
                                          self._alive_instances(model))
            if victim is not None:
                scaler.record_scale_in(
                    model, len(self._alive_instances(model)) - 1)
                self.stats["scale_ins"] += 1
                victim.release()       # idle: nothing to requeue
            self._balance_queues(model)
        self.loop.call_after(self.autoscale_interval, self._autoscale_tick,
                             daemon=True)

    def _on_instance_hot(self, inst: ModelInstance):
        self._balance_queues(inst.model_name)

    def _balance_queues(self, model: str):
        """Work stealing across HOT instances: queued work never sits on one
        saturated engine while another has spare capacity. (Work is never
        parked on cold instances — that would stall it for the whole cold
        start; cold instances pull work here once they turn hot.)"""
        hot = [i for i in self._alive_instances(model)
               if i.state == InstanceState.HOT]
        if len(hot) < 2 or not any(i.engine.queue_depth for i in hot):
            return
        entries = []
        for i in hot:
            # take_queued pops the robbed engine's _seq_of alongside its
            # queue (the receiver's submit re-issues arrival orders) —
            # clearing the queue alone leaks one map entry per steal
            entries.extend(i.engine.take_queued())
        for e in entries:               # round-robin by current effective load
            target = min(hot, key=lambda i: i.engine.load)
            target.engine.submit(*e)

    def _alive_instances(self, model: str) -> list[ModelInstance]:
        return [i for i in self.instances[model] if i.alive]

    def _spawn_instance(self, model: str) -> ModelInstance:
        dep = self.deployments[model]
        # with a pool keepalive configured, the POOL owns scale-in: the
        # instance's own flat idle timer is disabled
        idle_timeout = (None if dep.autoscale.keepalive is not None
                        else dep.idle_timeout)
        on_handoff = None
        if dep.role == "prefill-heavy":
            def on_handoff(sreq, produced, _m=model):
                return self._start_handoff(_m, sreq, produced)
        inst = ModelInstance(
            self.loop, model, dep.cost, self.scheduler,
            num_nodes=dep.nodes_per_instance, max_slots=dep.max_slots,
            idle_timeout=idle_timeout, walltime=dep.walltime,
            result_cpu=dep.result_cpu,
            role=dep.role, on_handoff=on_handoff,
            prefix_cache_hit_rate=dep.prefix_cache_hit_rate,
            chunked_prefill_budget=dep.chunked_prefill_budget,
            decode_steps_per_sync=dep.decode_steps_per_sync,
            spec_tokens=dep.spec_tokens,
            spec_accept_rate=dep.spec_accept_rate,
            draft_cost=dep.draft_cost,
            scheduling_policy=dep.scheduling_policy,
            enable_preemption=dep.enable_preemption,
            restore_hit_rate=dep.restore_hit_rate,
            on_released=self._on_instance_gone,
            on_failed=self._on_instance_failed,
            on_hot=self._on_instance_hot)
        self.instances[model].append(inst)
        # every spawn path stamps the scale: the cooldown window starts at
        # the spawn (cold starts in _dispatch included, which otherwise
        # let the next tick double-spawn behind them) and scale_events
        # records the first instance too
        self._autoscalers[model].record_scale(
            model, len(self._alive_instances(model)))
        return inst

    def _dispatch(self, model: str, sreq: SimRequest, fut: Future,
                  channel: StreamChannel | None = None):
        alive = self._alive_instances(model)
        if not alive:
            inst = self._spawn_instance(model)
        else:
            # least-loaded HOT instance; cold instances receive work only by
            # stealing once hot (or if nothing is hot yet)
            hot = [i for i in alive if i.state == InstanceState.HOT]
            pool = hot or alive
            inst = min(pool, key=lambda i: i.load)
            scaler = self._autoscalers[model]
            dep = self.deployments[model]
            if scaler.should_scale_up(model, alive,
                                      self.scheduler.available_nodes(),
                                      dep.nodes_per_instance):
                self._spawn_instance(model)

        first_holder = {}

        def on_first(t):
            first_holder["t"] = t
            sreq.first_token_at = t
            if channel is not None:
                channel.first_token(sreq.request_id, t)

        def on_done(result):
            self._inflight.pop(sreq.request_id, None)
            result = dict(result)
            # a resumed/handed-off request never re-fires on_first here:
            # its TTFT is the original first token the source stamped
            ft = first_holder.get("t", sreq.first_token_at)
            result["first_token_time"] = (ft if ft is not None
                                          else result["finish_time"])
            result["endpoint"] = self.endpoint_id
            if channel is not None and sreq.stream:
                channel.delta(sreq.request_id, 0, result["finish_time"],
                              offset=result.get("output_tokens", 0),
                              finished=True, finish_reason="length")
            if not fut.done():               # aborted tasks already errored
                fut.set_result(result)

        on_delta = None
        if channel is not None and sreq.stream:
            def on_delta(n, t, offset=0):
                channel.delta(sreq.request_id, n, t, offset=offset)

        inst.submit(sreq, on_first, on_done, on_delta)

    # -- disaggregated prefill/decode handoff ---------------------------------------
    def attach_federation(self, router) -> None:
        """Give the endpoint the federation router so prefill-role engines
        can target decode pools across clusters (testbed wiring)."""
        self._router = router

    def _start_handoff(self, model: str, sreq: SimRequest,
                       produced: int) -> bool:
        """Engine callback at the prefill/decode boundary: the sequence's
        prompt is ingested and its first token(s) streamed. Pick a
        decode-capable endpoint and move the sequence there, charging the
        KV-transfer hop. Returns False to keep decoding locally (unified
        fallback) when nothing can take it."""
        if self._router is None or sreq.request_id not in self._inflight:
            return False
        target = self._pick_decode_target(model, sreq)
        if target is None:
            self.stats["handoff_fallbacks"] += 1
            sreq.no_handoff = True
            return False
        self.stats["handoffs_out"] += 1
        dep = self.deployments[model]
        # the sequence's KV pages cross the inter-instance link; the
        # receiver then charges its restore prefill via resume admission.
        # The entry stays in _inflight during the hop so aborts/crashes
        # in the window resolve here and the delivery becomes a no-op.
        hop = dep.cost.handoff_time(sreq.prompt_tokens + produced)
        self.loop.call_after(hop, self._deliver_handoff, model, sreq, target)
        return True

    def _pick_decode_target(self, model: str, sreq: SimRequest):
        try:
            ep_id = self._router.select_endpoint(
                model, exclude=(self.endpoint_id,), qos=sreq.qos,
                role="decode")
        except Exception:              # noqa: BLE001 — no healthy target
            return None
        target = self._router.endpoints.get(ep_id)
        if target is None or target is self or not target.up:
            return None
        return target

    def _deliver_handoff(self, model: str, sreq: SimRequest, target):
        entry = self._inflight.pop(sreq.request_id, None)
        if entry is None:              # aborted / crashed mid-transfer
            return
        _, _, fut, channel = entry
        if fut.done():
            return
        if not target.up:
            # the decode target died mid-hop: the KV is still here, so
            # decode locally; no_handoff stops the engine from re-offering
            sreq.no_handoff = True
            self.stats["handoff_fallbacks"] += 1
            self._inflight[sreq.request_id] = entry
            self._dispatch(model, sreq, fut, channel)
            return
        self._handoffs[sreq.request_id] = target
        fut.add_done_callback(
            lambda _f, rid=sreq.request_id: self._handoffs.pop(rid, None))
        target.receive_handoff(model, sreq, fut, channel)

    def receive_handoff(self, model: str, sreq: SimRequest, fut: Future,
                        channel: StreamChannel | None) -> None:
        """Decode side of a prefill->decode handoff: adopt the in-flight
        entry (this endpoint's crash/requeue machinery covers it now) and
        admit via the resume path — a restore prefill of (prompt +
        produced), then decode continues from ``resume_tokens`` with
        contiguous stream offsets."""
        self.stats["handoffs_in"] += 1
        self._inflight[sreq.request_id] = (model, sreq, fut, channel)
        self._dispatch(model, sreq, fut, channel)

    # -- fault tolerance ------------------------------------------------------------
    def _on_instance_gone(self, inst: ModelInstance, inflight):
        self.instances[inst.model_name] = \
            [i for i in self.instances[inst.model_name] if i is not inst]
        self._requeue(inst.model_name, inflight)

    def _on_instance_failed(self, inst: ModelInstance, inflight):
        """Process-management restart (paper §3.2.2 fault tolerance): drop the
        failed instance and resubmit its in-flight requests; tasks resume
        from their last produced token (``SimRequest.resume_tokens``, stamped
        by ``SimEngine.halt``) so re-execution never regenerates — and never
        re-delivers — tokens the client already received."""
        if not self.up:              # endpoint-level crash: no local restart
            self.instances[inst.model_name] = \
                [i for i in self.instances[inst.model_name] if i is not inst]
            return
        self.stats["restarts"] += 1
        self._on_instance_gone(inst, inflight)

    def _requeue(self, model: str, inflight):
        for sreq in inflight:
            entry = self._inflight.get(sreq.request_id)
            if entry is None:
                continue
            self.stats["requeued"] += 1
            _, sreq, fut, channel = entry
            self.loop.call_after(0.0, self._dispatch, model, sreq, fut,
                                 channel)


class _Relay:
    """Serialized relay capacity of the cloud FaaS service: each task consumes
    ``cpu`` seconds on one of ``workers`` relay workers (both directions).
    Models the paper's §5.3.2 observation that overall scaling 'is currently
    limited by the ability of Globus Compute to scale and route requests'."""

    def __init__(self, loop, workers: int, cpu: float):
        self.loop = loop
        self.workers = workers
        self.cpu = cpu
        self.busy = 0
        self.queue: list = []

    def submit(self, fn):
        self.queue.append(fn)
        self._pump()

    def _pump(self):
        while self.busy < self.workers and self.queue:
            fn = self.queue.pop(0)
            self.busy += 1

            def _run(fn=fn):
                self.busy -= 1
                fn()
                self._pump()

            self.loop.call_after(self.cpu, _run)


class ComputeClient:
    """The cloud FaaS service: gateway -> (hop) -> endpoint -> (hop) -> gateway."""

    def __init__(self, loop, dispatch_latency: float = 0.15,
                 result_latency: float = 0.15,
                 connection_setup: float = 1.5,
                 connection_cache: bool = True,
                 relay_workers: int | None = None,
                 relay_cpu: float = 0.02):
        self.loop = loop
        self.dispatch_latency = dispatch_latency
        self.result_latency = result_latency
        self.connection_setup = connection_setup
        self.connection_cache = connection_cache
        self.relay = (_Relay(loop, relay_workers, relay_cpu)
                      if relay_workers else None)
        self._endpoints: dict[str, ComputeEndpoint] = {}
        self._connected: set[str] = set()
        self.tasks_in_cloud = 0
        self.max_tasks_in_cloud = 0

    def register_endpoint(self, endpoint: ComputeEndpoint):
        self._endpoints[endpoint.endpoint_id] = endpoint

    @property
    def endpoints(self) -> dict[str, ComputeEndpoint]:
        return self._endpoints

    def submit(self, endpoint_id: str, fn_name: str, payload: dict,
               on_first_token=None, on_delta=None) -> Future:
        """``on_first_token(request_id, t)`` / ``on_delta(StreamDelta)``:
        optional live back-channel callbacks; events ride back with one
        ``result_latency`` hop each, ahead of the completion future."""
        fut = Future()
        ep = self._endpoints.get(endpoint_id)
        if ep is None:
            fut.set_error(ComputeError(f"unknown endpoint {endpoint_id!r}"))
            return fut
        channel = None
        if on_first_token is not None or on_delta is not None:
            channel = StreamChannel(self.loop, self.result_latency,
                                    on_first_token, on_delta)
        hop = self.dispatch_latency
        if endpoint_id not in self._connected or not self.connection_cache:
            hop += self.connection_setup       # Optimization 2: cache this
            if self.connection_cache:
                self._connected.add(endpoint_id)
        self.tasks_in_cloud += 1
        self.max_tasks_in_cloud = max(self.max_tasks_in_cloud,
                                      self.tasks_in_cloud)

        def _deliver():
            inner = ep.execute(fn_name, payload, channel)

            def _back(f):
                def _resolve():
                    self.tasks_in_cloud -= 1
                    inner.chain(fut)

                def _hop_back():
                    self.loop.call_after(self.result_latency, _resolve)

                if self.relay is not None:
                    self.relay.submit(_hop_back)     # result leg also relays
                else:
                    _hop_back()

            inner.add_done_callback(_back)

        def _hop_out():
            self.loop.call_after(hop, _deliver)

        if self.relay is not None:
            self.relay.submit(_hop_out)
        else:
            _hop_out()
        return fut

    def cancel(self, endpoint_id: str, request_id: str) -> Future:
        """Propagate a client disconnect (or losing hedge) to the endpoint's
        pre-registered 'abort' function — one dispatch hop away."""
        fut = Future()
        ep = self._endpoints.get(endpoint_id)
        if ep is None:
            fut.set_error(ComputeError(f"unknown endpoint {endpoint_id!r}"))
            return fut

        def _deliver():
            ep.execute("abort", schemas.abort_wire(request_id)).chain(fut)

        self.loop.call_after(self.dispatch_latency, _deliver)
        return fut
