"""Model instances: cold-start pipeline, hot-node idle release, and the
discrete-event continuous-batching engine model used for simulated (large)
models. Real tiny models plug in through the same interface via
``repro.serving.engine`` adapters (examples/).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum

from repro.serving.costmodel import (InstanceCost, expected_spec_tokens,
                                     restore_tokens)
from repro.serving.scheduler import class_rank

_inst_ids = itertools.count(1)


@dataclass
class SimRequest:
    """Control-plane view of a request: token counts + QoS tags."""
    request_id: str
    prompt_tokens: int
    max_tokens: int
    user: str = "anonymous"
    qos: str = "interactive"          # workload class (interactive | batch)
    priority: int = 0                 # intra-class (lower = more urgent)
    deadline: float | None = None     # absolute TTFT deadline (loop time)
    stream: bool = False              # emit per-sync token deltas
    resume_tokens: int = 0            # failover resume: tokens ALREADY
    #                                   generated elsewhere — the engine
    #                                   restores (prompt+resume) via chunked
    #                                   prefill and continues from there
    no_handoff: bool = False          # pin to the current engine: a prefill-
    #                                   role engine decodes it locally instead
    #                                   of handing off (fallback after a
    #                                   failed/unroutable handoff)
    first_token_at: float | None = None   # stamped by the first dispatch; a
    #                                   resumed/handed-off request's TTFT is
    #                                   its ORIGINAL first token, not the
    #                                   resume point


class InstanceState(str, Enum):
    PENDING = "queued"       # batch job waiting for nodes
    LOADING = "starting"     # nodes acquired, weights loading
    HOT = "running"          # serving
    RELEASED = "released"
    FAILED = "failed"


class SimEngine:
    """DES model of a continuous-batching engine: per engine-step, every
    running sequence gains one token; newly admitted sequences add their
    prefill time to the step they join. Mirrors the real engine's
    iteration-level scheduling.

    Three data-plane toggles mirror ``repro.serving.engine.EngineConfig``:

    * ``prefix_cache_hit_rate`` — steady-state fraction of each prompt found
      in the hot instance's KV prefix cache (shared system prompts / few-shot
      templates); those tokens cost no prefill compute. Models a WARM
      instance — a cold instance's first prompts would miss, which this
      first-order model ignores.
    * ``chunked_prefill_budget`` — max prompt tokens ingested per engine
      step (None = whole prompts in the admission step). Sequences produce
      their first token only once their prefill budget has been consumed,
      and each step's duration charges only that step's chunk — bounding
      inter-token latency for running sequences, exactly like the real
      engine.
    * ``decode_steps_per_sync`` — the fused multi-step decode loop: each
      scheduled step covers K tokens per running sequence, charged
      ``K * decode_step_time(steps_per_sync=K)`` (the host-sync overhead
      amortized over K), and tokens surface in bursts of K at sync time —
      so throughput rises while tail inter-token latency quantizes to the
      sync period, matching ``benchmarks/decode_loop.py``. Falls back to
      K=1 whenever a prefill is in flight or new sequences were admitted,
      mirroring the real engine's composition-change rule.
    * ``spec_tokens`` / ``spec_accept_rate`` / ``draft_cost`` — speculative
      decoding: each steady-state round charges
      ``spec_round_time(batch, draft_cost, k)`` (k+1 fused draft steps plus
      ONE batched verify forward) and every running sequence gains
      ``expected_spec_tokens(accept_rate, k)`` tokens, matching
      ``benchmarks/spec_decode.py``. Rounds fall back to plain decode steps
      whenever a prefill is in flight or the composition changed — the same
      rule as the real engine's ``_decode_spec`` fallback.
    * ``scheduling_policy`` / ``enable_preemption`` / ``restore_hit_rate``
      — the QoS mirror of ``repro.serving.scheduler``: 'priority' admits
      interactive before batch (then intra-class priority, then arrival),
      'edf' admits by earliest TTFT deadline; with preemption on, a
      blocked more-urgent arrival evicts the most recently admitted
      less-urgent running sequence, whose re-admission charges a restore
      prefill of ``restore_tokens(held, restore_hit_rate)`` tokens — the
      recompute-via-prefix-cache cost term of the real engine's restore.
    """

    def __init__(self, loop, cost: InstanceCost, max_slots: int = 48,
                 on_idle=None, on_busy=None,
                 prefix_cache_hit_rate: float = 0.0,
                 chunked_prefill_budget: int | None = None,
                 decode_steps_per_sync: int = 1,
                 spec_tokens: int = 0, spec_accept_rate: float = 0.8,
                 draft_cost: InstanceCost | None = None,
                 scheduling_policy: str = "fcfs",
                 enable_preemption: bool = False,
                 restore_hit_rate: float = 1.0,
                 role: str = "unified", on_handoff=None):
        self.loop = loop
        self.cost = cost
        self.max_slots = max_slots
        self.on_idle = on_idle
        self.on_busy = on_busy
        if role not in ("unified", "prefill-heavy", "decode-heavy"):
            raise ValueError(f"unknown engine role {role!r}")
        # disaggregated serving: a prefill-heavy engine ingests prompts,
        # emits each sequence's FIRST token, then offers the sequence to
        # ``on_handoff(sreq, produced) -> bool`` — True moves it to a
        # decode-role engine (via the resume/restore machinery), False
        # keeps decoding here (unified fallback)
        self.role = role
        self.on_handoff = on_handoff
        self.prefix_cache_hit_rate = prefix_cache_hit_rate
        self.chunked_prefill_budget = chunked_prefill_budget
        self.decode_steps_per_sync = max(int(decode_steps_per_sync), 1)
        self.spec_tokens = max(int(spec_tokens), 0)
        self.spec_accept_rate = spec_accept_rate
        self.draft_cost = draft_cost
        if self.spec_tokens and draft_cost is None:
            raise ValueError("spec_tokens > 0 requires draft_cost")
        if scheduling_policy not in ("fcfs", "priority", "edf"):
            raise ValueError(f"unknown scheduling policy "
                             f"{scheduling_policy!r}")
        self.scheduling_policy = scheduling_policy
        self.enable_preemption = enable_preemption
        self.restore_hit_rate = restore_hit_rate
        # (sreq, on_first_token, on_done, on_delta) waiting entries
        self.queue: list[tuple] = []
        self.running: list[dict] = []
        # preempted victims awaiting re-admission (restore): running-dicts
        # with their produced-token state preserved
        self._preempted_q: list[dict] = []
        self._seq = itertools.count()
        self._seq_of: dict[str, int] = {}     # request_id -> arrival order
        self._step_ev = None
        self._step_k = 1
        self._composition_changed = False
        self.total_output_tokens = 0
        self.total_finished = 0
        self.total_cached_tokens = 0
        self.total_restore_cached_tokens = 0
        self.total_resumed_tokens = 0
        self.total_preemptions = 0
        self.total_aborted = 0
        self.total_handoffs = 0
        self.halted = False

    # -- load signals ----------------------------------------------------------
    @property
    def load(self) -> int:
        return len(self.queue) + len(self._preempted_q) + len(self.running)

    @property
    def queue_depth(self) -> int:
        return len(self.queue) + len(self._preempted_q)

    def saturated(self) -> bool:
        return len(self.running) >= self.max_slots and self.queue_depth > 0

    # -- ops -----------------------------------------------------------------------
    def submit(self, sreq: SimRequest, on_first_token, on_done,
               on_delta=None):
        """``on_delta(n_tokens, t, offset)`` — optional per-sync token
        stream: fired every engine step this request emits tokens in, with
        ``offset`` the stream position of the burst's first token (the DES
        mirror of the real engine's StreamDelta channel)."""
        if self.halted:
            raise RuntimeError("engine halted")
        self._seq_of[sreq.request_id] = next(self._seq)
        self.queue.append((sreq, on_first_token, on_done, on_delta))
        if self.on_busy:
            self.on_busy()
        self._kick()

    def abort(self, request_id: str) -> bool:
        """Drop a request wherever it lives (queued, preempted, running);
        its slot frees at once. Client disconnects and losing hedges land
        here via the endpoint's pre-registered 'abort' function."""
        for i, e in enumerate(self.queue):
            if e[0].request_id == request_id:
                del self.queue[i]
                self._seq_of.pop(request_id, None)
                self.total_aborted += 1
                return True
        for i, e in enumerate(self._preempted_q):
            if e["req"].request_id == request_id:
                del self._preempted_q[i]
                self.total_aborted += 1
                return True
        for i, e in enumerate(self.running):
            if e["req"].request_id == request_id:
                del self.running[i]
                self._composition_changed = True
                self.total_aborted += 1
                return True
        return False

    def take_queued(self) -> list[tuple]:
        """Remove and return every waiting fresh entry (work stealing).
        The robbed engine's ``_seq_of`` must shrink with its queue — the
        arrival order is re-issued by the receiving engine's ``submit`` —
        or the map leaks one entry per stolen request forever."""
        entries = list(self.queue)
        self.queue.clear()
        for e in entries:
            self._seq_of.pop(e[0].request_id, None)
        return entries

    def halt(self) -> list[SimRequest]:
        """Stop serving (failure/release); returns in-flight requests for
        requeue.  Requests that already produced tokens are stamped with
        ``resume_tokens`` so the next engine RESUMES them (restore prefill
        of prompt+generated) instead of regenerating from scratch — the
        stream offsets stay contiguous and the client never re-receives a
        token."""
        self.halted = True
        if self._step_ev:
            self.loop.cancel(self._step_ev)
            self._step_ev = None
        for r in self.running + self._preempted_q:
            r["req"].resume_tokens = r["produced"]
        inflight = [r["req"] for r in self.running] + \
            [r["req"] for r in self._preempted_q] + \
            [q[0] for q in self.queue]
        self.running.clear()
        self._preempted_q.clear()
        self.queue.clear()
        self._seq_of.clear()
        return inflight

    # -- QoS scheduling mirror --------------------------------------------------
    def _key(self, sreq: SimRequest, seq: int) -> tuple:
        """Admission order: FCFS = arrival; priority = (class, priority,
        arrival); EDF = (deadline, arrival) with None sorting last."""
        if self.scheduling_policy == "priority":
            return (class_rank(sreq.qos), sreq.priority, seq)
        if self.scheduling_policy == "edf":
            d = float("inf") if sreq.deadline is None else sreq.deadline
            return (d, seq)
        return (seq,)

    def _urgency(self, sreq: SimRequest) -> float:
        if self.scheduling_policy == "priority":
            return class_rank(sreq.qos)
        if self.scheduling_policy == "edf":
            return float("inf") if sreq.deadline is None else sreq.deadline
        return 0.0

    def _next_waiting(self):
        """(key, kind, idx) of the most urgent waiting entry, or None.
        Preempted victims keep their original arrival order, so they sort
        ahead of later arrivals of the same class."""
        best = None
        for idx, e in enumerate(self._preempted_q):
            k = self._key(e["req"], e["seq"])
            if best is None or k < best[0]:
                best = (k, "restore", idx)
        for idx, (sreq, *_cbs) in enumerate(self.queue):
            k = self._key(sreq, self._seq_of[sreq.request_id])
            if best is None or k < best[0]:
                best = (k, "fresh", idx)
        return best

    def _pick_victim(self, head: SimRequest) -> dict | None:
        """Most recently admitted running entry strictly less urgent than
        ``head`` (mid-prefill entries are not preemptible — restoring them
        would just repeat the same prefill)."""
        for e in reversed(self.running):
            if e["prefill_left"] > 0:
                continue
            if self._urgency(e["req"]) > self._urgency(head):
                return e
        return None

    def _admit_one(self) -> bool:
        pick = self._next_waiting()
        if pick is None:
            return False
        if len(self.running) >= self.max_slots:
            if not (self.enable_preemption
                    and self.scheduling_policy != "fcfs"):
                return False
            head = (self._preempted_q[pick[2]]["req"] if pick[1] == "restore"
                    else self.queue[pick[2]][0])
            victim = self._pick_victim(head)
            if victim is None:
                return False
            self.running.remove(victim)
            victim["preemptions"] = victim.get("preemptions", 0) + 1
            self.total_preemptions += 1
            self._composition_changed = True
            self._preempted_q.append(victim)
            pick = self._next_waiting()      # indices moved; re-resolve
        _key, kind, idx = pick
        if kind == "restore":
            e = self._preempted_q.pop(idx)
            # restore = recompute-via-prefix-cache prefill of the tokens
            # the cache does not cover (costmodel.restore_tokens). Tracked
            # apart from the prompt prefix-cache discount — the real
            # engine's RequestMetrics keeps cached_prompt_tokens and
            # restore_cached_tokens distinct too
            held = e["req"].prompt_tokens + e["produced"]
            restore = restore_tokens(held, self.restore_hit_rate)
            e["prefill_left"] = restore
            self.total_restore_cached_tokens += max(held - restore, 0)
            e["restore_cached"] = e.get("restore_cached", 0) \
                + max(held - restore, 0)
            self.running.append(e)
        elif self.queue[idx][0].resume_tokens > 0:
            sreq, on_first, on_done, on_delta = self.queue.pop(idx)
            # failover resume: this request already streamed tokens on an
            # engine that died. Restore = chunked-prefill recompute of
            # (prompt + generated) through the prefix cache — the
            # cross-engine analogue of a preemption restore — then decode
            # continues from resume_tokens, so delta offsets stay
            # contiguous with what the client already holds.
            resume = min(sreq.resume_tokens, sreq.max_tokens)
            held = sreq.prompt_tokens + resume
            restore = restore_tokens(held, self.restore_hit_rate)
            cached = max(held - restore, 0)
            self.total_restore_cached_tokens += cached
            self.total_resumed_tokens += resume
            self.running.append({"req": sreq,
                                 "produced": resume,
                                 "prefill_left": restore, "chunks": 0,
                                 "cached": 0, "restore_cached": cached,
                                 "resumed": resume,
                                 "seq": self._seq_of.pop(sreq.request_id),
                                 "on_first": on_first, "on_done": on_done,
                                 "on_delta": on_delta})
        else:
            sreq, on_first, on_done, on_delta = self.queue.pop(idx)
            # warm-cache discount: matched prefix tokens cost no compute;
            # at least one token is always recomputed (its logits seed
            # sampling), mirroring PagedKVCache.allocate_with_prefix
            eff = max(int(round(sreq.prompt_tokens
                                * (1.0 - self.prefix_cache_hit_rate))), 1)
            self.total_cached_tokens += sreq.prompt_tokens - eff
            self.running.append({"req": sreq, "produced": 0,
                                 "prefill_left": eff, "chunks": 0,
                                 "cached": sreq.prompt_tokens - eff,
                                 # the arrival order moves into the entry;
                                 # _seq_of must not grow with engine age
                                 "seq": self._seq_of.pop(sreq.request_id),
                                 "on_first": on_first, "on_done": on_done,
                                 "on_delta": on_delta})
        return True

    # -- internals ------------------------------------------------------------
    def _kick(self):
        if self._step_ev is None and not self.halted:
            self._schedule_step()

    def _schedule_step(self):
        admitted = False
        while self._admit_one():
            admitted = True
        if not self.running:
            self._step_ev = None
            if self.on_idle:
                self.on_idle()
            return
        # consume prompt tokens FIFO up to the chunk budget (all of them
        # when chunking is off); only their compute lands in this step
        prefill_cost = 0.0
        left = self.chunked_prefill_budget or float("inf")
        for r in self.running:
            if left <= 0:
                break
            if r["prefill_left"] > 0:
                take = min(r["prefill_left"], left)
                r["prefill_left"] -= take
                r["chunks"] += 1
                left -= take
                prefill_cost += self.cost.prefill_time(take)
        # multi-step decode: K tokens per sync unless a prefill is in
        # flight or the batch composition changed — admissions AND the
        # finishes/frees of the previous sync, which dirty the real
        # engine's slot state (same fallback rule as
        # ContinuousBatchingEngine._decode_fused)
        steady = not (admitted or self._composition_changed
                      or prefill_cost > 0
                      or any(r["prefill_left"] > 0 for r in self.running))
        self._composition_changed = False
        batch = len(self.running)
        ctx = sum(r["req"].prompt_tokens + r["produced"]
                  for r in self.running) / batch
        ctx = max(int(ctx), 1)
        if self.spec_tokens and steady:
            # speculative round: k+1 draft steps + one verify forward per
            # expected_spec_tokens(a, k) tokens per sequence
            self._step_k = max(int(round(expected_spec_tokens(
                self.spec_accept_rate, self.spec_tokens))), 1)
            dt = self.cost.spec_round_time(batch, self.draft_cost,
                                           self.spec_tokens, ctx=ctx) \
                + prefill_cost
        else:
            k = self.decode_steps_per_sync if steady else 1
            self._step_k = k
            dt = k * self.cost.decode_step_time(batch, ctx=ctx,
                                                steps_per_sync=k) \
                + prefill_cost
        self._step_ev = self.loop.call_after(dt, self._finish_step)

    def _finish_step(self):
        self._step_ev = None
        if self.halted:
            return
        now = self.loop.now()
        still = []
        for r in self.running:
            if r["prefill_left"] > 0:           # still ingesting its prompt
                still.append(r)
                continue
            first = r["produced"] == 0
            # a sequence reaching max_tokens mid-sync stops there, like the
            # device loop's done mask freezing the slot
            take = min(self._step_k, r["req"].max_tokens - r["produced"])
            r["produced"] += take
            self.total_output_tokens += take
            if first and r["on_first"]:
                r["on_first"](now)
            if take and r.get("on_delta"):
                r["on_delta"](take, now, r["produced"] - take)
            if r["produced"] >= r["req"].max_tokens:
                self.total_finished += 1
                self._composition_changed = True   # next sync runs K=1
                if r["on_done"]:
                    r["on_done"]({"request_id": r["req"].request_id,
                                  "output_tokens": r["produced"],
                                  "cached_prompt_tokens": r["cached"],
                                  "restore_cached_tokens":
                                      r.get("restore_cached", 0),
                                  "resumed_tokens": r.get("resumed", 0),
                                  "preemptions": r.get("preemptions", 0),
                                  "prefill_chunks": r["chunks"],
                                  "finish_time": now})
                continue
            # disaggregated prefill role: the prompt is ingested and the
            # first token(s) just streamed — offer the sequence to a
            # decode-role engine. resume_tokens carries the produced count
            # so the receiver restores (prompt + produced) through the
            # prefix-cache machinery and the stream continues contiguously.
            if (self.role == "prefill-heavy" and self.on_handoff is not None
                    and not r["req"].no_handoff):
                r["req"].resume_tokens = r["produced"]
                if self.on_handoff(r["req"], r["produced"]):
                    self.total_handoffs += 1
                    self._composition_changed = True
                    continue           # the entry leaves; no on_done here
            still.append(r)
        self.running = still
        self._schedule_step()


class ModelInstance:
    """One serving job: scheduler job -> weight load -> hot engine."""

    def __init__(self, loop, model_name: str, cost: InstanceCost,
                 scheduler, *, num_nodes: int = 1, max_slots: int = 48,
                 idle_timeout: float = 7200.0, on_released=None,
                 on_failed=None, on_hot=None, walltime: float | None = None,
                 result_cpu: float = 0.0,
                 prefix_cache_hit_rate: float = 0.0,
                 chunked_prefill_budget: int | None = None,
                 decode_steps_per_sync: int = 1,
                 spec_tokens: int = 0, spec_accept_rate: float = 0.8,
                 draft_cost: InstanceCost | None = None,
                 scheduling_policy: str = "fcfs",
                 enable_preemption: bool = False,
                 restore_hit_rate: float = 1.0,
                 role: str = "unified", on_handoff=None):
        self.loop = loop
        self.model_name = model_name
        self.cost = cost
        self.scheduler = scheduler
        self.idle_timeout = idle_timeout
        # per-instance Globus-worker result serialization (packaging +
        # upload happen on ONE endpoint worker process per instance)
        self.result_cpu = result_cpu
        self._result_busy = 0
        self._result_q: list = []
        self.instance_id = f"{model_name}#{next(_inst_ids)}"
        self.state = InstanceState.PENDING
        self.on_released = on_released
        self.on_failed = on_failed
        self.on_hot = on_hot
        self._pending: list[tuple[SimRequest, object, object]] = []
        self._idle_ev = None
        self.engine = SimEngine(loop, cost, max_slots=max_slots,
                                on_idle=self._went_idle,
                                on_busy=self._went_busy,
                                prefix_cache_hit_rate=prefix_cache_hit_rate,
                                chunked_prefill_budget=chunked_prefill_budget,
                                decode_steps_per_sync=decode_steps_per_sync,
                                spec_tokens=spec_tokens,
                                spec_accept_rate=spec_accept_rate,
                                draft_cost=draft_cost,
                                scheduling_policy=scheduling_policy,
                                enable_preemption=enable_preemption,
                                restore_hit_rate=restore_hit_rate,
                                role=role, on_handoff=on_handoff)
        self.role = role
        self.hot_since = None
        # when this HOT instance last drained to zero work (None while
        # busy/cold) — the pool-level keepalive scale-in reads this
        self.idle_since = None
        self.created = loop.now()
        self.job = scheduler.submit(num_nodes, on_start=self._nodes_ready,
                                    on_end=self._job_ended,
                                    walltime=walltime)

    # -- lifecycle ------------------------------------------------------------
    def _nodes_ready(self, job):
        if self.state != InstanceState.PENDING:
            return
        self.state = InstanceState.LOADING
        self.loop.call_after(self.cost.load_time(), self._loaded)

    def _loaded(self):
        if self.state != InstanceState.LOADING:
            return
        self.state = InstanceState.HOT
        self.hot_since = self.loop.now()
        for sreq, on_first, on_done, on_delta in self._pending:
            self.engine.submit(sreq, on_first, on_done, on_delta)
        self._pending.clear()
        if self.on_hot:
            self.on_hot(self)
        if self.engine.load == 0:
            self._went_idle()

    def _job_ended(self, job):
        if self.state in (InstanceState.RELEASED, InstanceState.FAILED):
            return
        failed = job.state.value == "failed"
        self.fail() if failed else self.release()

    # -- serving -----------------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self.state in (InstanceState.PENDING, InstanceState.LOADING,
                              InstanceState.HOT)

    @property
    def load(self) -> int:
        return len(self._pending) + self.engine.load

    def submit(self, sreq: SimRequest, on_first_token, on_done,
               on_delta=None):
        assert self.alive, f"submit to {self.state} instance"
        self._cancel_idle()
        if self.result_cpu > 0:
            on_done = self._serialized(on_done)
        if self.state == InstanceState.HOT:
            self.engine.submit(sreq, on_first_token, on_done, on_delta)
        else:
            self._pending.append((sreq, on_first_token, on_done, on_delta))

    def abort(self, request_id: str) -> bool:
        """Abort a request parked on or running in this instance."""
        for i, p in enumerate(self._pending):
            if p[0].request_id == request_id:
                del self._pending[i]
                return True
        if self.engine.abort(request_id):
            if self.engine.load == 0 and not self._pending:
                self._went_idle()
            return True
        return False

    def _serialized(self, on_done):
        """Charge ``result_cpu`` per completion on the instance's single
        endpoint-worker thread before the result leaves the node."""
        def wrapped(result):
            self._result_q.append((on_done, result))
            self._pump_results()
        return wrapped

    def _pump_results(self):
        if self._result_busy or not self._result_q:
            return
        self._result_busy = 1
        on_done, result = self._result_q.pop(0)

        def _fire():
            self._result_busy = 0
            on_done(result)
            self._pump_results()

        self.loop.call_after(self.result_cpu, _fire)

    # -- hot-node management (paper §3.2.2) ----------------------------------------
    def _went_idle(self):
        if self.state != InstanceState.HOT:
            return
        if self.idle_since is None:
            self.idle_since = self.loop.now()
        if self.idle_timeout is not None:
            self._cancel_idle()
            # daemon: housekeeping must not keep the event loop "busy"
            self._idle_ev = self.loop.call_after(self.idle_timeout,
                                                 self._idle_release,
                                                 daemon=True)

    def _went_busy(self):
        self.idle_since = None
        self._cancel_idle()

    def _cancel_idle(self):
        if self._idle_ev is not None:
            self.loop.cancel(self._idle_ev)
            self._idle_ev = None

    def _idle_release(self):
        if self.state == InstanceState.HOT and self.engine.load == 0:
            self.release()

    # -- teardown ------------------------------------------------------------------
    def release(self):
        if not self.alive:
            return
        self.state = InstanceState.RELEASED
        self._cancel_idle()
        inflight = self.engine.halt() + [p[0] for p in self._pending]
        self._pending.clear()
        self.scheduler.release(self.job)
        if self.on_released:
            self.on_released(self, inflight)

    def fail(self):
        if not self.alive:
            return
        self.state = InstanceState.FAILED
        self._cancel_idle()
        inflight = self.engine.halt() + [p[0] for p in self._pending]
        self._pending.clear()
        # a dead serving process must not pin its nodes: release the batch
        # job (no-op when the job itself died — release() ignores ended/
        # failed jobs) so replacement capacity can start
        self.scheduler.release(self.job)
        if self.on_failed:
            self.on_failed(self, inflight)
