"""Globus-Auth analogue: token issuance, introspection, group-based RBAC, and
the gateway-side introspection cache (paper Optimization 2 — caching removed
~2 s/request and avoided provider rate limits)."""
from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field

_tok_counter = itertools.count()

TOKEN_TTL = 48 * 3600.0            # paper §4.6: tokens valid 48 h


class AuthError(Exception):
    pass


@dataclass
class Identity:
    user: str
    groups: tuple = ()
    expires_at: float = 0.0


class AuthService:
    """The identity provider (runs 'remotely': introspection costs latency)."""

    def __init__(self, loop, introspection_latency: float = 2.0,
                 rate_limit_per_s: float = 50.0):
        self.loop = loop
        self.introspection_latency = introspection_latency
        self.rate_limit_per_s = rate_limit_per_s
        self._tokens: dict[str, Identity] = {}
        self._groups: dict[str, set] = {}
        self._window_start = 0.0
        self._window_count = 0
        self.introspections = 0

    # -- admin ------------------------------------------------------------------
    def add_user(self, user: str, groups=()):
        self._groups[user] = set(groups)

    def issue_token(self, user: str) -> str:
        if user not in self._groups:
            raise AuthError(f"unknown user {user}")
        raw = f"{user}:{next(_tok_counter)}"
        tok = hashlib.sha256(raw.encode()).hexdigest()[:32]
        self._tokens[tok] = Identity(
            user=user, groups=tuple(sorted(self._groups[user])),
            expires_at=self.loop.now() + TOKEN_TTL)
        return tok

    def refresh(self, token: str) -> str:
        ident = self._tokens.get(token)
        if ident is None:
            raise AuthError("unknown token")
        return self.issue_token(ident.user)

    # -- introspection (remote call: latency + provider rate limit) --------------
    def introspect(self, token: str, cb):
        """Async introspection; calls cb(identity or AuthError)."""
        now = self.loop.now()
        if now - self._window_start >= 1.0:
            self._window_start, self._window_count = now, 0
        self._window_count += 1
        if self._window_count > self.rate_limit_per_s:
            self.loop.call_after(self.introspection_latency, cb,
                                 AuthError("identity provider rate limited"))
            return
        self.introspections += 1
        ident = self._tokens.get(token)
        result = ident if ident and ident.expires_at > now else \
            AuthError("invalid or expired token")
        self.loop.call_after(self.introspection_latency, cb, result)


class CachingAuthClient:
    """Gateway-side cache of token introspections (Optimization 2)."""

    def __init__(self, loop, service: AuthService, ttl: float = 600.0,
                 enabled: bool = True):
        self.loop = loop
        self.service = service
        self.ttl = ttl
        self.enabled = enabled
        self._cache: dict[str, tuple[float, Identity]] = {}
        self._inflight: dict[str, list] = {}   # coalesce concurrent lookups
        self.hits = 0
        self.misses = 0

    def validate(self, token: str, cb):
        """cb(Identity) on success, cb(AuthError) on failure. Concurrent
        lookups of the same token coalesce into ONE introspection — a burst
        of first requests must not trip the provider's rate limit."""
        now = self.loop.now()
        if self.enabled:
            hit = self._cache.get(token)
            if hit and hit[0] > now:
                self.hits += 1
                self.loop.call_after(0.0, cb, hit[1])
                return
            if token in self._inflight:
                self.hits += 1
                self._inflight[token].append(cb)
                return
        self.misses += 1
        if self.enabled:
            self._inflight[token] = [cb]

        def _store(result):
            if isinstance(result, Identity) and self.enabled:
                self._cache[token] = (self.loop.now() + self.ttl, result)
            waiters = self._inflight.pop(token, [cb]) if self.enabled else [cb]
            for w in waiters:
                w(result)

        self.service.introspect(token, _store)


@dataclass
class AccessPolicy:
    """Globus-groups-style RBAC: which groups may use which models."""
    model_groups: dict = field(default_factory=dict)   # model -> required group
    default_allow: bool = True

    def allowed(self, ident: Identity, model: str) -> bool:
        need = self.model_groups.get(model)
        if need is None:
            return self.default_allow
        return need in ident.groups
