"""Engine cache backends.

SlotBackend  — contiguous per-slot KV/state cache, works for every family
               (attention, SSM, hybrid). The cache pytree has batch axis
               ``max_slots``; prefill fills one slot, decode steps all slots.
PagedBackend — vLLM-style paged KV pool with block tables, for attention
               families; decode attention goes through the paged-attention
               path (pure-jnp page gather on CPU, Pallas kernel on TPU via
               ``use_kernel=True``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import LM
from repro.models.layers import rms_norm, project_qkv, mlp_layer
from repro.models.moe import moe_ffn
from repro.models.transformer import _block
from repro.serving.kv_cache import PagedKVCache
from repro.kernels.paged_attention.ops import paged_attention as paged_attn_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class SlotBackend:
    """Contiguous cache with ``max_slots`` sequences of up to ``max_len``."""

    def __init__(self, model: LM, params, *, max_slots: int, max_len: int):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len)
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.slot_of: dict[str, int] = {}

        def _insert(cache, slot_cache, slot):
            def ins(big, small):
                ax = 0 if big.ndim == 1 else 1
                idx = [slice(None)] * big.ndim
                idx[ax] = slot
                return big.at[tuple(idx)].set(
                    jnp.squeeze(small, ax) if small.ndim == big.ndim else small)
            return jax.tree.map(ins, cache, slot_cache)

        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._prefill = {}  # bucket -> jitted fn
        self._decode = jax.jit(
            lambda p, toks, cache: self.model.decode_step(p, toks, cache),
            donate_argnums=(2,))

    # -- capacity -------------------------------------------------------------
    def can_admit(self, n_prompt: int) -> bool:
        return bool(self.free_slots) and n_prompt < self.max_len

    # -- ops --------------------------------------------------------------------
    def prefill(self, seq_id: str, prompt: list[int]):
        """Returns last-token logits (V,)."""
        slot = self.free_slots.pop()
        self.slot_of[seq_id] = slot
        S = len(prompt)
        # SSM/hybrid state is polluted by right-padding, so those use exact
        # lengths (one compile per distinct length); attention families use
        # power-of-two buckets with a masked last_index.
        if self.cfg.family in ("ssm", "hybrid"):
            bucket = S
        else:
            bucket = min(_bucket(S), self.max_len)
        if bucket not in self._prefill:
            def fn(params, toks, true_len):
                logits, cache = self.model.prefill(
                    params, {"tokens": toks}, max_len=self.max_len,
                    last_index=true_len - 1, moe_mode="dense")
                cache["len"] = jnp.full_like(cache["len"], true_len)
                return logits, cache
            self._prefill[bucket] = jax.jit(fn)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = prompt
        logits, slot_cache = self._prefill[bucket](
            self.params, jnp.asarray(toks), S)
        self.cache = self._insert(self.cache, slot_cache, slot)
        return np.asarray(logits)[0]

    def decode_batch(self, tokens_by_slot: np.ndarray):
        """tokens_by_slot: (max_slots,) int32. Returns logits (max_slots, V)."""
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens_by_slot),
                                          self.cache)
        return np.asarray(logits)

    def free(self, seq_id: str):
        slot = self.slot_of.pop(seq_id)
        self.free_slots.append(slot)

    def slot(self, seq_id: str) -> int:
        return self.slot_of[seq_id]


class PagedBackend:
    """Paged KV cache backend for attention-family models."""

    def __init__(self, model: LM, params, *, max_slots: int, max_len: int,
                 page_size: int = 128, num_pages: int | None = None,
                 use_kernel: bool = False):
        cfg = model.cfg
        assert cfg.family in ("dense", "moe", "vlm"), \
            "paged backend supports attention families"
        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = -(-max_len // page_size)
        if num_pages is None:
            num_pages = max_slots * self.pages_per_seq + 1  # +1: trash page 0
        self.kv = PagedKVCache(num_pages, page_size)
        L, KH, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        dtype = jnp.dtype(cfg.param_dtype)
        self.pools = {
            "k": jnp.zeros((L, num_pages, page_size, KH, hd), dtype),
            "v": jnp.zeros((L, num_pages, page_size, KH, hd), dtype),
        }
        self.use_kernel = use_kernel
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.slot_of: dict[str, int] = {}
        self.seq_of: dict[int, str] = {}
        self._prefill = {}
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))

    # -- capacity -------------------------------------------------------------
    def can_admit(self, n_prompt: int) -> bool:
        return (bool(self.free_slots)
                and self.kv.can_allocate(n_prompt + 1)
                and n_prompt < self.max_len)

    # -- jitted bodies ----------------------------------------------------------
    def _attend(self, q, kp, vp, tables, lens):
        if self.use_kernel:
            return paged_attn_kernel(q, kp, vp, tables, lens, interpret=True)
        return paged_attention_ref(q, kp, vp, tables, lens)

    def _prefill_impl(self, params, toks, pools, table, true_len, *, n_pages):
        """toks: (1, S_bucket); table: (n_pages,) page ids for this seq."""
        cfg = self.cfg
        model = self.model
        S = toks.shape[1]
        x = model.embed_inputs(params, {"tokens": toks})
        positions = jnp.arange(S)[None, :]

        def body(h, xs):
            lp, kp, vp = xs
            h2, (k, v), _ = _block(h, lp, cfg, positions, moe_mode="dense",
                                   return_kv=True)
            kpg = k[0].reshape(n_pages, self.page_size, *k.shape[2:])
            vpg = v[0].reshape(n_pages, self.page_size, *v.shape[2:])
            kp = kp.at[table].set(kpg.astype(kp.dtype))
            vp = vp.at[table].set(vpg.astype(vp.dtype))
            return h2, (kp, vp)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                         pools["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        idx = jnp.maximum(true_len - 1, 0)
        logits = model.logits(params, h[:, idx])
        return logits[0], {"k": nk, "v": nv}

    def _decode_impl(self, params, pools, tokens, tables, lens):
        """tokens: (B,); tables: (B, PPS); lens: (B,) current lengths.
        The page for position ``lens`` must already exist (ensure_slot)."""
        cfg = self.cfg
        model = self.model
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        positions = lens[:, None]
        page_slot = lens // self.page_size                     # (B,)
        page_idx = jnp.take_along_axis(tables, page_slot[:, None], 1)[:, 0]
        off = lens % self.page_size

        def body(h, xs):
            lp, kp, vp = xs
            xa = rms_norm(h, lp["norm1"], cfg.norm_eps)
            q, k, v = project_qkv(xa, lp["attn"], cfg, positions)
            kp = kp.at[page_idx, off].set(k[:, 0].astype(kp.dtype))
            vp = vp.at[page_idx, off].set(v[:, 0].astype(vp.dtype))
            a = self._attend(q[:, 0], kp, vp, tables, lens + 1)  # (B,H,hd)
            h = h + (a.reshape(B, 1, -1) @ lp["attn"]["wo"])
            g = rms_norm(h, lp["norm2"], cfg.norm_eps)
            if cfg.moe:
                f, _ = moe_ffn(g, lp["moe"], cfg, mode="dense")
            else:
                f = mlp_layer(g, lp["mlp"])
            return h + f, (kp, vp)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                         pools["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = model.logits(params, h[:, 0])
        return logits, {"k": nk, "v": nv}

    # -- public ops ---------------------------------------------------------------
    def prefill(self, seq_id: str, prompt: list[int]):
        slot = self.free_slots.pop()
        self.slot_of[seq_id] = slot
        self.seq_of[slot] = seq_id
        S = len(prompt)
        bucket = min(_bucket(max(S, self.page_size)), self.max_len)
        bucket = -(-bucket // self.page_size) * self.page_size
        n_pages = bucket // self.page_size
        pages = self.kv.allocate(seq_id, S)
        # padded tail of the bucket writes land in trash page 0 (copy — do
        # not mutate the sequence's own table)
        write_table = list(pages) + [0] * (n_pages - len(pages))
        write_table = write_table[:n_pages]
        if bucket not in self._prefill:
            self._prefill[bucket] = jax.jit(
                partial(self._prefill_impl, n_pages=n_pages),
                donate_argnums=(2,))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = prompt
        logits, self.pools = self._prefill[bucket](
            self.params, jnp.asarray(toks), self.pools,
            jnp.asarray(np.array(write_table, np.int32)), S)
        return np.asarray(logits)

    def decode_batch(self, tokens_by_slot: np.ndarray):
        """tokens_by_slot: (max_slots,). Inactive slots write to trash page 0."""
        for sid in self.slot_of:
            self.kv.ensure_slot(sid)
        tables = np.zeros((self.max_slots, self.pages_per_seq), np.int32)
        lens = np.zeros((self.max_slots,), np.int32)
        for slot, sid in self.seq_of.items():
            tables[slot] = self.kv.table_array([sid], self.pages_per_seq)[0]
            lens[slot] = self.kv.length(sid)
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(tokens_by_slot),
            jnp.asarray(tables), jnp.asarray(lens))
        for sid in self.slot_of:
            self.kv.advance(sid)
        return np.asarray(logits)

    def free(self, seq_id: str):
        slot = self.slot_of.pop(seq_id)
        self.seq_of.pop(slot, None)
        self.free_slots.append(slot)
        self.kv.free(seq_id)

    def slot(self, seq_id: str) -> int:
        return self.slot_of[seq_id]
