"""Engine cache backends.

SlotBackend  — contiguous per-slot KV/state cache, works for every family
               (attention, SSM, hybrid). The cache pytree has batch axis
               ``max_slots``; prefill fills one slot, decode steps all slots.
PagedBackend — vLLM-style paged KV pool with block tables, for attention
               families; decode attention goes through the paged-attention
               path. ``use_kernel=True`` picks the no-per-step-gather hot
               path: compiled Pallas kernels on TPU (shard_map'd over the
               kv-head axis under a mesh), and on other backends an "XLA
               twin" with the same memory-traffic structure — a cached
               contiguous context view plus per-call tail buffers instead
               of a full page gather and pool scatter every step.

Both backends expose two decode paths:

* ``decode_batch(tokens)`` — legacy host-driven step: one jitted model call,
  the full ``(max_slots, V)`` logits come back to the host and the engine
  samples there. Every step pays a device->host logits transfer plus a
  second sampling dispatch.
* ``fused_decode(K, host_state)`` — device-resident fast path: a single
  jitted, donated call runs K decode steps under ``lax.fori_loop``, each
  step fusing model forward + top-p sampling + stop/length checks on
  device. Per-slot sampling state (temperature/top-p/seed base/limits) and,
  for the paged backend, block tables and lengths stay resident across
  calls; only ``(K, max_slots)`` token ids and tiny ``(max_slots,)``
  produced/done vectors are synced to the host. Logits never leave the
  device (asserted via ``TRANSFER_STATS``).

Speculative decoding adds a third call, ``spec_verify(draft_tokens)``: ONE
jitted forward verifies the k proposed tokens plus the guaranteed target
token for every slot (write KV at len..len+k, attend causally, sample all
k+1 seeded targets, latch stops/limits, truncate to the accepted prefix) —
the multi-token analogue of one fused step, with the same state-residency
and zero-logits-transfer contract. ``spec_headroom``/``reset_lens`` are its
host-side page-reservation and draft-rollback companions.

Both backends speak the same prefill protocol to the engine:

  task = backend.start_prefill(seq_id, prompt)   # reserve slot/pages
  logits, n = backend.prefill_chunk(task, budget) # compute <= budget tokens
  ... repeat until logits is not None (prompt fully ingested) ...

``start_prefill`` on the paged backend also consults the prefix cache:
tokens covered by content-matched pages are skipped (``task.pos`` starts
past them), which is where shared-system-prompt workloads win. A sequence
only joins the decode batch once its prefill completes (``backend.activate``
is implied by the final chunk); mid-prefill sequences are excluded from
decode bookkeeping and their batch slots write to the trash page.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.sharding import ServeSharding
from repro.models import LM
from repro.models.layers import (NEG_INF, chunked_attention, mlp_layer,
                                 project_qkv, rms_norm)
from repro.models.moe import moe_ffn
from repro.models.transformer import _block
from repro.serving.kv_cache import OutOfPages, PagedKVCache
from repro.kernels.flash_attention.ops import paged_flash_prefill
from repro.kernels.paged_attention.ops import (
    fused_decode_attention, fused_decode_attention_sharded, kernels_compiled,
    paged_attention as paged_attn_kernel, paged_attention_sharded,
    shardable_kv_heads)
from repro.kernels.paged_attention.ref import (decode_tail_attention_ref,
                                               gather_kv, paged_attention_ref,
                                               paged_prefill_attention_ref)

from repro.serving.sampler import (fold_seeds, sample_from_logits,
                                   spec_accept, spec_targets)

ATTENTION_FAMILIES = ("dense", "moe", "vlm")

# -- host-transfer accounting -------------------------------------------------
# The fused decode path's contract is that logits never cross to the host;
# every logits device->host conversion in this module goes through
# ``_logits_to_host`` so tests can assert the fused path performs none.
# Sampled token ids / produced / done vectors are O(max_slots) ints and are
# the *intended* sync payload — they are not counted.
TRANSFER_STATS = {"decode_logits_transfers": 0, "decode_logits_bytes": 0}


def reset_transfer_stats() -> None:
    TRANSFER_STATS["decode_logits_transfers"] = 0
    TRANSFER_STATS["decode_logits_bytes"] = 0


def _logits_to_host(x) -> np.ndarray:
    out = np.asarray(x)
    TRANSFER_STATS["decode_logits_transfers"] += 1
    TRANSFER_STATS["decode_logits_bytes"] += out.nbytes
    return out


def _upload_state(host_state: dict, shard: ServeSharding | None = None) -> dict:
    # copy: jnp.asarray may alias numpy memory on CPU, and the fused call
    # donates the state buffers. Sharded engines replicate the state onto
    # the mesh's device set — sampling is replicated by construction.
    if shard is not None:
        return {k: shard.replicate(np.array(v)) for k, v in host_state.items()}
    return {k: jnp.asarray(np.array(v)) for k, v in host_state.items()}


def _sample_and_latch(st, logits, tokens, n_gen, done, produced, live):
    """Device-side sample + stop/limit latch for one fused decode step —
    the single definition both backends inline, so their token-identity
    semantics cannot diverge. ``live`` slots take the sampled token and
    advance; a live slot hitting its stop token or generation limit
    latches ``done`` and freezes from the next step on."""
    seeds = fold_seeds(st["seed_base"], n_gen)
    sampled = sample_from_logits(logits, st["temps"], st["top_ps"], seeds)
    tokens = jnp.where(live, sampled, tokens)
    n_gen = n_gen + live.astype(jnp.int32)
    hit_stop = (st["stop_tok"] >= 0) & (sampled == st["stop_tok"])
    done = done | (live & (hit_stop | (n_gen >= st["gen_limit"])))
    produced = produced + live.astype(jnp.int32)
    return tokens, n_gen, done, produced


def _spec_block_attention(q, k, v, lens, *, kv_major):
    """Attention for a speculative verify block of T tokens per slot.

    q: (B, T, H, D). k/v hold history PLUS the block's own KV (already
    written): kv-heads-major (B, KH, Smax, D) for the dense slot cache, or
    seq-major (B, S, KH, D) for a gathered page view. ``lens``: (B,) valid
    history length BEFORE the block — query j attends [0, lens + j + 1), the
    same visible set the sequential decode path sees at that position.
    """
    B, T, H, D = q.shape
    KH = k.shape[1] if kv_major else k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, T, KH, G, D).astype(jnp.float32)
    sub = "btkgd,bksd->bkgts" if kv_major else "btkgd,bskd->bkgts"
    s = jnp.einsum(sub, qr, k.astype(jnp.float32)) * scale
    S = s.shape[-1]
    ok = jnp.arange(S)[None, None, :] \
        < (lens[:, None] + 1 + jnp.arange(T))[:, :, None]      # (B, T, S)
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    sub = "bkgts,bksd->btkgd" if kv_major else "bkgts,bskd->btkgd"
    out = jnp.einsum(sub, p, v.astype(jnp.float32))
    return out.reshape(B, T, H, D).astype(q.dtype)


def _spec_accept_and_latch(st, logits, draft):
    """Device-side acceptance + stop/limit latch for one speculative round —
    the single definition both backends inline (the verify-path analogue of
    :func:`_sample_and_latch`). logits: (B, T, V) with T = k + 1; draft:
    (B, k). Emits the accepted draft prefix, the residual resample at the
    first mismatch (or the bonus token when everything matched), truncated
    at the first stop-token / generation-limit hit. Returns
    (targets (B, T), produced (B,), done (B,), st) with st's tokens/n_gen
    advanced by ``produced``.
    """
    T = logits.shape[1]
    targets = spec_targets(logits, st["temps"], st["top_ps"],
                           st["seed_base"], st["n_gen"])
    emit, n_emit = spec_accept(targets, draft)
    n2 = st["n_gen"][:, None] + 1 + jnp.arange(T, dtype=jnp.int32)[None, :]
    hit_stop = (st["stop_tok"][:, None] >= 0) \
        & (targets == st["stop_tok"][:, None])
    hit = emit & (hit_stop | (n2 >= st["gen_limit"][:, None]))
    any_hit = hit.any(axis=1)
    first_hit = jnp.argmax(hit, axis=1).astype(jnp.int32)
    produced = jnp.where(any_hit, first_hit + 1, n_emit)
    produced = jnp.where(st["active"], produced, 0)
    done = st["active"] & any_hit
    last = jnp.take_along_axis(
        targets, jnp.maximum(produced - 1, 0)[:, None], axis=1)[:, 0]
    tokens = jnp.where(produced > 0, last, st["tokens"])
    st = dict(st, tokens=tokens, n_gen=st["n_gen"] + produced)
    return targets, produced, done, st


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _chunk_layer(h, lp, cfg, positions, write_attend):
    """One transformer layer of a prefill chunk. The backends differ only in
    how a chunk's KV is written into their cache and attended against it —
    ``write_attend(q, k, v) -> (attn_out, new_cache_leaves)`` supplies that
    step; the residual/FFN structure stays in one place (mirrors
    transformer._block, which handles the no-cache and single-token cases).
    """
    B, S = h.shape[:2]
    xa = rms_norm(h, lp["norm1"], cfg.norm_eps)
    q, k, v = project_qkv(xa, lp["attn"], cfg, positions)
    a, new_cache = write_attend(q, k, v)
    h = h + (a.reshape(B, S, -1) @ lp["attn"]["wo"])
    g = rms_norm(h, lp["norm2"], cfg.norm_eps)
    if cfg.moe:
        f, _ = moe_ffn(g, lp["moe"], cfg, mode="dense")
    else:
        f = mlp_layer(g, lp["mlp"])
    return h + f, new_cache


@dataclass
class PrefillTask:
    """In-flight prompt ingestion state (one per admitted sequence)."""
    seq_id: str
    prompt: list
    pos: int = 0                    # next prompt position to compute
    cached_tokens: int = 0          # prefix tokens served from the page cache
    chunks: int = 0                 # chunks computed so far

    @property
    def remaining(self) -> int:
        return len(self.prompt) - self.pos

    @property
    def done(self) -> bool:
        return self.pos >= len(self.prompt)


class SlotBackend:
    """Contiguous cache with ``max_slots`` sequences of up to ``max_len``."""

    def __init__(self, model: LM, params, *, max_slots: int, max_len: int,
                 mesh=None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len)
        self.shard = ServeSharding(mesh, model.cfg) if mesh is not None \
            else None
        if self.shard is not None:
            self.params = self.shard.shard_params(params)
            self.cache = self.shard.shard_slot_cache(self.cache)
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.slot_of: dict[str, int] = {}

        def _insert(cache, slot_cache, slot):
            def ins(big, small):
                ax = 0 if big.ndim == 1 else 1
                idx = [slice(None)] * big.ndim
                idx[ax] = slot
                return big.at[tuple(idx)].set(
                    jnp.squeeze(small, ax) if small.ndim == big.ndim else small)
            return self._pin_cache(jax.tree.map(ins, cache, slot_cache))

        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._prefill = {}  # bucket -> jitted fn
        # one jit object; specializes per chunk-bucket shape
        self._chunk = jax.jit(self._chunk_impl, donate_argnums=(2,))

        def _decode(p, toks, cache):
            logits, cache = self.model.decode_step(p, toks, cache)
            return logits, self._pin_cache(cache)

        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._fused = {}        # K -> jitted multi-step decode+sample fn
        self._spec_fns = {}     # T -> jitted verify+accept fn
        self._dec_st = None     # device-resident per-slot decode state

    # -- sharded placement helpers ----------------------------------------------
    def _put(self, x):
        """Host upload: replicated onto the mesh device set when sharded."""
        return jnp.asarray(x) if self.shard is None \
            else self.shard.replicate(np.asarray(x))

    def _pin_cache(self, cache):
        """Pin cache leaves to their serving sharding inside jit, so the
        layout is a fixed point across donated calls (no-op unsharded)."""
        return cache if self.shard is None \
            else self.shard.pin_slot_cache(cache)

    def _pin_st(self, st):
        return st if self.shard is None else self.shard.pin_replicated(st)

    # -- capacity -------------------------------------------------------------
    def can_admit(self, n_prompt: int) -> bool:
        return bool(self.free_slots) and n_prompt < self.max_len

    @property
    def supports_chunked_prefill(self) -> bool:
        # SSM/hybrid state cannot be rebuilt from a cache slice, so those
        # families ingest prompts in one shot regardless of the budget
        return self.cfg.family in ATTENTION_FAMILIES

    # -- prefill protocol -------------------------------------------------------
    def start_prefill(self, seq_id: str, prompt: list) -> PrefillTask:
        slot = self.free_slots.pop()
        self.slot_of[seq_id] = slot
        return PrefillTask(seq_id=seq_id, prompt=list(prompt))

    def prefill_chunk(self, task: PrefillTask, budget: int | None = None):
        """Compute up to ``budget`` prompt tokens (all remaining if None).
        Returns (last_token_logits | None, tokens_computed)."""
        S = len(task.prompt)
        if budget is None or not self.supports_chunked_prefill:
            chunk = task.remaining
        else:
            chunk = min(max(budget, 1), task.remaining)
        if task.pos == 0 and chunk == S:
            logits = self._one_shot(task.seq_id, task.prompt)
            task.pos = S
            task.chunks += 1
            return logits, S
        logits = self._compute_chunk(task, chunk)
        task.pos += chunk
        task.chunks += 1
        if task.done:
            return logits, chunk
        return None, chunk

    def prefill(self, seq_id: str, prompt: list):
        """One-shot convenience: returns last-token logits (V,)."""
        task = self.start_prefill(seq_id, prompt)
        logits, _ = self.prefill_chunk(task, None)
        return logits

    # -- jitted bodies ----------------------------------------------------------
    def _one_shot(self, seq_id: str, prompt: list):
        slot = self.slot_of[seq_id]
        S = len(prompt)
        # SSM/hybrid state is polluted by right-padding, so those use exact
        # lengths (one compile per distinct length); attention families use
        # power-of-two buckets with a masked last_index.
        if self.cfg.family in ("ssm", "hybrid"):
            bucket = S
        else:
            bucket = min(_bucket(S), self.max_len)
        if bucket not in self._prefill:
            def fn(params, toks, true_len):
                logits, cache = self.model.prefill(
                    params, {"tokens": toks}, max_len=self.max_len,
                    last_index=true_len - 1, moe_mode="dense")
                cache["len"] = jnp.full_like(cache["len"], true_len)
                return logits, self._pin_cache(cache)
            self._prefill[bucket] = jax.jit(fn)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = prompt
        logits, slot_cache = self._prefill[bucket](
            self.params, self._put(toks), S)
        self.cache = self._insert(self.cache, slot_cache, slot)
        return logits[0]            # device-resident (V,)

    def _chunk_impl(self, params, toks, cache, slot, start, true_len):
        """One prefill chunk straight into the stacked slot cache.

        toks: (1, Cb) right-padded chunk; slot/start/true_len: traced
        scalars. Writes the chunk's KV at positions [start, start+true_len)
        of ``slot`` (padded rows are dropped out-of-bounds), then attends the
        chunk queries over the slot's cache rows [0, start+true_len).
        """
        cfg = self.cfg
        model = self.model
        Cb = toks.shape[1]
        x = model.embed_inputs(params, {"tokens": toks})
        positions = start + jnp.arange(Cb)[None, :]
        kv_len = start + true_len
        Smax = cache["k"].shape[3]
        wpos = start + jnp.arange(Cb)
        wpos = jnp.where(jnp.arange(Cb) < true_len, wpos, Smax)  # pad -> drop

        def body(h, xs):
            lp, kc, vc = xs                       # kc: (B, KH, Smax, hd)

            def write_attend(q, k, v):
                kc2 = kc.at[slot, :, wpos].set(k[0].astype(kc.dtype),
                                               mode="drop")
                vc2 = vc.at[slot, :, wpos].set(v[0].astype(vc.dtype),
                                               mode="drop")
                kg = jnp.swapaxes(kc2[slot], 0, 1)[None]  # (1, Smax, KH, hd)
                vg = jnp.swapaxes(vc2[slot], 0, 1)[None]
                a = chunked_attention(q, kg, vg, causal=True, q_offset=start,
                                      kv_len=kv_len)
                return a, (kc2, vc2)

            return _chunk_layer(h, lp, cfg, positions, write_attend)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        idx = jnp.maximum(true_len - 1, 0)
        logits = model.logits(params, h[:, idx])
        cache = dict(cache)
        cache["k"], cache["v"] = nk, nv
        cache["len"] = cache["len"].at[slot].set(kv_len)
        return logits[0], self._pin_cache(cache)

    def _compute_chunk(self, task: PrefillTask, chunk: int):
        slot = self.slot_of[task.seq_id]
        bucket = min(_bucket(chunk), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :chunk] = task.prompt[task.pos:task.pos + chunk]
        logits, self.cache = self._chunk(
            self.params, self._put(toks), self.cache, slot, task.pos, chunk)
        return logits               # device-resident (V,)

    # -- decode -----------------------------------------------------------------
    def decode_batch(self, tokens_by_slot: np.ndarray):
        """tokens_by_slot: (max_slots,) int32. Returns logits (max_slots, V)."""
        logits, self.cache = self._decode(self.params,
                                          self._put(tokens_by_slot),
                                          self.cache)
        return _logits_to_host(logits)

    # -- fused decode fast path --------------------------------------------------
    @property
    def supports_fused_decode(self) -> bool:
        return True

    def _fused_impl(self, params, cache, st, *, K):
        """K fused decode+sample+stop-check steps, entirely on device.

        st holds per-slot (max_slots,) vectors: tokens, n_gen, temps,
        top_ps, seed_base, stop_tok, gen_limit, active. A slot stops
        updating (``done``) once it hits its stop token or generation
        limit; the cache still steps every slot — exactly what the legacy
        path did for freed slots — so active slots are bit-identical.
        Returns (tokens (K, B), produced (B,), done (B,), cache, st).
        """
        B = st["tokens"].shape[0]

        def body(i, carry):
            cache, tokens, n_gen, done, produced, out = carry
            logits, cache = self.model.decode_step(params, tokens, cache)
            cache = self._pin_cache(cache)
            live = st["active"] & ~done
            tokens, n_gen, done, produced = _sample_and_latch(
                st, logits, tokens, n_gen, done, produced, live)
            out = out.at[i].set(tokens)
            return cache, tokens, n_gen, done, produced, out

        cache, tokens, n_gen, done, produced, out = lax.fori_loop(
            0, K, body,
            (self._pin_cache(cache), st["tokens"], st["n_gen"],
             jnp.zeros((B,), bool), jnp.zeros((B,), jnp.int32),
             jnp.zeros((K, B), jnp.int32)))
        st = self._pin_st(dict(st, tokens=tokens, n_gen=n_gen))
        return out, produced, done, cache, st

    def fused_decode(self, K: int, host_state: dict | None = None):
        """Run K decode steps on device; sync only token ids and flags.

        host_state (when the engine's slot composition changed) re-seeds the
        device-resident state; otherwise the state carried from the previous
        call is reused. Returns (tokens (K, max_slots) np.int32,
        produced (max_slots,) np.int32, done (max_slots,) bool).
        """
        if host_state is not None:
            self._dec_st = _upload_state(host_state, self.shard)
        assert self._dec_st is not None, \
            "fused_decode needs host_state on the first call"
        if K not in self._fused:
            self._fused[K] = jax.jit(partial(self._fused_impl, K=K),
                                     donate_argnums=(1, 2))
        out, produced, done, self.cache, self._dec_st = self._fused[K](
            self.params, self.cache, self._dec_st)
        return np.asarray(out), np.asarray(produced), np.asarray(done)

    # -- speculative decoding ----------------------------------------------------
    @property
    def supports_spec_decode(self) -> bool:
        # the verify block rewrites cache positions; SSM/hybrid state cannot
        # be rolled back, so only attention families can speculate
        return self.cfg.family in ATTENTION_FAMILIES

    def spec_headroom(self, k: int) -> int:
        """How many draft tokens a verify round can take (the engine already
        bounds k by max_seq_len); the dense cache has no page pool to run
        dry, so the answer is always k."""
        return k

    def reset_lens(self, lens_by_seq: dict[str, int]) -> None:
        """Roll per-slot cache lengths back to the given values — the
        draft cache's truncate-on-reject between speculative rounds. Only
        the (max_slots,) length vector moves; KV rows past the new length
        are rewritten before the length ever crosses them. The caller
        covers every live slot, and a dead slot's length is never read
        before its next prefill resets it, so the vector is rebuilt from
        the host without pulling the device copy back."""
        lens = np.zeros((self.max_slots,), np.int32)
        for sid, n in lens_by_seq.items():
            lens[self.slot_of[sid]] = n
        self.cache = dict(self.cache)
        self.cache["len"] = self._put(lens)

    def spec_catch_up(self, seq_id: str, tokens: list, from_pos: int):
        """Draft-cache resync after non-speculative rounds advanced the
        emitted stream without the draft: compute KV for
        ``tokens[from_pos:]`` (already-emitted prompt+output tokens) into
        the sequence's slot via the chunked-prefill body, leaving its
        cache length at ``len(tokens)``. Logits are discarded on device."""
        task = PrefillTask(seq_id=seq_id, prompt=list(tokens), pos=from_pos)
        self._compute_chunk(task, task.remaining)

    def _spec_impl(self, params, cache, st, draft, *, T):
        """Verify T = k+1 tokens per slot in ONE forward: feed
        [last_token, draft_0..draft_{k-1}], write their KV at positions
        lens..lens+k (dead slots drop out-of-bounds), attend causally, then
        accept/latch on device. Rejected positions keep their (masked)
        writes — they sit past the rolled-back length and are overwritten
        before the length crosses them. Returns
        (tokens (T, B), produced (B,), done (B,), cache, st)."""
        cfg = self.cfg
        B = st["tokens"].shape[0]
        lens = cache["len"]
        tokens_in = jnp.concatenate([st["tokens"][:, None], draft], axis=1)
        x = jnp.take(params["embed"], tokens_in, axis=0)
        positions = lens[:, None] + jnp.arange(T)[None, :]
        Smax = cache["k"].shape[3]
        bidx = jnp.arange(B)[:, None]
        wpos = jnp.where(st["active"][:, None], positions, Smax)  # dead: drop

        def body(h, xs):
            lp, kc, vc = xs

            def write_attend(q, k, v):
                kc2 = kc.at[bidx, :, wpos].set(k.astype(kc.dtype),
                                               mode="drop")
                vc2 = vc.at[bidx, :, wpos].set(v.astype(vc.dtype),
                                               mode="drop")
                a = _spec_block_attention(q, kc2, vc2, lens, kv_major=True)
                return a, (kc2, vc2)

            return _chunk_layer(h, lp, cfg, positions, write_attend)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self.model.logits(params, h)                  # (B, T, V)
        targets, produced, done, st = _spec_accept_and_latch(st, logits,
                                                             draft)
        cache = dict(cache, k=nk, v=nv)
        cache["len"] = lens + produced
        return targets.T, produced, done, self._pin_cache(cache), \
            self._pin_st(st)

    def spec_verify(self, draft_tokens: np.ndarray, host_state=None):
        """One speculative round's verification: draft_tokens (B, k) from
        the draft's fused loop; one jitted call verifies, accepts, resamples
        the residual, and truncates the cache — logits never reach the host.
        Returns (tokens (k+1, B), produced (B,), done (B,)) numpy arrays."""
        if host_state is not None:
            self._dec_st = _upload_state(host_state, self.shard)
        assert self._dec_st is not None, \
            "spec_verify needs host_state on the first call"
        T = draft_tokens.shape[1] + 1
        if T not in self._spec_fns:
            self._spec_fns[T] = jax.jit(partial(self._spec_impl, T=T),
                                        donate_argnums=(1, 2))
        out, produced, done, self.cache, self._dec_st = self._spec_fns[T](
            self.params, self.cache, self._dec_st,
            self._put(np.ascontiguousarray(draft_tokens)))
        return np.asarray(out), np.asarray(produced), np.asarray(done)

    def free(self, seq_id: str):
        slot = self.slot_of.pop(seq_id)
        self.free_slots.append(slot)

    def publish(self, seq_id: str, tokens: list) -> None:
        """Preemption hook: the slot backend has no content-addressed cache
        to publish into — a preempted sequence restores by full recompute."""

    def slot(self, seq_id: str) -> int:
        return self.slot_of[seq_id]

    def cache_stats(self) -> dict:
        return {}


class PagedBackend:
    """Paged KV cache backend for attention-family models."""

    def __init__(self, model: LM, params, *, max_slots: int, max_len: int,
                 page_size: int = 128, num_pages: int | None = None,
                 use_kernel: bool = False, enable_prefix_cache: bool = False,
                 mesh=None):
        cfg = model.cfg
        assert cfg.family in ATTENTION_FAMILIES, \
            "paged backend supports attention families"
        self.model = model
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = -(-max_len // page_size)
        if num_pages is None:
            num_pages = max_slots * self.pages_per_seq + 1  # +1: trash page 0
        self.kv = PagedKVCache(num_pages, page_size,
                               enable_prefix_cache=enable_prefix_cache)
        L, KH, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        dtype = jnp.dtype(cfg.param_dtype)
        self.pools = {
            "k": jnp.zeros((L, num_pages, page_size, KH, hd), dtype),
            "v": jnp.zeros((L, num_pages, page_size, KH, hd), dtype),
        }
        self.shard = ServeSharding(mesh, cfg) if mesh is not None else None
        if self.shard is not None:
            # pages shard along the kv-head axis; the host-side allocator
            # (tables, refcounts, prefix index) is one copy serving every
            # shard — see PagedKVCache's docstring
            self.params = self.shard.shard_params(params)
            self.pools = self.shard.shard_pools(self.pools)
        self.use_kernel = use_kernel
        # Kernel dispatch. GSPMD cannot partition a Pallas kernel body, so
        # under a mesh the kernels run per-shard via shard_map over the
        # kv-head axis — only possible when the head count divides the
        # model axis; otherwise the sharded jnp reference serves. Where
        # compiled Pallas is unavailable (non-TPU), the fused decode loop
        # runs the "XLA twin": same no-per-step-gather/scatter structure
        # (cached context view + tail buffers + one deferred commit), jnp
        # ops instead of a kernel.
        self._kernel_sharded = use_kernel and shardable_kv_heads(
            cfg.num_kv_heads, mesh)
        self._fused_use_pallas = (use_kernel and kernels_compiled()
                                  and (mesh is None or self._kernel_sharded))
        self._needs_view = use_kernel and not self._fused_use_pallas
        self._ctx_view = None       # gathered (L, B, S, KH, hd) ctx view
        self._gather_view = jax.jit(self._gather_view_impl)
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.slot_of: dict[str, int] = {}
        self.seq_of: dict[int, str] = {}
        self.decoding: set[str] = set()
        self._prefill = {}
        # one jit object; specializes per (chunk bucket, ctx-page bucket)
        self._chunk = jax.jit(self._chunk_prefill_impl, donate_argnums=(2,))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._cow = jax.jit(self._cow_impl, donate_argnums=(0,))
        # swap-in upload (preemption restore): write saved page KV back
        # into freshly allocated pages; specializes per page count
        self._swap = jax.jit(
            lambda pools, table, k, v: self._pin_pools({
                "k": pools["k"].at[:, table].set(k),
                "v": pools["v"].at[:, table].set(v)}),
            donate_argnums=(0,))
        self._fused = {}            # K -> jitted multi-step decode+sample fn
        self._spec_fns = {}         # T -> jitted verify+accept fn
        self._dec_st = None         # device-resident per-slot decode state
        self._dev_tables = None     # device-resident (tables, lens) pair
        self._dev_tables_key = None  # kv.table_version the pair was built at

    # -- capacity -------------------------------------------------------------
    def can_admit(self, n_prompt: int) -> bool:
        return (bool(self.free_slots)
                and self.kv.can_allocate(n_prompt + 1)
                and n_prompt < self.max_len)

    @property
    def supports_chunked_prefill(self) -> bool:
        return True

    # -- sharded placement helpers ----------------------------------------------
    def _put(self, x):
        """Host upload: replicated onto the mesh device set when sharded."""
        return jnp.asarray(x) if self.shard is None \
            else self.shard.replicate(np.asarray(x))

    def _pin_pools(self, pools):
        """Pin the page pools to their head-axis sharding inside jit, so
        the layout is a fixed point across donated calls (no-op unsharded)."""
        return pools if self.shard is None else self.shard.pin_pools(pools)

    def _pin_st(self, st):
        return st if self.shard is None else self.shard.pin_replicated(st)

    # -- jitted bodies ----------------------------------------------------------
    def _attend(self, q, kp, vp, tables, lens):
        if self.use_kernel:
            if self.shard is not None:
                if not self._kernel_sharded:
                    # kv heads don't divide the model axis: shard_map can't
                    # split the kernel — run the GSPMD-sharded reference
                    return paged_attention_ref(q, kp, vp, tables, lens)
                return paged_attention_sharded(q, kp, vp, tables, lens,
                                               mesh=self.shard.mesh)
            # interpret resolves once per process: compiled on TPU,
            # interpreter elsewhere
            return paged_attn_kernel(q, kp, vp, tables, lens)
        return paged_attention_ref(q, kp, vp, tables, lens)

    def _prefill_attend(self, q, kp, vp, tables, start, kv_len):
        """Chunked-prefill attention dispatch: the paged flash-prefill
        kernel streams pages straight from the pool when compiled Pallas
        is available on a single device; the gather reference otherwise
        (under a mesh GSPMD shards the gather + einsums — the decode hot
        loop is where shard_map pays)."""
        if (self.use_kernel and kernels_compiled() and self.shard is None):
            return paged_flash_prefill(q, kp, vp, tables, start, kv_len)
        return paged_prefill_attention_ref(q, kp, vp, tables, start, kv_len)

    def _cow_impl(self, pools, src, dst):
        """Copy-on-write: duplicate page ``src`` into ``dst`` on device
        (across every layer) before a write diverges a shared page."""
        return self._pin_pools(
            {"k": pools["k"].at[:, dst].set(pools["k"][:, src]),
             "v": pools["v"].at[:, dst].set(pools["v"][:, src])})

    def _prefill_impl(self, params, toks, pools, table, true_len, *, n_pages):
        """toks: (1, S_bucket); table: (n_pages,) page ids for this seq."""
        cfg = self.cfg
        model = self.model
        S = toks.shape[1]
        x = model.embed_inputs(params, {"tokens": toks})
        positions = jnp.arange(S)[None, :]

        def body(h, xs):
            lp, kp, vp = xs
            h2, (k, v), _ = _block(h, lp, cfg, positions, moe_mode="dense",
                                   return_kv=True)
            kpg = k[0].reshape(n_pages, self.page_size, *k.shape[2:])
            vpg = v[0].reshape(n_pages, self.page_size, *v.shape[2:])
            kp = kp.at[table].set(kpg.astype(kp.dtype))
            vp = vp.at[table].set(vpg.astype(vp.dtype))
            return h2, (kp, vp)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                         pools["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        idx = jnp.maximum(true_len - 1, 0)
        logits = model.logits(params, h[:, idx])
        return logits[0], self._pin_pools({"k": nk, "v": nv})

    def _chunk_prefill_impl(self, params, toks, pools, table, write_pages,
                            write_offs, start, true_len):
        """One prefill chunk against the page pool.

        toks: (1, Cb) right-padded chunk starting at absolute position
        ``start``; table: (pages_per_seq,) the sequence's full block table
        (0-padded); write_pages/write_offs: (Cb,) per-token destination in
        the pool (padded rows are routed to trash page 0). The chunk's KV is
        written first, then its queries attend over [0, start+true_len) via
        the paged gather path — cached prefix pages are read, never
        recomputed.
        """
        cfg = self.cfg
        model = self.model
        x = model.embed_inputs(params, {"tokens": toks})
        positions = start + jnp.arange(toks.shape[1])[None, :]
        kv_len = start + true_len

        def body(h, xs):
            lp, kp, vp = xs

            def write_attend(q, k, v):
                kp2 = kp.at[write_pages, write_offs].set(
                    k[0].astype(kp.dtype))
                vp2 = vp.at[write_pages, write_offs].set(
                    v[0].astype(vp.dtype))
                a = self._prefill_attend(q, kp2, vp2, table[None],
                                         start, kv_len)
                return a, (kp2, vp2)

            return _chunk_layer(h, lp, cfg, positions, write_attend)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                         pools["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        idx = jnp.maximum(true_len - 1, 0)
        logits = model.logits(params, h[:, idx])
        return logits[0], self._pin_pools({"k": nk, "v": nv})

    def _decode_forward(self, params, pools, tokens, tables, lens,
                        page_idx, off):
        """One decode-step transformer forward against the page pool:
        write each slot's new KV at (page_idx, off), attend over
        [0, lens+1). Shared by the legacy step and the fused loop (which
        routes dead slots' writes to the trash page via page_idx/off).
        Returns (logits (B, V), pools)."""
        cfg = self.cfg
        model = self.model
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens[:, None], axis=0)
        positions = lens[:, None]

        def body(h, xs):
            lp, kp, vp = xs
            xa = rms_norm(h, lp["norm1"], cfg.norm_eps)
            q, k, v = project_qkv(xa, lp["attn"], cfg, positions)
            kp = kp.at[page_idx, off].set(k[:, 0].astype(kp.dtype))
            vp = vp.at[page_idx, off].set(v[:, 0].astype(vp.dtype))
            a = self._attend(q[:, 0], kp, vp, tables, lens + 1)  # (B,H,hd)
            h = h + (a.reshape(B, 1, -1) @ lp["attn"]["wo"])
            g = rms_norm(h, lp["norm2"], cfg.norm_eps)
            if cfg.moe:
                f, _ = moe_ffn(g, lp["moe"], cfg, mode="dense")
            else:
                f = mlp_layer(g, lp["mlp"])
            return h + f, (kp, vp)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                         pools["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = model.logits(params, h[:, 0])
        return logits, self._pin_pools({"k": nk, "v": nv})

    def _decode_impl(self, params, pools, tokens, tables, lens):
        """tokens: (B,); tables: (B, PPS); lens: (B,) current lengths.
        The page for position ``lens`` must already exist (ensure_slot)."""
        page_slot = lens // self.page_size                     # (B,)
        page_idx = jnp.take_along_axis(tables, page_slot[:, None], 1)[:, 0]
        off = lens % self.page_size
        return self._decode_forward(params, pools, tokens, tables, lens,
                                    page_idx, off)

    # -- prefill protocol --------------------------------------------------------
    def start_prefill(self, seq_id: str, prompt: list) -> PrefillTask:
        slot = self.free_slots.pop()
        self.slot_of[seq_id] = slot
        self.seq_of[slot] = seq_id
        prompt = list(prompt)
        pages, n_cached = self.kv.allocate_with_prefix(seq_id, prompt)
        return PrefillTask(seq_id=seq_id, prompt=prompt, pos=n_cached,
                           cached_tokens=n_cached)

    def prefill_chunk(self, task: PrefillTask, budget: int | None = None):
        """Compute up to ``budget`` prompt tokens (all remaining if None).
        Returns (last_token_logits | None, tokens_computed)."""
        S = len(task.prompt)
        chunk = task.remaining if budget is None \
            else min(max(budget, 1), task.remaining)
        if (task.pos == 0 and chunk == S
                and not self.kv.enable_prefix_cache):
            # legacy fast path: whole-prompt self-attention, block KV writes
            logits = self._one_shot(task.seq_id, task.prompt)
        else:
            logits = self._compute_chunk(task, chunk)
        task.pos += chunk
        task.chunks += 1
        if task.done:
            self.kv.commit_prefix(task.seq_id, task.prompt)
            self.decoding.add(task.seq_id)
            return logits, chunk
        return None, chunk

    def prefill(self, seq_id: str, prompt: list):
        """One-shot convenience: returns last-token logits (V,)."""
        task = self.start_prefill(seq_id, prompt)
        logits, _ = self.prefill_chunk(task, None)
        return logits

    def _one_shot(self, seq_id: str, prompt: list):
        S = len(prompt)
        bucket = min(_bucket(max(S, self.page_size)), self.max_len)
        bucket = -(-bucket // self.page_size) * self.page_size
        n_pages = bucket // self.page_size
        pages = self.kv._tables[seq_id]
        # padded tail of the bucket writes land in trash page 0 (copy — do
        # not mutate the sequence's own table)
        write_table = list(pages) + [0] * (n_pages - len(pages))
        write_table = write_table[:n_pages]
        if bucket not in self._prefill:
            self._prefill[bucket] = jax.jit(
                partial(self._prefill_impl, n_pages=n_pages),
                donate_argnums=(2,))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :S] = prompt
        logits, self.pools = self._prefill[bucket](
            self.params, self._put(toks), self.pools,
            self._put(np.array(write_table, np.int32)), S)
        self._invalidate_view()
        return logits               # device-resident (V,)

    def _compute_chunk(self, task: PrefillTask, chunk: int):
        ps = self.page_size
        pos = task.pos
        # COW any shared page this chunk writes into (only possible for the
        # recomputed final token of a page-aligned full prefix hit)
        for pi in range(pos // ps, (pos + chunk - 1) // ps + 1):
            cow = self.kv.writable_page(task.seq_id, pi * ps)
            if cow is not None:
                self.pools = self._cow(self.pools, *cow)
        table = self.kv._tables[task.seq_id]
        bucket = min(_bucket(chunk), self.max_len)
        write_pages = np.zeros((bucket,), np.int32)     # pad -> trash page 0
        write_offs = np.arange(bucket, dtype=np.int32) % ps
        for j in range(chunk):
            p = pos + j
            write_pages[j] = table[p // ps]
            write_offs[j] = p % ps
        # gather only as much context as the chunk can see, bucketed so the
        # jit specializes per power-of-two page count — not per max_len
        n_ctx = min(_bucket(-(-(pos + chunk) // ps), lo=1),
                    self.pages_per_seq)
        ctx_table = np.zeros((n_ctx,), np.int32)
        ctx_table[:min(len(table), n_ctx)] = table[:n_ctx]
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :chunk] = task.prompt[pos:pos + chunk]
        logits, self.pools = self._chunk(
            self.params, self._put(toks), self.pools,
            self._put(ctx_table), self._put(write_pages),
            self._put(write_offs), pos, chunk)
        self._invalidate_view()
        return logits               # device-resident (V,)

    # -- decode -----------------------------------------------------------------
    def decode_batch(self, tokens_by_slot: np.ndarray):
        """tokens_by_slot: (max_slots,). Inactive / mid-prefill slots write
        to trash page 0."""
        for sid in self.decoding:
            self.kv.ensure_slot(sid)
            # a decode write into a still-shared page must diverge first
            cow = self.kv.writable_page(sid, self.kv.length(sid))
            if cow is not None:
                self.pools = self._cow(self.pools, *cow)
        tables = np.zeros((self.max_slots, self.pages_per_seq), np.int32)
        lens = np.zeros((self.max_slots,), np.int32)
        for slot, sid in self.seq_of.items():
            if sid not in self.decoding:
                continue
            tables[slot] = self.kv.table_array([sid], self.pages_per_seq)[0]
            lens[slot] = self.kv.length(sid)
        logits, self.pools = self._decode(
            self.params, self.pools, self._put(tokens_by_slot),
            self._put(tables), self._put(lens))
        self._invalidate_view()
        for sid in self.decoding:
            self.kv.advance(sid)
        return _logits_to_host(logits)

    # -- fused decode fast path --------------------------------------------------
    @property
    def supports_fused_decode(self) -> bool:
        return True

    def _fused_impl(self, params, pools, st, tables, lens, *, K):
        """K fused decode+sample+stop-check steps against the page pool.

        Per step: write the fed token's KV at position ``lens`` (dead slots
        route to trash page 0), attend over the block tables, sample on
        device, advance lens/n_gen only for live slots, latch ``done`` on
        stop-token or generation-limit hits. The host pre-allocates pages
        and resolves copy-on-write for all K positions before the call, so
        the block tables are loop-invariant. Returns
        (tokens (K, B), produced (B,), done (B,), pools, st, lens).
        """
        ps = self.page_size
        B = st["tokens"].shape[0]

        def step(i, carry):
            pools, tokens, n_gen, lens, done, produced, out = carry
            live = st["active"] & ~done
            page_slot = lens // ps
            page_idx = jnp.take_along_axis(tables, page_slot[:, None], 1)[:, 0]
            page_idx = jnp.where(live, page_idx, 0)      # dead slots -> trash
            off = jnp.where(live, lens % ps, 0)
            logits, pools = self._decode_forward(params, pools, tokens,
                                                 tables, lens, page_idx, off)
            lens = lens + live.astype(jnp.int32)
            tokens, n_gen, done, produced = _sample_and_latch(
                st, logits, tokens, n_gen, done, produced, live)
            out = out.at[i].set(tokens)
            return pools, tokens, n_gen, lens, done, produced, out

        pools, tokens, n_gen, lens, done, produced, out = lax.fori_loop(
            0, K, step,
            (self._pin_pools(pools), st["tokens"], st["n_gen"], lens,
             jnp.zeros((B,), bool), jnp.zeros((B,), jnp.int32),
             jnp.zeros((K, B), jnp.int32)))
        st = self._pin_st(dict(st, tokens=tokens, n_gen=n_gen))
        if self.shard is not None:
            lens = self.shard.pin(lens, jax.sharding.PartitionSpec())
        return out, produced, done, pools, st, lens

    # -- fused decode, kernel path ----------------------------------------------
    def _gather_view_impl(self, pools, tables):
        """Materialize the contiguous (L, B, S, KH, hd) view of the
        committed pages — once per allocator state, not once per step.
        The cache is keyed on ``kv.table_version`` through
        ``_refresh_tables`` (a version bump re-uploads the tables and
        drops the view) plus explicit ``_invalidate_view`` calls at every
        pool-mutation site outside the fused loop."""
        view = {n: jax.vmap(lambda p: gather_kv(p, tables))(pools[n])
                for n in ("k", "v")}
        return view if self.shard is None else self.shard.pin_view(view)

    def _invalidate_view(self) -> None:
        """Drop the cached context view after any pool mutation outside
        the fused loop — the next fused call re-gathers. Seven sites:
        prefill writes (``_one_shot``, ``_compute_chunk``), legacy decode
        (``decode_batch``), COW resolution (``_resolve_cow``), the device
        table re-upload (``_refresh_tables``), spec-decode verification
        (``spec_verify``), and swap-in. ``fused_decode`` itself is exempt:
        it maintains ``self._ctx_view`` in place from the donated call's
        return. The cache-invalidation firstlint rule enforces this
        inventory — a new pool-mutating method without an invalidation
        call (or in-place view maintenance) fails CI."""
        self._ctx_view = None

    def _fused_kernel_impl(self, params, pools, view, st, tables, lens, *,
                           K):
        """K fused decode steps with no per-step page gather or scatter.

        The loop body never touches the page pool: each step appends its
        new KV to (L, B, K, KH, hd) tail buffers and attends committed
        context + tail under ONE softmax — via the Pallas decode-tail
        kernel reading pages directly (TPU; shard_map'd over kv heads on a
        mesh, ``view`` is None), or via the cached contiguous ``view``
        (the XLA twin elsewhere). After the loop, one batched scatter
        commits the tails to the pool and advances the view in place, so
        the next call reuses it unless the allocator moved. Emits the same
        token stream as ``_fused_impl``: step i of slot b attends exactly
        positions [0, lens0[b] + produced[b] + 1) with the same values.
        """
        cfg = self.cfg
        ps = self.page_size
        B = st["tokens"].shape[0]
        L, KH, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        dt = pools["k"].dtype
        lens0 = lens
        kv_ctx = (pools["k"], pools["v"]) if view is None \
            else (view["k"], view["v"])

        def forward(tokens, written, k_tails, v_tails):
            x = jnp.take(params["embed"], tokens[:, None], axis=0)
            positions = (lens0 + written)[:, None]
            tail_lens = written + 1
            bidx = jnp.arange(B)

            def body(h, xs):
                lp, kc, vc, kt, vt = xs
                xa = rms_norm(h, lp["norm1"], cfg.norm_eps)
                q, k, v = project_qkv(xa, lp["attn"], cfg, positions)
                kt = kt.at[bidx, written].set(k[:, 0].astype(dt))
                vt = vt.at[bidx, written].set(v[:, 0].astype(dt))
                if view is not None:
                    a = decode_tail_attention_ref(q[:, 0], kc, vc, lens0,
                                                  kt, vt, tail_lens)
                elif self.shard is not None:
                    a = fused_decode_attention_sharded(
                        q[:, 0], kc, vc, tables, lens0, kt, vt, tail_lens,
                        mesh=self.shard.mesh)
                else:
                    a = fused_decode_attention(q[:, 0], kc, vc, tables,
                                               lens0, kt, vt, tail_lens)
                h = h + (a.reshape(B, 1, -1) @ lp["attn"]["wo"])
                g = rms_norm(h, lp["norm2"], cfg.norm_eps)
                if cfg.moe:
                    f, _ = moe_ffn(g, lp["moe"], cfg, mode="dense")
                else:
                    f = mlp_layer(g, lp["mlp"])
                return h + f, (kt, vt)

            h, (k_tails, v_tails) = lax.scan(
                body, x, (params["layers"], *kv_ctx, k_tails, v_tails))
            h = rms_norm(h, params["final_norm"], cfg.norm_eps)
            return self.model.logits(params, h[:, 0]), k_tails, v_tails

        def step(i, carry):
            k_tails, v_tails, tokens, n_gen, done, produced, out = carry
            live = st["active"] & ~done
            # ``produced`` doubles as the tail write cursor: both advance
            # by ``live`` each step, so slot b's valid tail rows are
            # exactly [0, produced[b]) and this step writes row
            # produced[b] (dead slots overwrite that row in place — their
            # outputs are discarded by the live mask, like the trash-page
            # writes on the reference path)
            logits, k_tails, v_tails = forward(tokens, produced, k_tails,
                                               v_tails)
            tokens, n_gen, done, produced = _sample_and_latch(
                st, logits, tokens, n_gen, done, produced, live)
            out = out.at[i].set(tokens)
            return k_tails, v_tails, tokens, n_gen, done, produced, out

        k_tails, v_tails, tokens, n_gen, done, produced, out = lax.fori_loop(
            0, K, step,
            (jnp.zeros((L, B, K, KH, hd), dt),
             jnp.zeros((L, B, K, KH, hd), dt),
             st["tokens"], st["n_gen"], jnp.zeros((B,), bool),
             jnp.zeros((B,), jnp.int32), jnp.zeros((K, B), jnp.int32)))

        # one deferred commit: scatter every valid tail row into its page
        # (rows past ``produced`` drop via an out-of-bounds page id)
        jj = jnp.arange(K)[None, :]
        pos = lens0[:, None] + jj                               # (B, K)
        valid = jj < produced[:, None]
        page_slot = jnp.minimum(pos // ps, tables.shape[1] - 1)
        page_idx = jnp.take_along_axis(tables, page_slot, axis=1)
        page_idx = jnp.where(valid, page_idx, pools["k"].shape[1])
        off = pos % ps

        def commit(pool_l, tail_l):
            return pool_l.at[page_idx, off].set(tail_l, mode="drop")

        pools = self._pin_pools(
            {"k": jax.vmap(commit)(pools["k"], k_tails),
             "v": jax.vmap(commit)(pools["v"], v_tails)})
        if view is not None:
            S = view["k"].shape[2]
            posv = jnp.where(valid, pos, S)       # invalid rows drop (OOB)
            brow = jnp.arange(B)[:, None]

            def advance(view_l, tail_l):
                return view_l.at[brow, posv].set(tail_l, mode="drop")

            view = {"k": jax.vmap(advance)(view["k"], k_tails),
                    "v": jax.vmap(advance)(view["v"], v_tails)}
            if self.shard is not None:
                view = self.shard.pin_view(view)
        lens = lens0 + produced
        st = self._pin_st(dict(st, tokens=tokens, n_gen=n_gen))
        if self.shard is not None:
            lens = self.shard.pin(lens, jax.sharding.PartitionSpec())
        return out, produced, done, pools, view, st, lens

    def fused_decode(self, K: int, host_state: dict | None = None):
        """Run up to K decode steps on device; sync only token ids and flags.

        Host-side prep per call: allocate page headroom for K tokens per
        decoding sequence (clamping K down if the pool is tight) and resolve
        copy-on-write for every page the loop will write. Block tables and
        lengths are uploaded only when the allocator state changed
        (``kv.table_version``) or the engine re-seeds the slot state;
        otherwise the device-resident copies carry over. Returns
        (tokens (K_eff, max_slots), produced, done) as numpy arrays.
        """
        K_eff = self._reserve_headroom(max(1, K))
        self._resolve_cow(K_eff)
        self._refresh_tables(force=host_state is not None)
        if host_state is not None:
            self._dec_st = _upload_state(host_state, self.shard)
        assert self._dec_st is not None, \
            "fused_decode needs host_state on the first call"
        if K_eff not in self._fused:
            # tables are NOT donated: the device copy is reused across
            # calls until the allocator bumps table_version
            if self.use_kernel:
                self._fused[K_eff] = jax.jit(
                    partial(self._fused_kernel_impl, K=K_eff),
                    donate_argnums=(1, 2, 3, 5))
            else:
                self._fused[K_eff] = jax.jit(
                    partial(self._fused_impl, K=K_eff),
                    donate_argnums=(1, 2, 4))
        tables_d, lens_d = self._dev_tables
        if self.use_kernel:
            if self._needs_view and self._ctx_view is None:
                self._ctx_view = self._gather_view(self.pools, tables_d)
            (out, produced, done, self.pools, self._ctx_view, self._dec_st,
             lens_d) = self._fused[K_eff](self.params, self.pools,
                                          self._ctx_view, self._dec_st,
                                          tables_d, lens_d)
        else:
            out, produced, done, self.pools, self._dec_st, lens_d = \
                self._fused[K_eff](self.params, self.pools, self._dec_st,
                                   tables_d, lens_d)
        self._dev_tables = (tables_d, lens_d)
        produced_np = np.asarray(produced)
        for slot, sid in self.seq_of.items():
            if sid in self.decoding:
                self.kv.advance_n(sid, int(produced_np[slot]))
        return np.asarray(out), produced_np, np.asarray(done)

    def _reserve_headroom(self, n: int) -> int:
        """Reserve page headroom for up to ``n`` token writes per decoding
        sequence. Guarantees every live sequence ONE token of headroom
        first (the legacy ensure_slot contract: raise loudly rather than
        routing a live KV write to the trash page) — only then extends
        best-effort toward ``n``, so one sequence's multi-token headroom
        can never starve a later sequence out of its single page. Returns
        the write count the pool (and ``max_len``) can actually take."""
        for sid in self.decoding:
            if self.kv.ensure_capacity(sid, 1) <= 0:
                raise OutOfPages(f"{sid}: pool exhausted on decode append")
        for sid in self.decoding:
            ahead = max(1, min(n, self.max_len - self.kv.length(sid)))
            n = min(n, max(1, self.kv.ensure_capacity(sid, ahead)))
        return n

    def _resolve_cow(self, n_writes: int) -> None:
        """COW every still-shared page the next ``n_writes`` decode/verify
        token writes of each decoding sequence would land in."""
        ps = self.page_size
        for sid in self.decoding:
            pos0 = self.kv.length(sid)
            for pi in range(pos0 // ps, (pos0 + n_writes - 1) // ps + 1):
                cow = self.kv.writable_page(sid, pi * ps)
                if cow is not None:
                    self.pools = self._cow(self.pools, *cow)
                    self._invalidate_view()

    def _refresh_tables(self, force: bool) -> None:
        """(Re)upload the device-resident (block tables, lengths) pair when
        the allocator state moved from under the cached copy."""
        if (force or self._dev_tables is None
                or self._dev_tables_key != self.kv.table_version):
            tables = np.zeros((self.max_slots, self.pages_per_seq), np.int32)
            lens = np.zeros((self.max_slots,), np.int32)
            for slot, sid in self.seq_of.items():
                if sid in self.decoding:
                    tables[slot] = self.kv.table_array(
                        [sid], self.pages_per_seq)[0]
                    lens[slot] = self.kv.length(sid)
            self._dev_tables = (self._put(tables), self._put(lens))
            self._dev_tables_key = self.kv.table_version
            # allocator moved (or slot state re-seeded): the cached
            # context view's page mapping is stale with it
            self._invalidate_view()

    # -- speculative decoding ----------------------------------------------------
    @property
    def supports_spec_decode(self) -> bool:
        return True

    def spec_headroom(self, k: int) -> int:
        """Reserve page headroom for a verify round of k draft tokens + the
        guaranteed target token; returns the k the pool can actually take
        (the same reservation policy as ``fused_decode``)."""
        return self._reserve_headroom(k + 1) - 1

    def reset_lens(self, lens_by_seq: dict[str, int]) -> None:
        """Truncate-on-reject for the draft's paged cache between rounds:
        roll each sequence's logical length back (pages stay as headroom)."""
        for sid, n in lens_by_seq.items():
            self.kv.rollback_to(sid, n)

    def spec_catch_up(self, seq_id: str, tokens: list, from_pos: int):
        """Draft-cache resync after non-speculative rounds advanced the
        emitted stream without the draft: compute KV for
        ``tokens[from_pos:]`` into the sequence's pages via the
        chunked-prefill body, leaving its logical length at
        ``len(tokens)``. Logits are discarded on device."""
        want = len(tokens)
        self.kv.rollback_to(seq_id, from_pos)
        need = want - self.kv.length(seq_id)
        if self.kv.ensure_capacity(seq_id, need) < need:
            raise OutOfPages(f"{seq_id}: pool exhausted on draft catch-up")
        task = PrefillTask(seq_id=seq_id, prompt=list(tokens), pos=from_pos)
        self._compute_chunk(task, task.remaining)
        self.kv.advance_n(seq_id, need)
        self.kv.table_version += 1       # device lens copy is now stale

    def _spec_impl(self, params, pools, st, tables, lens, draft, *, T):
        """Verify T = k+1 tokens per slot against the page pool in ONE
        forward: write their KV at positions lens..lens+k (dead slots to
        trash page 0), attend over the block tables with per-position
        causal masks, then accept/latch on device. Returns
        (tokens (T, B), produced (B,), done (B,), pools, st, lens)."""
        cfg = self.cfg
        ps = self.page_size
        tokens_in = jnp.concatenate([st["tokens"][:, None], draft], axis=1)
        x = jnp.take(params["embed"], tokens_in, axis=0)
        positions = lens[:, None] + jnp.arange(T)[None, :]
        live = st["active"][:, None]
        page_slot = jnp.minimum(positions // ps, tables.shape[1] - 1)
        page_idx = jnp.take_along_axis(tables, page_slot, axis=1)
        page_idx = jnp.where(live, page_idx, 0)          # dead slots -> trash
        off = jnp.where(live, positions % ps, 0)

        def body(h, xs):
            lp, kp, vp = xs

            def write_attend(q, k, v):
                kp2 = kp.at[page_idx, off].set(k.astype(kp.dtype))
                vp2 = vp.at[page_idx, off].set(v.astype(vp.dtype))
                kg = gather_kv(kp2, tables)
                vg = gather_kv(vp2, tables)
                a = _spec_block_attention(q, kg, vg, lens, kv_major=False)
                return a, (kp2, vp2)

            return _chunk_layer(h, lp, cfg, positions, write_attend)

        h, (nk, nv) = lax.scan(body, x, (params["layers"], pools["k"],
                                         pools["v"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = self.model.logits(params, h)                  # (B, T, V)
        targets, produced, done, st = _spec_accept_and_latch(st, logits,
                                                             draft)
        lens = lens + produced
        pools = self._pin_pools({"k": nk, "v": nv})
        if self.shard is not None:
            lens = self.shard.pin(lens, jax.sharding.PartitionSpec())
        return targets.T, produced, done, pools, self._pin_st(st), lens

    def spec_verify(self, draft_tokens: np.ndarray, host_state=None):
        """One speculative round's verification (page headroom must already
        be reserved via ``spec_headroom``). Resolves copy-on-write for every
        page the verify block writes, then runs verify + accept + residual
        resample + truncate in one jitted call; logits never reach the host.
        Returns (tokens (k+1, B), produced (B,), done (B,)) numpy arrays."""
        T = draft_tokens.shape[1] + 1
        self._resolve_cow(T)
        self._refresh_tables(force=host_state is not None)
        if host_state is not None:
            self._dec_st = _upload_state(host_state, self.shard)
        assert self._dec_st is not None, \
            "spec_verify needs host_state on the first call"
        if T not in self._spec_fns:
            self._spec_fns[T] = jax.jit(partial(self._spec_impl, T=T),
                                        donate_argnums=(1, 2, 4))
        tables_d, lens_d = self._dev_tables
        out, produced, done, self.pools, self._dec_st, lens_d = \
            self._spec_fns[T](self.params, self.pools, self._dec_st,
                              tables_d, lens_d,
                              self._put(np.ascontiguousarray(draft_tokens)))
        self._invalidate_view()
        self._dev_tables = (tables_d, lens_d)
        produced_np = np.asarray(produced)
        for slot, sid in self.seq_of.items():
            if sid in self.decoding:
                self.kv.advance_n(sid, int(produced_np[slot]))
        return np.asarray(out), produced_np, np.asarray(done)

    def free(self, seq_id: str):
        slot = self.slot_of.pop(seq_id)
        self.seq_of.pop(slot, None)
        self.decoding.discard(seq_id)
        self.free_slots.append(slot)
        self.kv.free(seq_id)

    # -- preemption support ------------------------------------------------------
    def publish(self, seq_id: str, tokens: list) -> None:
        """Register a preempted sequence's full pages (prompt AND decoded
        tokens) in the content index before they are freed: they park in
        the LRU and the restore prefill content-matches them back, so a
        preempt/restore round trip recomputes only the partial tail page.
        No-op when the prefix cache is disabled."""
        self.kv.commit_prefix(seq_id, tokens)

    def swap_out(self, seq_id: str) -> dict:
        """Copy a sequence's computed KV pages to host memory (the swap
        restore path, for when a prefix-cache hit cannot be counted on).
        Only the pages covering the sequence's logical length are saved —
        trailing headroom pages hold no committed KV. The caller frees the
        sequence afterwards; ``swap_in`` restores into fresh pages."""
        n_tokens = self.kv.length(seq_id)
        n_pages = self.kv.pages_needed(n_tokens)
        table = np.array(self.kv._tables[seq_id][:n_pages], np.int32)
        return {"k": np.asarray(self.pools["k"][:, table]),
                "v": np.asarray(self.pools["v"][:, table]),
                "n_tokens": n_tokens}

    def swap_in(self, seq_id: str, n_tokens: int, blob: dict) -> None:
        """Rebind a swapped-out sequence: reserve a slot, allocate fresh
        pages, upload the saved KV, and rejoin the decode set — no
        recompute. ``n_tokens`` must equal the blob's saved length."""
        assert n_tokens == blob["n_tokens"], \
            f"{seq_id}: swap blob holds {blob['n_tokens']} tokens, " \
            f"restore asked for {n_tokens}"
        slot = self.free_slots.pop()
        self.slot_of[seq_id] = slot
        self.seq_of[slot] = seq_id
        pages = self.kv.allocate(seq_id, n_tokens)
        self.pools = self._swap(self.pools,
                                self._put(np.array(pages, np.int32)),
                                self._put(blob["k"]), self._put(blob["v"]))
        self._invalidate_view()
        self.decoding.add(seq_id)

    def slot(self, seq_id: str) -> int:
        return self.slot_of[seq_id]

    def cache_stats(self) -> dict:
        s = dict(self.kv.stats)
        s["hit_rate"] = self.kv.hit_rate()
        s["cached_free_pages"] = self.kv.cached_free_pages
        return s
