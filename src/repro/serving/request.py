"""Request / response dataclasses (OpenAI-completions-shaped)."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

_req_counter = itertools.count()


@dataclass
class SamplingParams:
    max_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    top_p: float = 1.0
    seed: int = 0
    stop_token: Optional[int] = None


@dataclass
class InferenceRequest:
    model: str
    prompt_tokens: list                       # list[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    request_id: str = ""
    user: str = "anonymous"
    arrival_time: float = 0.0
    api_endpoint: str = "chat/completions"    # chat/completions|completions|embeddings
    # QoS routing/scheduling fields, threaded gateway -> engine (see
    # serving/scheduler.py): workload class, intra-class priority (lower =
    # more urgent), and absolute TTFT deadline (clock time; None = none)
    qos: str = "interactive"                  # interactive | batch
    priority: int = 0
    deadline: Optional[float] = None

    def __post_init__(self):
        if not self.request_id:
            self.request_id = f"req-{next(_req_counter)}"


@dataclass
class RequestMetrics:
    arrival_time: float = 0.0
    queued_time: float = 0.0       # entered engine queue
    first_token_time: float = 0.0
    finish_time: float = 0.0
    cached_prompt_tokens: int = 0  # prompt tokens served from the prefix cache
    prefill_chunks: int = 0        # engine steps this prompt's ingest spanned
    preemptions: int = 0           # times this request was evicted mid-run
    restore_cached_tokens: int = 0  # restore-prefill tokens the cache covered

    @property
    def ttft(self):
        return self.first_token_time - self.arrival_time

    @property
    def e2e_latency(self):
        return self.finish_time - self.arrival_time


@dataclass
class RequestOutput:
    request_id: str
    output_tokens: list = field(default_factory=list)
    finished: bool = False
    finish_reason: str = ""
    metrics: RequestMetrics = field(default_factory=RequestMetrics)
    error: str = ""

    @property
    def num_output_tokens(self):
        return len(self.output_tokens)
