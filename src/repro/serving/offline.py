"""Offline batch engine (paper §4.4): a dedicated allocation processes a whole
request file with no online-serving mediation — admit everything, loop until
drained, report aggregate throughput."""
from __future__ import annotations

import time

from repro.models import LM
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig


def run_batch(model: LM, params, requests, engine_cfg: EngineConfig | None = None,
              clock=None):
    """Returns (outputs, stats). Requests are processed with maximum batching
    and zero scheduling overhead between steps."""
    eng = ContinuousBatchingEngine(model, params, engine_cfg, clock=clock)
    t0 = time.monotonic()
    for r in requests:
        eng.add_request(r)
    outputs = eng.run_to_completion()
    dt = time.monotonic() - t0
    total_out = sum(o.num_output_tokens for o in outputs)
    stats = dict(eng.stats)
    stats.update({
        "wall_s": dt,
        "output_tokens": total_out,
        "output_tok_per_s": total_out / dt if dt > 0 else 0.0,
        "req_per_s": len(outputs) / dt if dt > 0 else 0.0,
    })
    return outputs, stats
