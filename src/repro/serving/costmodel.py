"""Roofline-based service-time model for simulated large-model instances.

The CPU container cannot run a 70B model, but the discrete-event benchmarks
need realistic per-step service times. We derive them from the same roofline
terms reported in EXPERIMENTS.md §Roofline, for the TPU v5e target:

  compute  = FLOPs / (chips * 197e12 * eff)
  memory   = bytes / (chips * 819e9)
  step     = max(compute, memory) + fixed overhead

Calibration knob ``mfu``/``eff`` defaults to 0.5 for prefill (compute-bound)
and 1.0 for memory streaming (decode is HBM-bound).

The per-step overhead is split to mirror the real engine's two decode
paths: ``dispatch_overhead`` is the irreducible per-step kernel-launch /
collective floor paid on device, while ``host_sync_overhead`` is the
host-side cost of a decode sync (logits/token transfer, sampling dispatch,
python bookkeeping). The legacy path pays both every token; the fused
multi-step path amortizes the host share over ``steps_per_sync`` tokens —
which is exactly what ``benchmarks/decode_loop.py`` measures on the real
engine, and what the DES reproduces through
``decode_step_time(steps_per_sync=K)``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
HOST_IO_BW = 64e9            # bytes/s device<->host staging (KV swap path)
ICI_BW = 45e9                # bytes/s per-link inter-chip interconnect (v5e)
COLLECTIVE_LAUNCH = 5e-6     # per-collective launch floor (tiny all-reduces)
DISPATCH_OVERHEAD = 2e-4     # per-step kernel dispatch/collective floor
HOST_SYNC_OVERHEAD = 1.8e-3  # per-sync host transfer+sampling+scheduling
STEP_OVERHEAD = DISPATCH_OVERHEAD + HOST_SYNC_OVERHEAD  # legacy K=1 total
KV_TRANSFER_BW = 25e9        # bytes/s inter-instance KV link (200 Gb fabric)
HANDOFF_OVERHEAD = 2e-3      # per-handoff control-plane hop (disaggregated)


def restore_tokens(n_tokens: int, cache_hit_rate: float) -> int:
    """Prompt-stream tokens a preemption *restore* prefill must recompute:
    the fraction the prefix cache does not cover, never less than one (the
    allocator always leaves the final position to recompute — mirrors
    ``PagedKVCache.allocate_with_prefix``). The real engine's
    recompute-via-prefix-cache restore hits the pages the victim published
    on eviction, so a warm restore recomputes only the partial tail page."""
    h = min(max(cache_hit_rate, 0.0), 1.0)
    return max(int(round(n_tokens * (1.0 - h))), 1)


def expected_spec_tokens(accept_rate: float, k: int) -> float:
    """Expected tokens emitted per speculative round: the accepted draft
    prefix plus the guaranteed target token (the residual resample, or the
    bonus token when all k drafts survive). With i.i.d. per-token acceptance
    probability ``a`` this is ``sum_{j=0..k} a^j = (1 - a^(k+1)) / (1 - a)``,
    saturating at ``k + 1`` when every draft is accepted."""
    a = min(max(accept_rate, 0.0), 1.0)
    k = max(int(k), 0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


@dataclass
class InstanceCost:
    """Per-phase timing for one model instance on ``chips`` chips.

    ``peak_flops``/``hbm_bw`` default to the TPU-v5e target; pass A100
    constants (312e12 bf16, 1555e9) to validate the DES against the paper's
    own hardware.

    ``model_shards`` mirrors the real engine's tensor-parallel mesh (the
    ``model`` axis of ``EngineConfig.mesh``): the FLOP/HBM rooflines above
    already scale with ``chips``, so sharding's *cost* is the per-layer
    all-reduce traffic that perfect scaling ignores — 2 collectives per
    layer over the activations (Megatron TP), charged on every forward.
    The default of 1 adds exactly zero and reproduces the unsharded model
    bit-for-bit."""
    cfg: ModelConfig
    chips: int = 8
    mfu: float = 0.5
    bytes_per_param: float = 2.0
    storage_bw: float = 2e9     # weight-load bandwidth (bytes/s per instance)
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    # total per-token overhead when the host syncs every step (K=1);
    # the device-side dispatch floor below is the part that cannot be
    # amortized by multi-step decode — the remainder is host-sync cost
    step_overhead: float = STEP_OVERHEAD
    dispatch_overhead: float = DISPATCH_OVERHEAD
    host_io_bw: float = HOST_IO_BW   # KV swap-out/in staging bandwidth
    model_shards: int = 1            # TP width (EngineConfig.mesh mirror)
    ici_bw: float = ICI_BW           # all-reduce ring bandwidth per link
    kv_transfer_bw: float = KV_TRANSFER_BW  # prefill->decode handoff link
    handoff_overhead: float = HANDOFF_OVERHEAD  # per-handoff hop floor

    def __post_init__(self):
        n = int(self.model_shards)
        if n < 1:
            raise ValueError(f"model_shards must be >= 1, got {n}")
        if self.chips % n:
            raise ValueError(
                f"model_shards={n} must divide chips={self.chips} "
                f"(each TP group spans chips/model_shards chips)")

    # -- tensor parallelism ------------------------------------------------------
    def _collective_time(self, batch: int, tokens_per_seq: int = 1) -> float:
        """All-reduce wall time for one forward under Megatron-style TP:
        2 collectives per layer (attention output + MLP output) over the
        (batch, tokens, d_model) activations, ring cost ``2(n-1)/n`` times
        the payload per device at ICI bandwidth, plus a per-collective
        launch floor (decode-shaped all-reduces are latency-bound)."""
        n = int(self.model_shards)
        if n <= 1:
            return 0.0
        act = batch * tokens_per_seq * self.cfg.d_model * self.bytes_per_param
        ring = 2.0 * (n - 1) / n * act / self.ici_bw
        return 2 * self.cfg.num_layers * (ring + COLLECTIVE_LAUNCH)

    def hbm_bytes_per_shard(self, batch: int = 1, ctx: int = 1024) -> float:
        """Resident bytes per TP shard: weights split over ``model`` and the
        KV pool split along its head axis, so both divide by the TP width
        (the HBM-headroom argument for sharding a too-large model)."""
        cfg = self.cfg
        w_bytes = cfg.num_params * self.bytes_per_param
        kv_bytes = (cfg.attn_layer_count() * 2 * cfg.kv_dim
                    * self.bytes_per_param * ctx * batch)
        return (w_bytes + kv_bytes) / int(self.model_shards)

    # -- model load (cold start component) -------------------------------------
    def load_time(self) -> float:
        """Weight load from cluster storage into device memory."""
        return self.cfg.num_params * self.bytes_per_param / self.storage_bw

    # -- prefill ---------------------------------------------------------------
    def prefill_time(self, prompt_tokens: int, batch: int = 1) -> float:
        flops = 2.0 * self.cfg.num_active_params * prompt_tokens * batch
        t_c = flops / (self.chips * self.peak_flops * self.mfu)
        t_coll = self._collective_time(batch, prompt_tokens)
        return max(t_c + t_coll, self.step_overhead)

    # -- preemption (QoS scheduling) ---------------------------------------------
    def restore_time(self, n_tokens: int,
                     cache_hit_rate: float = 1.0) -> float:
        """Service time to restore a preempted sequence of ``n_tokens`` by
        recompute-via-prefix-cache: a prefill of whatever the cache does
        not cover (see :func:`restore_tokens`)."""
        return self.prefill_time(restore_tokens(n_tokens, cache_hit_rate))

    def swap_time(self, n_tokens: int) -> float:
        """One leg of the host swap restore path: stage a sequence's KV
        pages across the device<->host link (charged once on swap-out and
        once on swap-in; no recompute)."""
        cfg = self.cfg
        kv_per_tok = (cfg.attn_layer_count() * 2 * cfg.kv_dim
                      * self.bytes_per_param)
        return kv_per_tok * n_tokens / self.host_io_bw

    def handoff_time(self, n_tokens: int) -> float:
        """Transfer hop of a prefill->decode handoff (disaggregated
        serving): the sequence's KV pages for ``n_tokens`` positions cross
        the inter-instance link, plus a fixed control-plane hop. The
        receiving engine's restore prefill (``restore_time``) is charged
        separately by its resume admission path."""
        cfg = self.cfg
        kv_per_tok = (cfg.attn_layer_count() * 2 * cfg.kv_dim
                      * self.bytes_per_param)
        return (self.handoff_overhead
                + kv_per_tok * max(int(n_tokens), 0) / self.kv_transfer_bw)

    # -- decode ------------------------------------------------------------------
    def decode_step_time(self, batch: int, ctx: int = 1024,
                         steps_per_sync: int = 1) -> float:
        """Per-token service time for one decode step.

        ``steps_per_sync`` (K) models the fused multi-step decode loop: the
        host-sync share of the overhead is paid once per K tokens, the
        device dispatch floor and the HBM/FLOP roofline term every token.
        K=1 reproduces the legacy host-driven path exactly.
        """
        t_mem, t_c = self._decode_roofline(batch, ctx)
        k = max(int(steps_per_sync), 1)
        host_sync = max(self.step_overhead - self.dispatch_overhead, 0.0)
        return (max(t_mem, t_c) + self._collective_time(batch)
                + self.dispatch_overhead + host_sync / k)

    def decode_tok_per_s(self, batch: int, ctx: int = 1024,
                         steps_per_sync: int = 1) -> float:
        return batch / self.decode_step_time(batch, ctx, steps_per_sync)

    # -- speculative decoding ----------------------------------------------------
    def _decode_roofline(self, batch: int, ctx: int,
                         tokens_per_seq: int = 1) -> tuple[float, float]:
        """(memory, compute) roofline terms for one decode-shaped forward
        covering ``tokens_per_seq`` positions per sequence: the weights
        stream once regardless (the whole point of batched verification),
        compute scales with the positions."""
        cfg = self.cfg
        w_bytes = cfg.num_active_params * self.bytes_per_param
        kv_per_tok = (cfg.attn_layer_count() * 2 * cfg.kv_dim
                      * self.bytes_per_param)
        kv_bytes = kv_per_tok * ctx * batch
        t_mem = (w_bytes + kv_bytes) / (self.chips * self.hbm_bw)
        flops = 2.0 * cfg.num_active_params * batch * tokens_per_seq
        t_c = flops / (self.chips * self.peak_flops * self.mfu)
        return t_mem, t_c

    def spec_round_time(self, batch: int, draft: "InstanceCost",
                        spec_tokens: int, ctx: int = 1024) -> float:
        """Wall time of one draft-and-verify round mirroring the real
        engine: k+1 draft steps in one fused call (device dispatch floor per
        step, no host sync inside), then ONE target forward verifying all
        k+1 positions (weights read once, compute scaled by k+1), then one
        host sync for the round."""
        k = max(int(spec_tokens), 1)
        t_draft = (k + 1) * draft.decode_step_time(batch, ctx,
                                                   steps_per_sync=k + 1)
        t_mem, t_c = self._decode_roofline(batch, ctx, tokens_per_seq=k + 1)
        host_sync = max(self.step_overhead - self.dispatch_overhead, 0.0)
        t_verify = (max(t_mem, t_c) + self._collective_time(batch, k + 1)
                    + self.dispatch_overhead + host_sync)
        return t_draft + t_verify

    def spec_decode_tok_per_s(self, batch: int, draft: "InstanceCost",
                              spec_tokens: int, accept_rate: float,
                              ctx: int = 1024) -> float:
        tokens = expected_spec_tokens(accept_rate, spec_tokens)
        return (batch * tokens
                / self.spec_round_time(batch, draft, spec_tokens, ctx))
