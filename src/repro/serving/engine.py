"""Continuous-batching inference engine (the vLLM-analogue, real JAX).

One ``step()`` = admit waiting requests into free capacity (prefilling each),
then run ONE batched decode step across all running sequences. This is
vLLM-style iteration-level scheduling: new requests join the running batch
between token steps, finished ones free their slots/pages immediately.

Two throughput/latency features layer on top of the base loop:

* **Prefix caching** (``enable_prefix_cache``, paged backend): prompts whose
  leading pages content-match already-computed pages skip recomputing them —
  the backend's ``PrefillTask.cached_tokens`` reports how much was reused.
* **Chunked prefill** (``chunked_prefill_budget`` > 0): instead of ingesting
  a whole prompt in one step (stalling decode for every running sequence),
  each step computes at most ``budget`` prompt tokens across the in-flight
  prefills, then still runs the decode batch — bounding time-between-tokens
  while long prompts admit. A sequence samples its first token (and joins
  the decode batch) only once its final chunk completes.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import numpy as np

from repro.models import LM
from repro.serving.backends import PagedBackend, PrefillTask, SlotBackend
from repro.serving.request import (InferenceRequest, RequestMetrics,
                                   RequestOutput)
from repro.serving.sampler import sample_tokens


class _RealClock:
    def now(self) -> float:
        return time.monotonic()


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_seq_len: int = 512
    backend: str = "slots"            # slots | paged
    page_size: int = 64
    num_pages: int | None = None
    use_kernel: bool = False
    max_prefills_per_step: int = 4
    # prompt tokens computed per engine step across all in-flight prefills;
    # 0 disables chunking (whole prompts ingest in their admission step)
    chunked_prefill_budget: int = 0
    # content-addressed KV page reuse across sequences (paged backend only)
    enable_prefix_cache: bool = False


@dataclass
class _Running:
    req: InferenceRequest
    metrics: RequestMetrics
    output_tokens: list = field(default_factory=list)

    @property
    def last_token(self) -> int:
        return self.output_tokens[-1]


class ContinuousBatchingEngine:
    def __init__(self, model: LM, params, cfg: EngineConfig | None = None,
                 clock=None):
        self.model = model
        self.cfg = cfg or EngineConfig()
        self.clock = clock or _RealClock()
        if self.cfg.backend == "paged":
            self.backend = PagedBackend(
                model, params, max_slots=self.cfg.max_slots,
                max_len=self.cfg.max_seq_len, page_size=self.cfg.page_size,
                num_pages=self.cfg.num_pages, use_kernel=self.cfg.use_kernel,
                enable_prefix_cache=self.cfg.enable_prefix_cache)
        else:
            if self.cfg.enable_prefix_cache:
                raise ValueError("prefix caching requires backend='paged'")
            self.backend = SlotBackend(
                model, params, max_slots=self.cfg.max_slots,
                max_len=self.cfg.max_seq_len)
        self.waiting: deque[InferenceRequest] = deque()
        # request_id -> (_Running, PrefillTask): admitted, prompt not yet
        # fully ingested (only populated when chunked prefill is on)
        self.prefilling: "OrderedDict[str, tuple[_Running, PrefillTask]]" = \
            OrderedDict()
        self.running: dict[str, _Running] = {}
        self.stats = {"prefill_tokens": 0, "cached_prompt_tokens": 0,
                      "prefill_chunks": 0, "decode_tokens": 0, "steps": 0,
                      "finished": 0, "aborted": 0}

    # -- queue management -------------------------------------------------------
    def add_request(self, req: InferenceRequest):
        m = RequestMetrics(arrival_time=req.arrival_time or self.clock.now(),
                           queued_time=self.clock.now())
        req._metrics = m
        self.waiting.append(req)

    def abort(self, request_id: str) -> bool:
        for i, r in enumerate(self.waiting):
            if r.request_id == request_id:
                del self.waiting[i]
                self.stats["aborted"] += 1
                return True
        if request_id in self.prefilling:
            self.backend.free(request_id)
            del self.prefilling[request_id]
            self.stats["aborted"] += 1
            return True
        if request_id in self.running:
            self.backend.free(request_id)
            del self.running[request_id]
            self.stats["aborted"] += 1
            return True
        return False

    def has_work(self) -> bool:
        return bool(self.waiting or self.prefilling or self.running)

    @property
    def num_running(self) -> int:
        return len(self.running)

    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    def saturated(self) -> bool:
        """No free capacity and a queue is forming (autoscaler signal)."""
        return bool(self.waiting) and not self.backend.can_admit(
            len(self.waiting[0].prompt_tokens))

    def cache_stats(self) -> dict:
        """Prefix-cache counters from the backend (empty for slot backend)."""
        return self.backend.cache_stats()

    # -- engine iteration ---------------------------------------------------------
    def step(self) -> list[RequestOutput]:
        self.stats["steps"] += 1
        finished: list[RequestOutput] = []

        # 1) prefill: whole prompts (legacy) or up to the chunk budget
        if self.cfg.chunked_prefill_budget > 0:
            self._prefill_chunked(finished)
        else:
            self._prefill_one_shot(finished)

        # 2) one batched decode step over all running sequences
        if self.running:
            max_slots = self.cfg.max_slots
            tokens = np.zeros((max_slots,), np.int32)
            by_slot: dict[int, _Running] = {}
            for rid, run in self.running.items():
                s = self.backend.slot(rid)
                tokens[s] = run.last_token
                by_slot[s] = run
            logits = self.backend.decode_batch(tokens)
            temps = np.zeros((max_slots,), np.float32)
            top_ps = np.ones((max_slots,), np.float32)
            seeds = np.zeros((max_slots,), np.int32)
            for s, run in by_slot.items():
                sp = run.req.sampling
                temps[s] = sp.temperature
                top_ps[s] = sp.top_p
                seeds[s] = (sp.seed * 1_000_003
                            + len(run.output_tokens)) % (2 ** 31 - 1)
            toks = np.asarray(sample_tokens(logits, temps, top_ps, seeds))
            for s, run in by_slot.items():
                run.output_tokens.append(int(toks[s]))
                self.stats["decode_tokens"] += 1
                f = self._maybe_finish(run)
                if f:
                    finished.append(f)
        return finished

    def run_to_completion(self) -> list[RequestOutput]:
        outs = []
        while self.has_work():
            outs.extend(self.step())
        return outs

    # -- prefill scheduling -------------------------------------------------------
    def _admit(self) -> tuple[_Running, PrefillTask]:
        req = self.waiting.popleft()
        run = _Running(req=req, metrics=req._metrics)
        task = self.backend.start_prefill(req.request_id, req.prompt_tokens)
        run.metrics.cached_prompt_tokens = task.cached_tokens
        self.stats["cached_prompt_tokens"] += task.cached_tokens
        return run, task

    def _prefill_one_shot(self, finished: list):
        admitted = 0
        while (self.waiting and admitted < self.cfg.max_prefills_per_step
               and self.backend.can_admit(len(self.waiting[0].prompt_tokens))):
            run, task = self._admit()
            logits, n = self.backend.prefill_chunk(task, None)
            self._account_chunk(run, n)
            self._finish_prefill(run, logits, finished)
            admitted += 1

    def _prefill_chunked(self, finished: list):
        budget = self.cfg.chunked_prefill_budget
        left = budget
        # continue in-flight prefills first (FIFO: oldest admission makes
        # progress before new prompts consume budget)
        for rid, (run, task) in list(self.prefilling.items()):
            if left <= 0:
                return
            logits, n = self.backend.prefill_chunk(task, left)
            left -= n
            self._account_chunk(run, n)
            if logits is not None:
                del self.prefilling[rid]
                self._finish_prefill(run, logits, finished)
        admitted = 0
        while (left > 0 and self.waiting
               and admitted < self.cfg.max_prefills_per_step
               and self.backend.can_admit(len(self.waiting[0].prompt_tokens))):
            run, task = self._admit()
            admitted += 1
            logits, n = self.backend.prefill_chunk(task, left)
            left -= n
            self._account_chunk(run, n)
            if logits is not None:
                self._finish_prefill(run, logits, finished)
            else:
                self.prefilling[run.req.request_id] = (run, task)

    def _account_chunk(self, run: _Running, n_tokens: int):
        self.stats["prefill_tokens"] += n_tokens
        self.stats["prefill_chunks"] += 1
        run.metrics.prefill_chunks += 1

    def _finish_prefill(self, run: _Running, logits, finished: list):
        tok = self._sample_one(run.req, logits, step=0)
        run.output_tokens.append(tok)
        run.metrics.first_token_time = self.clock.now()
        self.stats["decode_tokens"] += 1
        self.running[run.req.request_id] = run
        f = self._maybe_finish(run)
        if f:
            finished.append(f)

    # -- helpers ------------------------------------------------------------------
    def _sample_one(self, req, logits, step) -> int:
        sp = req.sampling
        seed = (sp.seed * 1_000_003 + step) % (2 ** 31 - 1)
        tok = sample_tokens(logits[None].astype(np.float32),
                            np.array([sp.temperature], np.float32),
                            np.array([sp.top_p], np.float32),
                            np.array([seed], np.int32))
        return int(np.asarray(tok)[0])

    def _maybe_finish(self, run: _Running):
        sp = run.req.sampling
        reason = ""
        if sp.stop_token is not None and run.last_token == sp.stop_token:
            reason = "stop"
        elif len(run.output_tokens) >= sp.max_tokens:
            reason = "length"
        elif len(run.output_tokens) + len(run.req.prompt_tokens) \
                >= self.cfg.max_seq_len:
            reason = "max_seq_len"
        if not reason:
            return None
        run.metrics.finish_time = self.clock.now()
        self.backend.free(run.req.request_id)
        del self.running[run.req.request_id]
        self.stats["finished"] += 1
        return RequestOutput(request_id=run.req.request_id,
                             output_tokens=run.output_tokens, finished=True,
                             finish_reason=reason, metrics=run.metrics)
