"""Continuous-batching inference engine (the vLLM-analogue, real JAX).

One ``step()`` = admit waiting requests into free capacity (prefilling each),
then run batched decode across all running sequences. This is vLLM-style
iteration-level scheduling: new requests join the running batch between
token steps, finished ones free their slots/pages immediately.

Throughput/latency features layered on the base loop:

* **Prefix caching** (``enable_prefix_cache``, paged backend): prompts whose
  leading pages content-match already-computed pages skip recomputing them —
  the backend's ``PrefillTask.cached_tokens`` reports how much was reused.
* **Chunked prefill** (``chunked_prefill_budget`` > 0): instead of ingesting
  a whole prompt in one step (stalling decode for every running sequence),
  each step computes at most ``budget`` prompt tokens across the in-flight
  prefills, then still runs the decode batch — bounding time-between-tokens
  while long prompts admit. A sequence samples its first token (and joins
  the decode batch) only once its final chunk completes.
* **Fused decode fast path** (``fused_decode``, default on): decode forward,
  sampling, and stop/length checks run in ONE jitted donated device call;
  the ``(max_slots, V)`` logits never come back to the host. Per-slot
  sampling state lives in slot-indexed arrays updated only when the batch
  composition changes (admit/free), not rebuilt per step.
* **Multi-step decode** (``decode_steps_per_sync`` = K > 1): the fused call
  loops K decode steps on device (``lax.fori_loop``) and the host syncs
  once per K tokens — amortizing dispatch + transfer latency. The engine
  falls back to K=1 automatically whenever a prefill is in flight or the
  batch composition just changed, so chunked prefill and prefix caching
  compose unchanged; outputs are token-identical to the per-step path.
* **Speculative decoding** (``spec_tokens`` = k > 0, with a draft model):
  per round the draft's fused loop proposes k tokens and ONE batched
  target forward verifies all k+1 positions, accepting via the seeded-
  sampler exact-match test (see ``serving/sampler.py``) — so the target's
  weights are read once per up-to-k+1 emitted tokens while greedy AND
  seeded top-p streams stay token-identical to non-speculative decoding.
  Both caches truncate to the accepted prefix each round.
* **Pluggable scheduling + preemption** (``scheduling_policy``,
  ``enable_preemption``): admission/ordering/eviction decisions live in
  ``serving/scheduler.py`` (FCFS — the legacy behavior, bit-identical;
  priority/QoS with per-class token budgets; EDF on TTFT deadlines). With
  preemption on, a policy may evict a running lower-urgency sequence:
  its pages are published to the prefix cache and freed (COW/refcount
  aware), and the victim re-enters the queue to be *restored* by
  recompute-via-prefix-cache — a chunked prefill of its emitted stream
  that mostly hits the pages it just published — or, with
  ``preempt_swap``, by a host swap-out/in round trip that needs no
  recompute. Restored sequences keep their sampling state (seeds fold on
  ``n_gen``), so outputs stay token-identical to an uninterrupted run.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.api.schemas import StreamDelta
from repro.models import LM
from repro.serving.backends import (ATTENTION_FAMILIES, PagedBackend,
                                    PrefillTask, SlotBackend)
from repro.serving.request import (InferenceRequest, RequestMetrics,
                                   RequestOutput)
from repro.serving.sampler import (SEED_MOD, sample_token, sample_tokens,
                                   seed_base)
from repro.serving.scheduler import SchedulingPolicy, make_policy


class _RealClock:
    def now(self) -> float:
        return time.monotonic()


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_seq_len: int = 512
    backend: str = "slots"            # slots | paged
    page_size: int = 64
    num_pages: int | None = None
    use_kernel: bool = False
    # tensor-parallel serving: a jax.sharding.Mesh with a "model" axis (see
    # launch.mesh.make_local_mesh). Params are TP-sharded via ShardingRules,
    # KV pools/caches shard along the kv-head axis, sampling state stays
    # replicated so the fused decode loop keeps its zero-logits-transfer
    # contract. None = the legacy single-device layout.
    mesh: object | None = None
    max_prefills_per_step: int = 4
    # prompt tokens computed per engine step across all in-flight prefills;
    # 0 disables chunking (whole prompts ingest in their admission step)
    chunked_prefill_budget: int = 0
    # content-addressed KV page reuse across sequences (paged backend only)
    enable_prefix_cache: bool = False
    # device-resident decode: fuse decode+sample+stop checks into one jitted
    # call (logits never transferred to host); False = legacy per-step path
    fused_decode: bool = True
    # decode steps per host sync in the fused path (K): the device loops K
    # fused steps and the host unpacks K tokens per slot. Auto-falls back to
    # 1 while prefills are in flight or the batch composition changed.
    decode_steps_per_sync: int = 1
    # speculative decoding: draft tokens proposed per round (0 = off). Needs
    # a draft model passed to the engine; each round the draft's fused loop
    # proposes k tokens and ONE target forward verifies all k+1 positions,
    # accepting via the seeded-sampler acceptance test (token-identical to
    # the non-speculative path for every sampling mode).
    spec_tokens: int = 0
    # admission/ordering/eviction policy: 'fcfs' (legacy behavior,
    # bit-identical), 'priority' (QoS classes + per-class token budgets),
    # 'edf' (earliest TTFT deadline first), or a SchedulingPolicy instance
    scheduling_policy: object = "fcfs"
    # allow the policy to evict running lower-urgency sequences (their KV
    # pages are reclaimed; the victim requeues and restores later)
    enable_preemption: bool = False
    # restore preempted sequences from a host KV copy (swap-out/in) instead
    # of recompute-via-prefix-cache (paged backend only)
    preempt_swap: bool = False
    # per-class in-flight token budgets for the priority policy, e.g.
    # {"batch": 2048}; ignored by other policies
    qos_token_budgets: dict | None = None


@dataclass
class _Running:
    req: InferenceRequest
    metrics: RequestMetrics
    output_tokens: list = field(default_factory=list)
    delta_idx: int = 0                      # next StreamDelta frame index
    draft_task: PrefillTask | None = None   # speculative draft-cache prefill
    # emitted-stream positions the draft cache holds valid KV for; falls
    # behind cache_len whenever non-speculative rounds run (chunked-prefill
    # interleave, headroom fallback) and is caught up before proposing
    draft_len: int = 0
    # preemption state: True while a restore prefill re-ingests the emitted
    # stream; swap_blob holds the host KV copy on the swap path
    restoring: bool = False
    swap_blob: dict | None = None

    @property
    def last_token(self) -> int:
        return self.output_tokens[-1]

    @property
    def cache_len(self) -> int:
        """KV entries a backend holds for this sequence: every emitted token
        except the last (which is fed, and written, by the next step)."""
        return len(self.req.prompt_tokens) + len(self.output_tokens) - 1


class _SlotStates:
    """Slot-indexed decode state, host mirror of the device-resident copy.

    Rebuilt from scratch never — entries are written on admit (activate)
    and cleared on free, so the per-step hot loop does no host array
    construction. ``dirty`` means the batch composition changed since the
    device copy was seeded: the next fused call re-uploads, and the engine
    syncs every token (K=1) for that step.
    """

    def __init__(self, n: int):
        self.tokens = np.zeros((n,), np.int32)      # last sampled token
        self.n_gen = np.zeros((n,), np.int32)       # tokens generated so far
        self.temps = np.zeros((n,), np.float32)
        self.top_ps = np.ones((n,), np.float32)
        self.seed_base = np.zeros((n,), np.uint32)
        self.stop_tok = np.full((n,), -1, np.int32)  # -1 = no stop token
        self.gen_limit = np.full((n,), np.iinfo(np.int32).max, np.int32)
        self.active = np.zeros((n,), bool)
        self.dirty = True

    def host_state(self) -> dict:
        return {"tokens": self.tokens, "n_gen": self.n_gen,
                "temps": self.temps, "top_ps": self.top_ps,
                "seed_base": self.seed_base, "stop_tok": self.stop_tok,
                "gen_limit": self.gen_limit, "active": self.active}

    def step_seeds(self) -> np.ndarray:
        """PRNG seeds for the next decode step (legacy host path)."""
        s = (self.seed_base + self.n_gen.astype(np.uint32)) % SEED_MOD
        return s.astype(np.int32)


class ContinuousBatchingEngine:
    def __init__(self, model: LM, params, cfg: EngineConfig | None = None,
                 clock=None, draft_model: LM | None = None,
                 draft_params=None):
        self.model = model
        self.cfg = cfg or EngineConfig()
        self.clock = clock or _RealClock()
        if self.cfg.backend == "paged":
            self.backend = PagedBackend(
                model, params, max_slots=self.cfg.max_slots,
                max_len=self.cfg.max_seq_len, page_size=self.cfg.page_size,
                num_pages=self.cfg.num_pages, use_kernel=self.cfg.use_kernel,
                enable_prefix_cache=self.cfg.enable_prefix_cache,
                mesh=self.cfg.mesh)
        else:
            if self.cfg.enable_prefix_cache:
                raise ValueError("prefix caching requires backend='paged'")
            self.backend = SlotBackend(
                model, params, max_slots=self.cfg.max_slots,
                max_len=self.cfg.max_seq_len, mesh=self.cfg.mesh)
        self.draft_backend = None
        if self.cfg.spec_tokens > 0:
            if draft_model is None:
                raise ValueError("spec_tokens > 0 requires a draft model")
            if not self.cfg.fused_decode:
                raise ValueError("speculative decoding requires fused_decode")
            if not getattr(self.backend, "supports_spec_decode", False) \
                    or draft_model.cfg.family not in ATTENTION_FAMILIES:
                raise ValueError("speculative decoding requires attention-"
                                 "family target and draft models")
            if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError("draft and target must share a vocabulary")
            # the draft keeps its KV alongside the target cache in a mirror
            # backend of the same kind (prefix caching off: draft pages are
            # private, rolled back every round)
            if self.cfg.backend == "paged":
                self.draft_backend = PagedBackend(
                    draft_model, draft_params, max_slots=self.cfg.max_slots,
                    max_len=self.cfg.max_seq_len,
                    page_size=self.cfg.page_size,
                    num_pages=self.cfg.num_pages,
                    use_kernel=self.cfg.use_kernel, mesh=self.cfg.mesh)
            else:
                self.draft_backend = SlotBackend(
                    draft_model, draft_params, max_slots=self.cfg.max_slots,
                    max_len=self.cfg.max_seq_len, mesh=self.cfg.mesh)
        if self.cfg.preempt_swap and self.cfg.backend != "paged":
            raise ValueError("preempt_swap requires backend='paged'")
        kwargs = {}
        if self.cfg.scheduling_policy == "priority" \
                and self.cfg.qos_token_budgets:
            kwargs["token_budgets"] = self.cfg.qos_token_budgets
        self.policy: SchedulingPolicy = make_policy(
            self.cfg.scheduling_policy, **kwargs)
        # request_id -> _Running of preempted sequences awaiting restore
        # (their requests sit in the policy queue like fresh arrivals)
        self._preempted: dict[str, _Running] = {}
        # request_id -> StreamDelta callback for stream=true requests
        self._delta_subs: dict[str, object] = {}
        # request_id -> (_Running, PrefillTask): admitted, prompt not yet
        # fully ingested (only populated when chunked prefill is on)
        self.prefilling: "OrderedDict[str, tuple[_Running, PrefillTask]]" = \
            OrderedDict()
        self.running: dict[str, _Running] = {}
        self.slots = _SlotStates(self.cfg.max_slots)
        self.stats = {"prefill_tokens": 0, "cached_prompt_tokens": 0,
                      "prefill_chunks": 0, "decode_tokens": 0, "steps": 0,
                      "decode_syncs": 0, "finished": 0, "aborted": 0,
                      "spec_rounds": 0, "spec_proposed": 0,
                      "spec_accepted": 0, "preemptions": 0, "restores": 0,
                      "restore_cached_tokens": 0, "swap_outs": 0,
                      "swap_ins": 0}

    # -- queue management -------------------------------------------------------
    def add_request(self, req: InferenceRequest, on_delta=None):
        """``on_delta(StreamDelta)``: subscribe to this request's token
        stream — one frame per engine sync that emitted tokens for it (so
        K tokens arrive per frame on the fused multi-step path), plus a
        final empty frame carrying ``finish_reason``. Reassembled frames
        are token-identical to the returned ``RequestOutput``."""
        m = RequestMetrics(arrival_time=req.arrival_time or self.clock.now(),
                           queued_time=self.clock.now())
        req._metrics = m
        if on_delta is not None:
            self._delta_subs[req.request_id] = on_delta
        self.policy.add(req)

    def resume_request(self, req: InferenceRequest, generated_tokens,
                       on_delta=None):
        """Cross-engine failover resume: admit ``req`` with
        ``generated_tokens`` already produced (and streamed to the client)
        by an engine that died. Reuses the preemption-restore path
        verbatim: the emitted stream (prompt + generated) is re-ingested by
        chunked prefill through the prefix cache, sampling state resumes at
        ``n_gen = len(generated)``, and stream frames continue at offset
        ``len(generated)`` — so the stitched output is token-identical to
        an uninterrupted run under greedy AND seeded sampling."""
        if not generated_tokens:
            return self.add_request(req, on_delta)
        m = RequestMetrics(arrival_time=req.arrival_time or self.clock.now(),
                           queued_time=self.clock.now())
        req._metrics = m
        if on_delta is not None:
            self._delta_subs[req.request_id] = on_delta
        run = _Running(req=req, metrics=m,
                       output_tokens=list(generated_tokens))
        self.stats["resumed_tokens"] = \
            self.stats.get("resumed_tokens", 0) + len(generated_tokens)
        self._preempted[req.request_id] = run
        self.policy.add(req)

    def abort(self, request_id: str) -> bool:
        self._delta_subs.pop(request_id, None)
        req = self.policy.remove(request_id)
        if req is not None:
            # a queued preempted victim also drops its saved state
            self._preempted.pop(request_id, None)
            self.stats["aborted"] += 1
            return True
        for pool in (self.prefilling, self.running):
            if request_id in pool:
                entry = pool.pop(request_id)
                run = entry[0] if isinstance(entry, tuple) else entry
                self._release_slot(request_id)
                self.policy.on_released(run.req)
                self.stats["aborted"] += 1
                return True
        return False

    def has_work(self) -> bool:
        return bool(len(self.policy) or self.prefilling or self.running)

    @property
    def num_running(self) -> int:
        return len(self.running)

    @property
    def num_waiting(self) -> int:
        return len(self.policy)

    @property
    def waiting(self) -> list:
        """Queued requests in the policy's admission order (read-only)."""
        return self.policy.snapshot()

    def saturated(self) -> bool:
        """No free capacity and a queue is forming (autoscaler signal)."""
        if not len(self.policy):
            return False
        head = self.policy.peek()
        if head is None:        # queue non-empty but over a class budget
            return True
        return not self._can_admit(self._admit_len(head))

    def _admit_len(self, req: InferenceRequest) -> int:
        """Tokens the admission prefill must cover: the prompt, or — for a
        preempted victim being restored — its whole emitted stream minus
        the last token (whose KV the next decode step writes)."""
        run = self._preempted.get(req.request_id)
        if run is None:
            return len(req.prompt_tokens)
        return len(req.prompt_tokens) + len(run.output_tokens) - 1

    def _can_admit(self, n_prompt: int) -> bool:
        """Admission needs capacity in the target backend AND, when
        speculating, in the draft's mirror backend. With preemption on,
        an admission must also leave enough free pages for the decode
        appends already due this step — otherwise re-admitting a victim
        right after a page-pressure eviction would hand its freed pages
        straight back and starve the surviving sequences' appends. (Gated
        on ``enable_preemption`` so legacy FCFS admission timing is
        untouched.)"""
        if not self.backend.can_admit(n_prompt):
            return False
        if self.cfg.enable_preemption:
            kv = getattr(self.backend, "kv", None)
            if kv is not None and kv.pages_needed(n_prompt + 1) \
                    + self._appends_due() > kv.free_pages:
                return False
        return self.draft_backend is None \
            or self.draft_backend.can_admit(n_prompt)

    def _appends_due(self) -> int:
        """Pages the next decode step must claim for its KV appends (0 for
        the slot backend: its cache is pre-sized)."""
        kv = getattr(self.backend, "kv", None)
        if kv is None:
            return 0
        return sum(1 for sid in self.backend.decoding
                   if kv.pages_needed(kv.length(sid) + 1)
                   > kv.pages_held(sid))

    def cache_stats(self) -> dict:
        """Prefix-cache counters from the backend (empty for slot backend)."""
        return self.backend.cache_stats()

    def spec_acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        p = self.stats["spec_proposed"]
        return self.stats["spec_accepted"] / p if p else 0.0

    # -- preemption ---------------------------------------------------------------
    def preempt(self, request_id: str) -> bool:
        """Evict a RUNNING sequence: publish its computed pages to the
        prefix cache (or swap its KV to the host), free its slot/pages, and
        requeue it for a later restore. Returns False if the request is not
        currently running (mid-prefill sequences are not preemptible —
        their restore would just repeat the same prefill)."""
        run = self.running.pop(request_id, None)
        if run is None:
            return False
        stream = run.req.prompt_tokens + run.output_tokens
        if self.cfg.preempt_swap and hasattr(self.backend, "swap_out"):
            run.swap_blob = self.backend.swap_out(request_id)
            self.stats["swap_outs"] += 1
        else:
            # register the victim's full pages in the content index so the
            # restore prefill content-matches them out of the LRU
            self.backend.publish(request_id, stream[:run.cache_len])
        self._release_slot(request_id)
        self.policy.on_released(run.req)
        run.metrics.preemptions += 1
        self.stats["preemptions"] += 1
        self._preempted[request_id] = run
        self.policy.requeue(run.req)
        return True

    def _page_deficit(self) -> int:
        """Pages the next decode step needs beyond what the pool can claim
        (0 for the slot backend: it never runs out mid-decode)."""
        kv = getattr(self.backend, "kv", None)
        if kv is None:
            return 0
        return max(0, self._appends_due() - kv.free_pages)

    def _admissible_ever(self, n_tokens: int) -> bool:
        """Whether an admission of ``n_tokens`` could EVER fit an empty
        engine — preempting for one that cannot would thrash forever."""
        if n_tokens >= self.cfg.max_seq_len:
            return False
        kv = getattr(self.backend, "kv", None)
        if kv is not None and kv.pages_needed(n_tokens + 1) > kv.num_pages - 1:
            return False
        return True

    def _maybe_preempt(self):
        """Policy-driven eviction, two triggers: the pool cannot cover the
        next decode step's page appends (pressure), or the queue head is
        blocked on capacity while lower-urgency sequences run."""
        if not self.cfg.enable_preemption:
            return
        view = [(rid, run.req, len(run.output_tokens),
                 run.metrics.preemptions)
                for rid, run in self.running.items()]
        deficit = self._page_deficit()
        # pressure needs at least two running sequences: shedding the sole
        # runner frees pages nothing else can use (and would livelock a
        # sequence whose stream simply outgrew the pool)
        while deficit > 0 and len(view) > 1:
            victim = self.policy.select_victim(None, view)
            if victim is None or not self.preempt(victim):
                break
            view = [e for e in view if e[0] != victim]
            deficit = self._page_deficit()
        head = self.policy.peek()
        if head is None:
            return
        n = self._admit_len(head)
        if self._can_admit(n) or not self._admissible_ever(n):
            return
        victim = self.policy.select_victim(head, view)
        if victim is not None:
            self.preempt(victim)

    # -- engine iteration ---------------------------------------------------------
    def step(self) -> list[RequestOutput]:
        self.stats["steps"] += 1
        finished: list[RequestOutput] = []

        # 0) policy-driven eviction (page pressure / blocked urgent head):
        # freed pages are claimable by this same step's admissions
        self._maybe_preempt()

        # 1) prefill: whole prompts (legacy) or up to the chunk budget
        if self.cfg.chunked_prefill_budget > 0:
            self._prefill_chunked(finished)
        else:
            self._prefill_one_shot(finished)

        # 2) batched decode over all running sequences
        if self.running:
            by_slot = {self.backend.slot(rid): run
                       for rid, run in self.running.items()}
            if self.draft_backend is not None and not self.prefilling:
                # speculative round; during chunked-prefill interleave we
                # fall back to the plain fused path (which clamps K=1) so
                # time-between-tokens stays bounded while prompts ingest
                self._decode_spec(by_slot, finished)
            elif (self.cfg.fused_decode
                    and getattr(self.backend, "supports_fused_decode", False)):
                self._decode_fused(by_slot, finished)
            else:
                self._decode_legacy(by_slot, finished)
        return finished

    def _decode_legacy(self, by_slot: dict, finished: list):
        """Per-token host-driven decode: logits come back to the host, a
        second jitted call samples them there."""
        st = self.slots
        logits = self.backend.decode_batch(st.tokens)
        toks = np.asarray(sample_tokens(logits, st.temps, st.top_ps,
                                        st.step_seeds()))
        self.stats["decode_syncs"] += 1
        for s, run in by_slot.items():
            tok = int(toks[s])
            run.output_tokens.append(tok)
            st.tokens[s] = tok
            st.n_gen[s] += 1
            self.stats["decode_tokens"] += 1
            self._emit_delta(run, [tok])
            f = self._maybe_finish(run)
            if f:
                finished.append(f)

    def _decode_fused(self, by_slot: dict, finished: list):
        """Device-resident decode: one fused jitted call runs K decode +
        sample + stop-check steps; the host syncs only (K, max_slots) token
        ids plus produced/done vectors."""
        st = self.slots
        K = max(1, self.cfg.decode_steps_per_sync)
        if self.prefilling or st.dirty:
            # prefill in flight or batch composition changed: sync every
            # token so chunked prefill interleaves unchanged. A backlog in
            # ``waiting`` alone does NOT clamp K — queued requests can only
            # admit once a slot frees, which happens at a sync boundary
            # either way, so a saturated engine keeps the multi-step win.
            K = 1
        toks, produced, done = self.backend.fused_decode(
            K, st.host_state() if st.dirty else None)
        st.dirty = False
        self.stats["decode_syncs"] += 1
        for s, run in by_slot.items():
            p = int(produced[s])
            new = [int(toks[j, s]) for j in range(p)]
            run.output_tokens.extend(new)
            st.tokens[s] = run.last_token
            st.n_gen[s] += p
            self.stats["decode_tokens"] += p
            self._emit_delta(run, new)
            f = self._maybe_finish(run)
            if (f is not None) != bool(done[s]):
                raise RuntimeError(
                    f"fused decode divergence for {run.req.request_id}: "
                    f"device done={bool(done[s])}, host finish="
                    f"{f.finish_reason if f else None}")
            if f:
                finished.append(f)

    def _draft_state(self) -> dict:
        """Per-slot state for the draft's proposal loop: the target's
        sampling params and seed fold (so draft proposals are the token the
        target would sample whenever the logits agree), but no stop token
        and no generation limit — the target's verdict, not the draft's,
        finishes sequences."""
        st = self.slots
        return {"tokens": st.tokens, "n_gen": st.n_gen, "temps": st.temps,
                "top_ps": st.top_ps, "seed_base": st.seed_base,
                "stop_tok": np.full_like(st.stop_tok, -1),
                "gen_limit": np.full_like(st.gen_limit,
                                          np.iinfo(np.int32).max),
                "active": st.active}

    def _decode_spec(self, by_slot: dict, finished: list):
        """One draft-and-verify round: the draft's fused loop proposes k
        tokens per slot (k+1 steps, so the last proposal's KV is written
        too), ONE target forward verifies all k+1 positions on device, and
        both caches truncate to the accepted prefix. Greedy and seeded
        top-p outputs are token-identical to the non-speculative path."""
        st = self.slots
        k = self.cfg.spec_tokens
        lens_by_seq: dict[str, int] = {}
        for run in by_slot.values():
            lens_by_seq[run.req.request_id] = run.cache_len
            # the verify block writes positions cache_len..cache_len+k
            k = min(k, self.cfg.max_seq_len - 1 - run.cache_len)
        k = min(k, self.backend.spec_headroom(max(k, 0)))
        if k < 1:          # no room to speculate (pool tight / seqs at cap)
            return self._decode_fused(by_slot, finished)
        # resync the draft cache: non-speculative rounds (chunked-prefill
        # interleave, headroom fallback) advance the emitted stream without
        # it, so it first ingests the tokens it missed ...
        for run in by_slot.values():
            if run.draft_len < run.cache_len:
                stream = run.req.prompt_tokens + run.output_tokens
                self.draft_backend.spec_catch_up(
                    run.req.request_id, stream[:run.cache_len],
                    run.draft_len)
                run.draft_len = run.cache_len
        # ... then truncate-on-reject from the previous round, and propose:
        # k+1 fused steps emit k usable proposals and leave the k-th
        # proposal's KV written for the all-accepted case
        self.draft_backend.reset_lens(lens_by_seq)
        draft_toks, _, _ = self.draft_backend.fused_decode(
            k + 1, self._draft_state())
        k_used = min(k, draft_toks.shape[0] - 1)   # draft pool may clamp
        draft = draft_toks[:k_used].T              # (max_slots, k_used)
        out, produced, done = self.backend.spec_verify(
            draft, st.host_state() if st.dirty else None)
        st.dirty = False
        self.stats["decode_syncs"] += 1
        self.stats["spec_rounds"] += 1
        for s, run in by_slot.items():
            p = int(produced[s])
            self.stats["spec_proposed"] += k_used
            self.stats["spec_accepted"] += max(p - 1, 0)
            new = [int(out[j, s]) for j in range(p)]
            run.output_tokens.extend(new)
            self._emit_delta(run, new)
            st.tokens[s] = run.last_token
            st.n_gen[s] += p
            # the proposal loop wrote KV for exactly the accepted prefix
            # (plus rejected rows past the rolled-back length)
            run.draft_len = run.cache_len
            self.stats["decode_tokens"] += p
            f = self._maybe_finish(run)
            if (f is not None) != bool(done[s]):
                raise RuntimeError(
                    f"spec decode divergence for {run.req.request_id}: "
                    f"device done={bool(done[s])}, host finish="
                    f"{f.finish_reason if f else None}")
            if f:
                finished.append(f)

    def run_to_completion(self) -> list[RequestOutput]:
        outs = []
        while self.has_work():
            outs.extend(self.step())
        return outs

    # -- prefill scheduling -------------------------------------------------------
    def _admit(self) -> tuple[_Running, PrefillTask | None]:
        req = self.policy.pop()
        self.policy.on_admitted(req)
        run = self._preempted.pop(req.request_id, None)
        if run is not None:
            return self._admit_restore(run)
        run = _Running(req=req, metrics=req._metrics)
        task = self.backend.start_prefill(req.request_id, req.prompt_tokens)
        if self.draft_backend is not None:
            # reserve the draft's slot/pages NOW so both backends see the
            # same admit/free order (their slot indices stay equal); the
            # draft's prompt is computed one-shot when the target's prefill
            # completes
            run.draft_task = self.draft_backend.start_prefill(
                req.request_id, req.prompt_tokens)
        run.metrics.cached_prompt_tokens = task.cached_tokens
        self.stats["cached_prompt_tokens"] += task.cached_tokens
        return run, task

    def _admit_restore(self, run: _Running) -> tuple[_Running, PrefillTask | None]:
        """Re-admit a preempted victim. Swap path: upload the saved host KV
        and rejoin the decode batch immediately (no recompute). Recompute
        path: a prefill of the emitted stream minus its last token — whose
        leading pages usually content-match what the victim published on
        eviction, so only the partial tail page actually computes."""
        rid = run.req.request_id
        run.restoring = True
        hist = (run.req.prompt_tokens + run.output_tokens)[:-1]
        if run.swap_blob is not None:
            self.backend.swap_in(rid, len(hist), run.swap_blob)
            run.swap_blob = None
            self.stats["swap_ins"] += 1
            if self.draft_backend is not None:
                run.draft_task = self.draft_backend.start_prefill(rid, hist)
            self._finish_restore(run)
            return run, None
        task = self.backend.start_prefill(rid, hist)
        if self.draft_backend is not None:
            run.draft_task = self.draft_backend.start_prefill(rid, hist)
        run.metrics.restore_cached_tokens += task.cached_tokens
        self.stats["restore_cached_tokens"] += task.cached_tokens
        return run, task

    def _finish_ingest(self, run: _Running, logits, finished: list):
        """A prompt (or a restore's emitted stream) is fully in the cache:
        rejoin the decode batch — sampling a first token for fresh
        admissions, resuming the saved stream for restores."""
        if run.restoring:
            self._finish_restore(run)
        else:
            self._finish_prefill(run, logits, finished)

    def _prefill_one_shot(self, finished: list):
        admitted = 0
        while admitted < self.cfg.max_prefills_per_step:
            head = self.policy.peek()
            if head is None or not self._can_admit(self._admit_len(head)):
                break
            run, task = self._admit()
            admitted += 1
            if task is None:                  # swap-in restore: no prefill
                continue
            logits, n = self.backend.prefill_chunk(task, None)
            self._account_chunk(run, n)
            self._finish_ingest(run, logits, finished)

    def _prefill_chunked(self, finished: list):
        budget = self.cfg.chunked_prefill_budget
        left = budget
        # continue in-flight prefills first (FIFO: oldest admission makes
        # progress before new prompts consume budget)
        for rid, (run, task) in list(self.prefilling.items()):
            if left <= 0:
                return
            logits, n = self.backend.prefill_chunk(task, left)
            left -= n
            self._account_chunk(run, n)
            if logits is not None:
                del self.prefilling[rid]
                self._finish_ingest(run, logits, finished)
        admitted = 0
        while left > 0 and admitted < self.cfg.max_prefills_per_step:
            head = self.policy.peek()
            if head is None or not self._can_admit(self._admit_len(head)):
                break
            run, task = self._admit()
            admitted += 1
            if task is None:                  # swap-in restore: no prefill
                continue
            logits, n = self.backend.prefill_chunk(task, left)
            left -= n
            self._account_chunk(run, n)
            if logits is not None:
                self._finish_ingest(run, logits, finished)
            else:
                self.prefilling[run.req.request_id] = (run, task)

    def _account_chunk(self, run: _Running, n_tokens: int):
        self.stats["prefill_tokens"] += n_tokens
        self.stats["prefill_chunks"] += 1
        run.metrics.prefill_chunks += 1

    def _finish_prefill(self, run: _Running, logits, finished: list):
        tok = self._sample_one(run.req, logits, step=0)
        run.output_tokens.append(tok)
        run.metrics.first_token_time = self.clock.now()
        self.stats["decode_tokens"] += 1
        self._emit_delta(run, [tok])
        self.running[run.req.request_id] = run
        f = self._maybe_finish(run)
        if f:
            finished.append(f)
        else:
            if run.draft_task is not None:
                # populate the draft's KV for the whole prompt in one shot
                # (the draft is small; its logits are discarded on device)
                self.draft_backend.prefill_chunk(run.draft_task, None)
                run.draft_len = len(run.req.prompt_tokens)
                assert (self.draft_backend.slot(run.req.request_id)
                        == self.backend.slot(run.req.request_id)), \
                    "draft/target slot assignment diverged"
            self._activate_slot(run)

    def _finish_restore(self, run: _Running):
        """A preempted victim's KV is whole again (swap-in or restore
        prefill): rejoin the decode batch with the SAME sampling state —
        ``n_gen`` picks up where it left off, so seeds fold identically
        and the stream stays token-identical to an uninterrupted run. No
        token is sampled here (the restore prefill's logits are for a
        position whose token was already emitted)."""
        rid = run.req.request_id
        run.restoring = False
        self.running[rid] = run
        self.stats["restores"] += 1
        if run.draft_task is not None:
            self.draft_backend.prefill_chunk(run.draft_task, None)
            run.draft_len = run.cache_len
            assert (self.draft_backend.slot(rid) == self.backend.slot(rid)), \
                "draft/target slot assignment diverged"
        self._activate_slot(run)

    # -- slot state ---------------------------------------------------------------
    def _activate_slot(self, run: _Running):
        """Seed the slot-indexed decode state when a sequence joins the
        decode batch (its prefill completed). This is the ONLY place
        sampling params are materialized — the decode loop never rebuilds
        per-step host arrays."""
        s = self.backend.slot(run.req.request_id)
        sp = run.req.sampling
        st = self.slots
        st.tokens[s] = run.last_token
        st.n_gen[s] = len(run.output_tokens)
        st.temps[s] = sp.temperature
        st.top_ps[s] = sp.top_p
        st.seed_base[s] = seed_base(sp.seed)
        st.stop_tok[s] = -1 if sp.stop_token is None else sp.stop_token
        # one bound covers both finish conditions the device can hit:
        # n_gen >= max_tokens ("length") and prompt+n_gen >= max_seq_len
        st.gen_limit[s] = min(sp.max_tokens,
                              self.cfg.max_seq_len
                              - len(run.req.prompt_tokens))
        st.active[s] = True
        st.dirty = True

    def _release_slot(self, request_id: str):
        s = self.backend.slot(request_id)
        self.slots.active[s] = False
        self.slots.dirty = True
        self.backend.free(request_id)
        if self.draft_backend is not None:
            self.draft_backend.free(request_id)

    # -- helpers ------------------------------------------------------------------
    def _sample_one(self, req, logits, step) -> int:
        """First-token sampling from device-resident prefill logits: only
        the sampled id crosses to the host, via the same sampler the fused
        decode path inlines."""
        sp = req.sampling
        seed = (seed_base(sp.seed) + step) % SEED_MOD
        return int(sample_token(logits, sp.temperature, sp.top_p, seed))

    def _emit_delta(self, run: _Running, toks):
        """Push newly appended tokens to the request's stream subscriber
        (a no-op for unsubscribed requests — the hot loop stays clean)."""
        cb = self._delta_subs.get(run.req.request_id)
        if cb is None or not toks:
            return
        frame = StreamDelta(id=run.req.request_id, index=run.delta_idx,
                            tokens=[int(t) for t in toks],
                            n_tokens=len(toks),
                            offset=len(run.output_tokens) - len(toks),
                            created=self.clock.now())
        run.delta_idx += 1
        cb(frame)

    def _maybe_finish(self, run: _Running):
        sp = run.req.sampling
        reason = ""
        if sp.stop_token is not None and run.last_token == sp.stop_token:
            reason = "stop"
        elif len(run.output_tokens) >= sp.max_tokens:
            reason = "length"
        elif len(run.output_tokens) + len(run.req.prompt_tokens) \
                >= self.cfg.max_seq_len:
            reason = "max_seq_len"
        if not reason:
            return None
        cb = self._delta_subs.pop(run.req.request_id, None)
        if cb is not None:                  # final frame: reason, no tokens
            cb(StreamDelta(id=run.req.request_id, index=run.delta_idx,
                           tokens=[], n_tokens=0,
                           offset=len(run.output_tokens),
                           created=self.clock.now(),
                           finished=True, finish_reason=reason))
            run.delta_idx += 1
        run.metrics.finish_time = self.clock.now()
        self._release_slot(run.req.request_id)
        del self.running[run.req.request_id]
        self.policy.on_released(run.req)
        self.stats["finished"] += 1
        return RequestOutput(request_id=run.req.request_id,
                             output_tokens=run.output_tokens, finished=True,
                             finish_reason=reason, metrics=run.metrics)
