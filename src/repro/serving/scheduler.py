"""Pluggable request scheduling policies for the serving engine.

All admission / ordering / eviction *decisions* live here; the engine keeps
only the *mechanics* (prefill protocol, slot state, KV reclaim). A policy is
a queue with an opinion:

* **FCFSPolicy** — arrival order, never preempts. Bit-identical to the
  single hardwired deque the engine grew up with: ``peek`` is the old
  ``waiting[0]``, ``pop`` the old ``popleft``, and head-of-line blocking is
  preserved on purpose (the parity matrix holds across the refactor).
* **PriorityPolicy** — QoS classes (``interactive`` > ``batch`` by
  default, then ``priority`` then arrival within a class) with optional
  per-class *token budgets*: a class whose in-flight tokens
  (prompt + max_tokens of every admitted request) exceed its budget stops
  admitting until sequences finish, so a batch flood cannot occupy every
  slot even before preemption enters the picture. May select a victim:
  the most recently admitted running request of the lowest-ranked class
  strictly below the head's class (LIFO keeps the restore cheap — the
  youngest victim has published the fewest pages).
* **EDFPolicy** — SLA-aware earliest-deadline-first on TTFT deadlines
  (``InferenceRequest.deadline``, absolute clock time; requests without a
  deadline sort last, FIFO among themselves). May preempt the running
  request with the *latest* deadline when the head's deadline is strictly
  earlier.

Preemption itself (page reclaim, requeue, recompute-via-prefix-cache
restore) is engine machinery — see ``ContinuousBatchingEngine.preempt`` —
policies only ever *choose*. ``select_victim(head, running)`` receives the
blocked head request (or ``None`` under pure page pressure) plus the
engine's running view ``[(request_id, request, n_output_tokens,
n_preemptions), ...]`` in admission order, and returns a ``request_id``
or ``None``.
"""
from __future__ import annotations

from collections import deque

from repro.serving.request import InferenceRequest

QOS_INTERACTIVE = "interactive"
QOS_BATCH = "batch"
# lower rank = more important; unknown classes rank with batch
DEFAULT_CLASS_RANK = {QOS_INTERACTIVE: 0, QOS_BATCH: 1}


def class_rank(qos: str) -> int:
    return DEFAULT_CLASS_RANK.get(qos, DEFAULT_CLASS_RANK[QOS_BATCH])


def request_tokens(req: InferenceRequest) -> int:
    """Budget charge for one admitted request: its whole KV footprint."""
    return len(req.prompt_tokens) + req.sampling.max_tokens


class SchedulingPolicy:
    """Queue + admission-order + victim-selection interface.

    The engine calls, per step: ``peek`` (may I admit this next?), ``pop``
    (admission committed), ``on_admitted`` / ``on_released`` (budget
    accounting), and — only when preemption is enabled —
    ``select_victim``. ``add`` enqueues both fresh requests and preempted
    victims re-entering the queue (the engine keeps the victim's partial
    output elsewhere; to the policy a requeued victim is just a request of
    its class again).
    """

    name = "base"

    def add(self, req: InferenceRequest) -> None:
        raise NotImplementedError

    def remove(self, request_id: str) -> InferenceRequest | None:
        """Drop a queued request (abort). Returns it, or None if absent."""
        raise NotImplementedError

    def peek(self) -> InferenceRequest | None:
        """Next admission candidate (None = nothing eligible)."""
        raise NotImplementedError

    def pop(self) -> InferenceRequest:
        """Commit admission of the current ``peek()`` result."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def queue_depth(self) -> int:
        return len(self)

    def snapshot(self) -> list[InferenceRequest]:
        """Queued requests in admission order (introspection only)."""
        raise NotImplementedError

    def requeue(self, req: InferenceRequest) -> None:
        """Re-enqueue a preempted victim. Defaults to ``add``; policies may
        rank victims ahead of fresh arrivals of the same class (their pages
        are parked in the prefix-cache LRU — the sooner they restore, the
        cheaper it is)."""
        self.add(req)

    # -- lifecycle feedback (budget accounting; default: none) ---------------
    def on_admitted(self, req: InferenceRequest) -> None:
        pass

    def on_released(self, req: InferenceRequest) -> None:
        """Admitted request left the engine (finished/aborted/preempted)."""
        pass

    # -- preemption ----------------------------------------------------------
    def select_victim(self, head: InferenceRequest | None,
                      running: list[tuple[str, InferenceRequest, int, int]]
                      ) -> str | None:
        """Pick a running request to preempt so ``head`` (a blocked
        higher-urgency admission, or None under pure page pressure) can
        make progress. ``running`` entries are ``(request_id, request,
        n_output_tokens, n_preemptions)`` in admission order. Base
        policies never preempt."""
        return None


class FCFSPolicy(SchedulingPolicy):
    """Strict arrival order — the pre-refactor engine behavior."""

    name = "fcfs"

    def __init__(self):
        self._q: deque[InferenceRequest] = deque()

    def add(self, req: InferenceRequest) -> None:
        self._q.append(req)

    def remove(self, request_id: str) -> InferenceRequest | None:
        for i, r in enumerate(self._q):
            if r.request_id == request_id:
                del self._q[i]
                return r
        return None

    def peek(self) -> InferenceRequest | None:
        return self._q[0] if self._q else None

    def pop(self) -> InferenceRequest:
        return self._q.popleft()

    def __len__(self) -> int:
        return len(self._q)

    def snapshot(self) -> list[InferenceRequest]:
        return list(self._q)

    def requeue(self, req: InferenceRequest) -> None:
        self._q.appendleft(req)


class PriorityPolicy(SchedulingPolicy):
    """QoS classes with optional per-class token budgets.

    ``class_order``: class names from most to least important (requests of
    unlisted classes are appended at batch rank). ``token_budgets``: class
    -> max in-flight tokens admitted at once (None / missing = unlimited).
    Within a class: lower ``priority`` first, then arrival order.
    """

    name = "priority"

    def __init__(self, class_order: tuple[str, ...] = (QOS_INTERACTIVE,
                                                       QOS_BATCH),
                 token_budgets: dict[str, int] | None = None):
        self.class_order = tuple(class_order)
        self.token_budgets = dict(token_budgets or {})
        self._queues: dict[str, list[InferenceRequest]] = \
            {c: [] for c in self.class_order}
        self._seq = 0                       # arrival tiebreak
        self._rseq = -(1 << 40)             # requeue tiebreak (before fresh)
        self._order: dict[str, int] = {}    # request_id -> arrival seq
        self._in_flight: dict[str, int] = {c: 0 for c in self.class_order}

    def _class_of(self, req: InferenceRequest) -> str:
        return req.qos if req.qos in self._queues else self.class_order[-1]

    def add(self, req: InferenceRequest) -> None:
        if req.request_id not in self._order:
            self._order[req.request_id] = self._seq
            self._seq += 1
        q = self._queues[self._class_of(req)]
        q.append(req)
        q.sort(key=lambda r: (r.priority, self._order[r.request_id]))

    def remove(self, request_id: str) -> InferenceRequest | None:
        for q in self._queues.values():
            for i, r in enumerate(q):
                if r.request_id == request_id:
                    del q[i]
                    self._order.pop(request_id, None)
                    return r
        return None

    def _within_budget(self, cls: str, req: InferenceRequest) -> bool:
        budget = self.token_budgets.get(cls)
        if budget is None:
            return True
        if self._in_flight[cls] == 0:
            # an idle class always gets its head request through, even one
            # bigger than the whole budget — a budget caps CONCURRENCY, it
            # must never make a request permanently inadmissible (the
            # engine would otherwise spin on has_work() forever)
            return True
        return self._in_flight[cls] + request_tokens(req) <= budget

    def peek(self) -> InferenceRequest | None:
        for cls in self.class_order:
            q = self._queues[cls]
            if q and self._within_budget(cls, q[0]):
                return q[0]
        return None

    def pop(self) -> InferenceRequest:
        head = self.peek()
        assert head is not None, "pop() on an empty/over-budget queue"
        self._queues[self._class_of(head)].remove(head)
        self._order.pop(head.request_id, None)
        return head

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def snapshot(self) -> list[InferenceRequest]:
        return [r for c in self.class_order for r in self._queues[c]]

    def requeue(self, req: InferenceRequest) -> None:
        # victims sort before fresh arrivals of the same priority, FIFO
        # among themselves (negative arrival keys, increasing)
        self._order[req.request_id] = self._rseq
        self._rseq += 1
        q = self._queues[self._class_of(req)]
        q.append(req)
        q.sort(key=lambda r: (r.priority, self._order[r.request_id]))

    def on_admitted(self, req: InferenceRequest) -> None:
        self._in_flight[self._class_of(req)] += request_tokens(req)

    def on_released(self, req: InferenceRequest) -> None:
        cls = self._class_of(req)
        self._in_flight[cls] -= request_tokens(req)
        assert self._in_flight[cls] >= 0, f"budget underflow for {cls!r}"

    def select_victim(self, head, running) -> str | None:
        # among the WORST class strictly below the head's class, ROTATE:
        # fewest-preempted first, then most recently admitted. Pure LIFO
        # would evict the same victim every time a burst of urgent work
        # lands — that one sequence then drains the whole run alone in a
        # near-empty (slow) batch, which costs more total throughput than
        # spreading the delay across victims. Under pure page pressure
        # (head=None) any class may be shed.
        floor = class_rank(head.qos) if head is not None else -1
        victim, victim_key = None, None
        for i, (rid, req, _n_out, n_pre) in enumerate(running):
            r = class_rank(req.qos)
            if r <= floor:
                continue
            key = (r, -n_pre, i)    # worst class, least-evicted, youngest
            if victim_key is None or key > victim_key:
                victim, victim_key = rid, key
        return victim


class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first on TTFT deadlines (absolute clock time).

    Requests without a deadline sort after every deadlined request, FIFO
    among themselves — so EDF degrades to FCFS for untagged traffic.
    """

    name = "edf"

    _NO_DEADLINE = float("inf")

    def __init__(self):
        self._q: list[InferenceRequest] = []
        self._seq = 0
        self._rseq = -(1 << 40)             # requeue tiebreak (before fresh)
        self._order: dict[str, int] = {}

    @classmethod
    def _deadline(cls, req: InferenceRequest) -> float:
        return cls._NO_DEADLINE if req.deadline is None else req.deadline

    def add(self, req: InferenceRequest) -> None:
        if req.request_id not in self._order:
            self._order[req.request_id] = self._seq
            self._seq += 1
        self._q.append(req)
        self._q.sort(key=lambda r: (self._deadline(r),
                                    self._order[r.request_id]))

    def remove(self, request_id: str) -> InferenceRequest | None:
        for i, r in enumerate(self._q):
            if r.request_id == request_id:
                del self._q[i]
                self._order.pop(request_id, None)
                return r
        return None

    def peek(self) -> InferenceRequest | None:
        return self._q[0] if self._q else None

    def pop(self) -> InferenceRequest:
        req = self._q.pop(0)
        self._order.pop(req.request_id, None)
        return req

    def __len__(self) -> int:
        return len(self._q)

    def snapshot(self) -> list[InferenceRequest]:
        return list(self._q)

    def requeue(self, req: InferenceRequest) -> None:
        # a preempted victim sorts before fresh arrivals of the SAME
        # deadline (its pages are parked in the prefix-cache LRU); an
        # earlier deadline elsewhere in the queue still wins
        self._order[req.request_id] = self._rseq
        self._rseq += 1
        self._q.append(req)
        self._q.sort(key=lambda r: (self._deadline(r),
                                    self._order[r.request_id]))

    def select_victim(self, head, running) -> str | None:
        # shed the running request with the most slack (latest deadline,
        # most recent on ties); with a blocked head the victim's deadline
        # must be strictly LATER than the head's
        floor = self._deadline(head) if head is not None else -1.0
        victim, victim_d = None, floor
        for rid, req, _n_out, _n_pre in running:   # admission-ordered
            d = self._deadline(req)
            if d > floor and d >= victim_d:
                victim, victim_d = rid, d
        return victim


POLICIES = {p.name: p for p in (FCFSPolicy, PriorityPolicy, EDFPolicy)}


def make_policy(spec: str | SchedulingPolicy | None,
                **kwargs) -> SchedulingPolicy:
    """Build a policy from a name ('fcfs' | 'priority' | 'edf'), pass an
    instance through unchanged, or default to FCFS."""
    if spec is None:
        return FCFSPolicy()
    if isinstance(spec, SchedulingPolicy):
        return spec
    if spec not in POLICIES:
        raise ValueError(f"unknown scheduling policy {spec!r} "
                         f"(have {sorted(POLICIES)})")
    return POLICIES[spec](**kwargs)
