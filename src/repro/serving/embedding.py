"""Embedding engine for encoder-only models (the Infinity-backend analogue:
paper §3.3 serves NV-Embed-v2 next to the LLMs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.models.transformer import forward as tf_forward


class EmbeddingEngine:
    def __init__(self, model: LM, params, max_batch: int = 16,
                 max_len: int = 512):
        assert model.cfg.is_encoder
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self._fwd = {}

    def embed(self, embeds_batch: np.ndarray, lengths: np.ndarray):
        """embeds_batch: (B, S, D) precomputed frontend features;
        lengths: (B,). Returns mean-pooled embeddings (B, D)."""
        B, S, _ = embeds_batch.shape
        key = (B, S)
        if key not in self._fwd:
            def fn(params, x, lens):
                h, _ = tf_forward(params, x.astype(params["embed"].dtype),
                                  self.model.cfg, remat=False)
                mask = (jnp.arange(x.shape[1])[None, :] < lens[:, None])
                mask = mask[..., None].astype(h.dtype)
                pooled = (h * mask).sum(1) / jnp.maximum(mask.sum(1), 1)
                return pooled / jnp.linalg.norm(pooled, axis=-1, keepdims=True)
            self._fwd[key] = jax.jit(fn)
        return np.asarray(self._fwd[key](self.params,
                                         jnp.asarray(embeds_batch),
                                         jnp.asarray(lengths)))
