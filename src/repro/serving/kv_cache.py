"""Paged KV cache manager (vLLM-style block tables, host-side bookkeeping).

The page *pool* is device memory (jnp arrays, shaped (L, NP, page, KH, hd));
this class owns the free list and per-sequence block tables. Token writes and
attention reads happen inside the jitted engine step functions, which receive
the pool plus padded block-table / length arrays built here.
"""
from __future__ import annotations

import numpy as np


class OutOfPages(RuntimeError):
    pass


class PagedKVCache:
    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        # page 0 is reserved as the trash page: inactive batch slots in the
        # jitted decode step write there (masked reads make it harmless)
        self._free = list(range(num_pages - 1, 0, -1))
        self._tables: dict[str, list[int]] = {}
        self._lens: dict[str, int] = {}

    # -- capacity ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= self.free_pages

    # -- lifecycle -----------------------------------------------------------
    def allocate(self, seq_id: str, n_tokens: int) -> list[int]:
        need = self.pages_needed(max(n_tokens, 1))
        if need > self.free_pages:
            raise OutOfPages(f"{seq_id}: need {need} pages, {self.free_pages} free")
        pages = [self._free.pop() for _ in range(need)]
        self._tables[seq_id] = pages
        self._lens[seq_id] = n_tokens
        return pages

    def ensure_slot(self, seq_id: str) -> None:
        """Make sure a page exists for the NEXT token position (call before
        the decode step writes at position ``len``)."""
        n = self._lens[seq_id] + 1
        if self.pages_needed(n) > len(self._tables[seq_id]):
            if not self._free:
                raise OutOfPages(f"{seq_id}: pool exhausted on append")
            self._tables[seq_id].append(self._free.pop())

    def advance(self, seq_id: str) -> None:
        self._lens[seq_id] += 1

    def append_token(self, seq_id: str) -> None:
        """ensure_slot + advance (single-sequence convenience)."""
        self.ensure_slot(seq_id)
        self.advance(seq_id)

    def free(self, seq_id: str) -> None:
        self._free.extend(reversed(self._tables.pop(seq_id, [])))
        self._lens.pop(seq_id, None)

    def length(self, seq_id: str) -> int:
        return self._lens[seq_id]

    # -- device-facing views ---------------------------------------------------
    def table_array(self, seq_ids: list[str], max_pages: int) -> np.ndarray:
        """(B, max_pages) int32, padded with page 0 (masked by lens)."""
        out = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self._tables.get(sid, [])
            out[i, :len(t)] = t
        return out

    def lens_array(self, seq_ids: list[str]) -> np.ndarray:
        return np.array([self._lens.get(s, 0) for s in seq_ids], np.int32)
