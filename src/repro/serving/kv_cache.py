"""Paged KV cache manager (vLLM-style block tables, host-side bookkeeping).

The page *pool* is device memory (jnp arrays, shaped (L, NP, page, KH, hd));
this class owns the free list and per-sequence block tables. Token writes and
attention reads happen inside the jitted engine step functions, which receive
the pool plus padded block-table / length arrays built here.

Prefix caching (``enable_prefix_cache=True``) adds three mechanisms on top of
the plain allocator:

* **Content-addressed pages** — every *full* page of a committed prompt is
  registered under a chain hash ``h_i = H(h_{i-1}, tokens_in_page_i)``, so a
  later prompt sharing the same token prefix maps to the same page chain.
* **Copy-on-write reference counts** — matched pages are shared (refcount
  incremented), including with still-running sequences. Any write into a page
  with refcount > 1 must first go through :meth:`writable_page`, which hands
  the caller a private copy target (the backend performs the device copy).
* **LRU free list** — freeing a sequence does not destroy its registered
  pages; they park in an LRU "cached-free" list and can be resurrected by a
  later hash hit. Fresh allocations draw from the never-cached free list
  first and only then evict the least-recently-used cached page (dropping its
  hash registration).

Invariants (checked by tests/test_prefix_cache.py):
  * page 0 is the trash page: never allocated, never hashed;
  * every other page is in exactly one of {referenced (ref>0), LRU
    cached-free, plain free};
  * ``free_pages`` counts plain free + LRU pages (both are claimable);
  * a partial (not-full) page is never registered, so it is only shared in
    the page-aligned full-prefix case handled by :meth:`writable_page`.

Tensor-parallel serving shards the page *pool* along the kv-head axis, but
this allocator stays a single host-side copy: page ids, block tables,
refcounts, and the prefix index are identical on every shard by
construction (each shard's pool slice is indexed by the SAME tables). When
shards run in separate host processes the allocator must be driven with an
identical operation sequence on each — :meth:`snapshot` captures the full
allocator state so tests can assert replicas never diverge under
admit/free/preempt/COW churn (tests/test_tp_mesh.py).
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


class OutOfPages(RuntimeError):
    pass


class PagedKVCache:
    def __init__(self, num_pages: int, page_size: int, *,
                 enable_prefix_cache: bool = False):
        self.num_pages = num_pages
        self.page_size = page_size
        self.enable_prefix_cache = enable_prefix_cache
        # page 0 is reserved as the trash page: inactive batch slots in the
        # jitted decode step write there (masked reads make it harmless)
        self._free = list(range(num_pages - 1, 0, -1))
        self._tables: dict[str, list[int]] = {}
        self._lens: dict[str, int] = {}
        self._ref: dict[int, int] = {}            # page -> refcount (>0 only)
        # prefix-cache state (all empty when disabled)
        self._hash_of: dict[int, object] = {}     # page -> chain hash
        self._page_of: dict[object, int] = {}     # chain hash -> page
        self._lru: OrderedDict[int, None] = OrderedDict()  # freed cached pages
        self.stats = {"hit_tokens": 0, "miss_tokens": 0, "hit_pages": 0,
                      "evictions": 0, "cow_copies": 0, "resurrections": 0}
        # bumped on every block-table mutation (allocate/append/COW/free);
        # the fused decode path caches device-side tables keyed on this
        self.table_version = 0

    # -- capacity ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free) + len(self._lru)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_allocate(self, n_tokens: int) -> bool:
        # conservative: assumes no prefix hit
        return self.pages_needed(n_tokens) <= self.free_pages

    # -- page hashing ----------------------------------------------------------
    def page_hashes(self, tokens: list[int]) -> list[object]:
        """Chain hash per FULL page of ``tokens`` (partial tail excluded)."""
        out = []
        h = None
        for i in range(len(tokens) // self.page_size):
            chunk = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            h = hash((h, chunk))
            out.append(h)
        return out

    # -- internal page acquisition ---------------------------------------------
    def _take_page(self) -> int:
        """Claim a writable page: prefer never-cached free pages, then evict
        the least-recently-used cached-free page (its hash dies with it)."""
        if self._free:
            p = self._free.pop()
        elif self._lru:
            p, _ = self._lru.popitem(last=False)       # oldest first
            self._drop_registration(p)
            self.stats["evictions"] += 1
        else:
            raise OutOfPages("page pool exhausted")
        self._ref[p] = 1
        return p

    def _drop_registration(self, page: int) -> None:
        h = self._hash_of.pop(page, None)
        if h is not None and self._page_of.get(h) == page:
            del self._page_of[h]

    def _release_page(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page] > 0:
            return
        del self._ref[page]
        if page in self._hash_of:
            self._lru[page] = None                     # park, resurrectable
            self._lru.move_to_end(page)
        else:
            self._free.append(page)

    # -- lifecycle -----------------------------------------------------------
    def allocate(self, seq_id: str, n_tokens: int) -> list[int]:
        """Plain allocation (no prefix matching)."""
        need = self.pages_needed(max(n_tokens, 1))
        if need > self.free_pages:
            raise OutOfPages(f"{seq_id}: need {need} pages, "
                             f"{self.free_pages} free")
        pages = [self._take_page() for _ in range(need)]
        self._tables[seq_id] = pages
        self._lens[seq_id] = n_tokens
        self.table_version += 1
        return pages

    def allocate_with_prefix(self, seq_id: str,
                             tokens: list[int]) -> tuple[list[int], int]:
        """Allocate pages for a full prompt, reusing the longest cached page
        chain. Returns ``(pages, n_cached)``: the sequence's block table and
        how many leading tokens are already computed in shared pages.

        At least one token is always left to compute (its logits seed
        sampling), so a page-aligned full hit reports ``len(tokens) - 1``
        cached tokens; the recomputed final token's KV write then lands in a
        shared page and is COW'd by the backend via :meth:`writable_page`.
        """
        if not self.enable_prefix_cache:
            pages = self.allocate(seq_id, len(tokens))
            self.stats["miss_tokens"] += len(tokens)
            return pages, 0
        hashes = self.page_hashes(tokens)
        matched: list[int] = []
        for h in hashes:
            p = self._page_of.get(h)
            if p is None:
                break
            matched.append(p)
        n_cached = min(len(matched) * self.page_size, max(len(tokens) - 1, 0))
        need_total = self.pages_needed(max(len(tokens), 1))
        n_fresh = need_total - len(matched)
        if n_fresh > len(self._free) + len(self._lru) - sum(
                1 for p in matched if p in self._lru):
            # matched LRU pages are about to be pinned; they no longer count
            # as claimable when sizing the fresh allocation
            raise OutOfPages(f"{seq_id}: need {n_fresh} fresh pages")
        for p in matched:                              # pin shared pages
            if p in self._lru:
                del self._lru[p]
                self._ref[p] = 1
                self.stats["resurrections"] += 1
            else:
                self._ref[p] += 1
        fresh = [self._take_page() for _ in range(n_fresh)]
        self._tables[seq_id] = matched + fresh
        self._lens[seq_id] = len(tokens)
        self.table_version += 1
        self.stats["hit_tokens"] += n_cached
        self.stats["miss_tokens"] += len(tokens) - n_cached
        self.stats["hit_pages"] += len(matched)
        return self._tables[seq_id], n_cached

    def commit_prefix(self, seq_id: str, tokens: list[int]) -> None:
        """Register the sequence's freshly computed full pages in the content
        index (call once prefill has actually written them)."""
        if not self.enable_prefix_cache:
            return
        table = self._tables[seq_id]
        for i, h in enumerate(self.page_hashes(tokens)):
            p = table[i]
            if p in self._hash_of:
                continue                               # already registered
            if h in self._page_of:
                continue                               # a twin won the race
            self._hash_of[p] = h
            self._page_of[h] = p

    def writable_page(self, seq_id: str, token_pos: int):
        """Ensure the page holding ``token_pos`` is privately owned before a
        KV write. Returns ``None`` if already exclusive, else ``(src, dst)``:
        the caller MUST copy device page ``src`` -> ``dst`` (copy-on-write);
        the block table is already updated to ``dst``.
        """
        idx = token_pos // self.page_size
        table = self._tables[seq_id]
        if idx >= len(table):
            return None            # page not allocated yet (nothing shared)
        src = table[idx]
        if self._ref.get(src, 0) <= 1:
            return None
        dst = self._take_page()
        table[idx] = dst
        self.table_version += 1
        self._ref[src] -= 1                            # still >0: others own it
        self.stats["cow_copies"] += 1
        return src, dst

    def ensure_slot(self, seq_id: str) -> None:
        """Make sure a page exists for the NEXT token position (call before
        the decode step writes at position ``len``)."""
        n = self._lens[seq_id] + 1
        if self.pages_needed(n) > len(self._tables[seq_id]):
            if not self.free_pages:
                raise OutOfPages(f"{seq_id}: pool exhausted on append")
            self._tables[seq_id].append(self._take_page())
            self.table_version += 1

    def advance(self, seq_id: str) -> None:
        self._lens[seq_id] += 1

    def advance_n(self, seq_id: str, n: int) -> None:
        """Advance a sequence's length by ``n`` tokens (multi-step decode
        sync: the device loop already wrote their KV)."""
        self._lens[seq_id] += n

    def rollback_to(self, seq_id: str, length: int) -> None:
        """Truncate-on-reject (speculative decoding): shrink a sequence's
        logical length back to ``length``. Pages stay allocated — positions
        past ``length`` are write headroom again and are rewritten before the
        length ever crosses them, so no device-side cleanup is needed. Bumps
        ``table_version`` so device-resident length vectors are re-uploaded.
        """
        cur = self._lens[seq_id]
        assert 0 <= length <= cur, \
            f"{seq_id}: rollback to {length} from {cur}"
        if length != cur:
            self._lens[seq_id] = length
            self.table_version += 1

    def ensure_capacity(self, seq_id: str, ahead: int) -> int:
        """Append pages until the block table covers ``ahead`` tokens past
        the current length (best effort: stops early when the pool runs
        dry rather than raising). Returns how many tokens of write headroom
        the table actually covers — the multi-step decode loop clamps its
        step count to the minimum across sequences."""
        cur = self._lens[seq_id]
        table = self._tables[seq_id]
        while len(table) * self.page_size < cur + ahead and self.free_pages:
            table.append(self._take_page())
            self.table_version += 1
        return min(ahead, len(table) * self.page_size - cur)

    def append_token(self, seq_id: str) -> None:
        """ensure_slot + advance (single-sequence convenience)."""
        self.ensure_slot(seq_id)
        self.advance(seq_id)

    def free(self, seq_id: str) -> None:
        for p in reversed(self._tables.pop(seq_id, [])):
            self._release_page(p)
        self._lens.pop(seq_id, None)
        self.table_version += 1

    def length(self, seq_id: str) -> int:
        return self._lens[seq_id]

    def pages_held(self, seq_id: str) -> int:
        """Block-table size (committed pages + decode headroom)."""
        return len(self._tables[seq_id])

    def ref_count(self, page: int) -> int:
        return self._ref.get(page, 0)

    @property
    def cached_free_pages(self) -> int:
        return len(self._lru)

    def hit_rate(self) -> float:
        tot = self.stats["hit_tokens"] + self.stats["miss_tokens"]
        return self.stats["hit_tokens"] / tot if tot else 0.0

    def snapshot(self) -> dict:
        """Canonical, comparable copy of the full allocator state (block
        tables, lengths, refcounts, free/LRU lists, prefix registrations,
        version). Two allocator replicas driven by the same op sequence
        must produce equal snapshots — the per-shard consistency contract
        of tensor-parallel serving."""
        return {
            "tables": {s: tuple(t) for s, t in self._tables.items()},
            "lens": dict(self._lens),
            "ref": dict(self._ref),
            "free": tuple(self._free),
            "lru": tuple(self._lru.keys()),
            "hash_of": dict(self._hash_of),
            "page_of": dict(self._page_of),
            "table_version": self.table_version,
        }

    # -- device-facing views ---------------------------------------------------
    def table_array(self, seq_ids: list[str], max_pages: int) -> np.ndarray:
        """(B, max_pages) int32, padded with page 0 (masked by lens)."""
        out = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, sid in enumerate(seq_ids):
            t = self._tables.get(sid, [])
            out[i, :len(t)] = t
        return out

    def lens_array(self, seq_ids: list[str]) -> np.ndarray:
        return np.array([self._lens.get(s, 0) for s in seq_ids], np.int32)
