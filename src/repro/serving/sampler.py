"""Token sampling: greedy / temperature / top-p (nucleus)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def sample_tokens(logits, temperature, top_p, seeds):
    """logits: (B, V) f32; temperature, top_p: (B,) f32; seeds: (B,) int32
    (per-request seed folded with the step counter by the caller).
    temperature == 0 -> greedy. Returns (B,) int32."""

    def one(lg, temp, tp, seed):
        greedy = jnp.argmax(lg).astype(jnp.int32)

        def sampled():
            scaled = lg / jnp.maximum(temp, 1e-6)
            sort_idx = jnp.argsort(-scaled)
            sorted_logits = scaled[sort_idx]
            probs = jax.nn.softmax(sorted_logits)
            cum = jnp.cumsum(probs)
            keep = cum - probs < tp               # first token always kept
            masked = jnp.where(keep, sorted_logits, -jnp.inf)
            choice = jax.random.categorical(jax.random.PRNGKey(seed), masked)
            return sort_idx[choice].astype(jnp.int32)

        return jax.lax.cond(temp <= 0.0, lambda: greedy, sampled)

    return jax.vmap(one)(logits, temperature, top_p, seeds)
