"""Token sampling: greedy / temperature / top-p (nucleus).

Three entry points share one implementation:

* :func:`sample_tokens` — jitted batch sampler (the legacy host-driven
  decode path and tests).
* :func:`sample_token` — jitted single-logits sampler for prefill's first
  token; the logits stay on device, only the sampled id crosses to host.
* :func:`sample_from_logits` / :func:`fold_seeds` — pure bodies for
  inlining inside larger jitted programs (the fused decode step), where
  sampling must happen on device without a separate dispatch.

Seed folding: the engine derives a per-request ``seed_base =
(seed * 1_000_003) % SEED_MOD`` once at admission; each step's PRNG seed is
``(seed_base + n_generated) % SEED_MOD``. :func:`fold_seeds` reproduces that
arithmetic in uint32 on device, so host- and device-driven sampling are
bit-identical for the same request state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SEED_MOD = 2 ** 31 - 1
SEED_MULT = 1_000_003


def seed_base(seed: int) -> int:
    """Host-side per-request seed base (fits in uint32/int32)."""
    return (seed * SEED_MULT) % SEED_MOD


def fold_seeds(base, n_gen):
    """base: (B,) uint32 seed bases; n_gen: (B,) int32 tokens generated so
    far. Returns (B,) int32 PRNG seeds, identical to the host fold
    ``(seed * SEED_MULT + n_gen) % SEED_MOD``."""
    s = (base.astype(jnp.uint32) + n_gen.astype(jnp.uint32)) % jnp.uint32(
        SEED_MOD)
    return s.astype(jnp.int32)


def _sample_one(lg, temp, tp, seed):
    """lg: (V,) f32; temp/tp: f32 scalars; seed: int32 scalar -> int32."""
    greedy = jnp.argmax(lg).astype(jnp.int32)

    def sampled():
        scaled = lg / jnp.maximum(temp, 1e-6)
        sort_idx = jnp.argsort(-scaled)
        sorted_logits = scaled[sort_idx]
        probs = jax.nn.softmax(sorted_logits)
        cum = jnp.cumsum(probs)
        keep = cum - probs < tp               # first token always kept
        masked = jnp.where(keep, sorted_logits, -jnp.inf)
        choice = jax.random.categorical(jax.random.PRNGKey(seed), masked)
        return sort_idx[choice].astype(jnp.int32)

    return jax.lax.cond(temp <= 0.0, lambda: greedy, sampled)


def sample_from_logits(logits, temperature, top_p, seeds):
    """Pure (jit-inlinable) batch sampler. logits: (B, V) f32; temperature,
    top_p: (B,) f32; seeds: (B,) int32. temperature == 0 -> greedy.
    Returns (B,) int32."""
    return jax.vmap(_sample_one)(logits, temperature, top_p, seeds)


@jax.jit
def sample_tokens(logits, temperature, top_p, seeds):
    """Jitted batch sampler (see :func:`sample_from_logits`)."""
    return sample_from_logits(logits, temperature, top_p, seeds)


@jax.jit
def sample_token(logits, temperature, top_p, seed):
    """One sequence's first token from device-resident logits (V,).
    Scalars are weak-typed, so repeated calls don't retrace."""
    return _sample_one(logits.astype(jnp.float32),
                       jnp.float32(temperature), jnp.float32(top_p),
                       jnp.int32(seed))
