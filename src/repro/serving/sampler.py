"""Token sampling: greedy / temperature / top-p (nucleus).

Three entry points share one implementation:

* :func:`sample_tokens` — jitted batch sampler (the legacy host-driven
  decode path and tests).
* :func:`sample_token` — jitted single-logits sampler for prefill's first
  token; the logits stay on device, only the sampled id crosses to host.
* :func:`sample_from_logits` / :func:`fold_seeds` — pure bodies for
  inlining inside larger jitted programs (the fused decode step), where
  sampling must happen on device without a separate dispatch.

Seed folding: the engine derives a per-request ``seed_base =
(seed * 1_000_003) % SEED_MOD`` once at admission; each step's PRNG seed is
``(seed_base + n_generated) % SEED_MOD``. :func:`fold_seeds` reproduces that
arithmetic in uint32 on device, so host- and device-driven sampling are
bit-identical for the same request state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SEED_MOD = 2 ** 31 - 1
SEED_MULT = 1_000_003


def seed_base(seed: int) -> int:
    """Host-side per-request seed base (fits in uint32/int32)."""
    return (seed * SEED_MULT) % SEED_MOD


def fold_seeds(base, n_gen):
    """base: (B,) uint32 seed bases; n_gen: (B,) int32 tokens generated so
    far. Returns (B,) int32 PRNG seeds, identical to the host fold
    ``(seed * SEED_MULT + n_gen) % SEED_MOD``."""
    s = (base.astype(jnp.uint32) + n_gen.astype(jnp.uint32)) % jnp.uint32(
        SEED_MOD)
    return s.astype(jnp.int32)


def _sample_one(lg, temp, tp, seed):
    """lg: (V,) f32; temp/tp: f32 scalars; seed: int32 scalar -> int32."""
    greedy = jnp.argmax(lg).astype(jnp.int32)

    def sampled():
        scaled = lg / jnp.maximum(temp, 1e-6)
        sort_idx = jnp.argsort(-scaled)
        sorted_logits = scaled[sort_idx]
        probs = jax.nn.softmax(sorted_logits)
        cum = jnp.cumsum(probs)
        keep = cum - probs < tp               # first token always kept
        masked = jnp.where(keep, sorted_logits, -jnp.inf)
        choice = jax.random.categorical(jax.random.PRNGKey(seed), masked)
        return sort_idx[choice].astype(jnp.int32)

    return jax.lax.cond(temp <= 0.0, lambda: greedy, sampled)


def sample_from_logits(logits, temperature, top_p, seeds):
    """Pure (jit-inlinable) batch sampler. logits: (B, V) f32; temperature,
    top_p: (B,) f32; seeds: (B,) int32. temperature == 0 -> greedy.
    Returns (B,) int32."""
    return jax.vmap(_sample_one)(logits, temperature, top_p, seeds)


@jax.jit
def sample_tokens(logits, temperature, top_p, seeds):
    """Jitted batch sampler (see :func:`sample_from_logits`)."""
    return sample_from_logits(logits, temperature, top_p, seeds)


@jax.jit
def sample_token(logits, temperature, top_p, seed):
    """One sequence's first token from device-resident logits (V,).
    Scalars are weak-typed, so repeated calls don't retrace."""
    return _sample_one(logits.astype(jnp.float32),
                       jnp.float32(temperature), jnp.float32(top_p),
                       jnp.int32(seed))


# ---------------------------------------------------------------------------
# speculative decoding: acceptance test + residual resampling
# ---------------------------------------------------------------------------
# The engine's sampler is DETERMINISTIC given (seed_base, n_gen): position i
# of a sequence always samples the same token from the same logits. Under
# that sampler the target distribution at each position is a point mass on
# the seeded sample t_i, so the standard accept-with-prob-min(1, p/q) test
# collapses to an exact-match test (accept the draft token iff it equals
# t_i) and the residual distribution max(0, p - q) collapses to t_i itself —
# "residual resampling" emits the target's own seeded sample at the first
# mismatch. For greedy (temperature == 0) this is the classic argmax
# acceptance rule. The payoff: speculative output streams are token-
# identical to non-speculative decoding for EVERY sampling mode, not just
# distributionally equivalent.


def spec_targets(logits, temps, top_ps, seed_base, n_gen):
    """Seeded target samples for a block of verify positions.

    logits: (B, T, V) f32 — position j holds the target logits after feeding
    verify token j; temps/top_ps: (B,); seed_base: (B,) uint32; n_gen: (B,)
    tokens generated so far. Position j folds seed ``seed_base + n_gen + j``,
    matching what the non-speculative loop would fold when emitting that
    token. Returns (B, T) int32.
    """
    B, T, V = logits.shape
    flat = logits.reshape(B * T, V).astype(jnp.float32)
    n2 = n_gen[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    seeds = fold_seeds(jnp.repeat(seed_base, T), n2.reshape(-1))
    out = sample_from_logits(flat, jnp.repeat(temps, T),
                             jnp.repeat(top_ps, T), seeds)
    return out.reshape(B, T)


def spec_accept(targets, draft):
    """Acceptance test: how much of the draft survives verification.

    targets: (B, k+1) seeded target samples (see :func:`spec_targets`);
    draft: (B, k) proposed tokens. Returns ``(emit, n_emit)``:
    ``emit[b, j]`` marks verify position j as emittable (position 0 — the
    guaranteed target token — always is; position j > 0 iff every draft
    token before it matched), ``n_emit = 1 + accepted`` counts them. The
    emitted token at the first mismatch is ``targets`` at that position —
    the residual resample.
    """
    B = targets.shape[0]
    match = (targets[:, :-1] == draft).astype(jnp.int32)
    prefix = jnp.cumprod(match, axis=1)
    emit = jnp.concatenate(
        [jnp.ones((B, 1), jnp.int32), prefix], axis=1).astype(bool)
    return emit, emit.sum(axis=1).astype(jnp.int32)
