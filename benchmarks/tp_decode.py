"""Tensor-parallel decode benchmark (real engine, simulated mesh, CPU).

Steady-state fused decode on a single device vs the same engine sharded
4-way over a simulated ``(data=1, model=4)`` mesh (qwen MHA reduced, so
the KV pool genuinely splits along its head axis). On real accelerators
the sharded path buys HBM headroom and per-chip FLOP reduction; on a
simulated CPU mesh every "device" shares the same cores plus all-reduce
overhead, so the interesting outputs are CORRECTNESS ratios, not a
speedup:

* token streams must be byte-identical across the two placements (the
  mesh-axis parity contract of tests/test_parity_matrix.py, here at
  benchmark batch/length scale);
* neither placement may ship a single logits tensor to the host
  (sampling stays replicated on the mesh);
* the sharded/single throughput ratio is recorded as an artifact trend
  line — no floor is enforced.

Needs >= 4 visible devices. When run via ``benchmarks.run`` (where jax
already initialized single-device), ``main`` re-execs this module as a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Writes ``results/benchmarks/tp_decode.json`` (``.fast.json`` under
--fast/--smoke).
"""
from __future__ import annotations

import copy
import json
import os
import subprocess
import sys
import time

# when executed directly, fake the mesh devices before jax initializes
if __name__ == "__main__":
    _x = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _x:
        os.environ["XLA_FLAGS"] = \
            (_x + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from benchmarks.common import csv_line, print_table
from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving import backends
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

ARCH = "qwen1.5-4b"        # MHA: 4 kv heads / 4 shards -> true head split
SHARDS = 4
PAGE = 16
PROMPT_LEN = 24
SLOTS = 4
K = 4                      # fused decode steps per host sync
OUT_PATH = os.path.join("results", "benchmarks", "tp_decode.json")


def _requests(vocab, n, gen, seed=0):
    rng = np.random.default_rng(seed)
    return [InferenceRequest(
        model=ARCH,
        prompt_tokens=rng.integers(2, vocab, size=PROMPT_LEN).tolist(),
        request_id=f"r{i}",
        sampling=SamplingParams(max_tokens=gen, temperature=0.0))
        for i in range(n)]


def _mk_engine(model, params, gen, mesh):
    cfg = EngineConfig(
        max_slots=SLOTS, max_seq_len=PROMPT_LEN + gen + PAGE,
        backend="paged", page_size=PAGE, fused_decode=True,
        decode_steps_per_sync=K, mesh=mesh)
    return ContinuousBatchingEngine(model, params, cfg)


def _timed_pass(eng, reqs):
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    dec0 = eng.stats["decode_tokens"]
    rates = []
    outputs = {}
    t0 = time.perf_counter()
    prev = t0
    while eng.has_work():
        tok0 = eng.stats["decode_tokens"]
        for o in eng.step():
            outputs[o.request_id] = list(o.output_tokens)
        now = time.perf_counter()
        if eng.stats["decode_tokens"] > tok0:
            rates.append((eng.stats["decode_tokens"] - tok0) / (now - prev))
        prev = now
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "decode_tokens": eng.stats["decode_tokens"] - dec0,
        "tok_per_s": (eng.stats["decode_tokens"] - dec0) / wall,
        "steady_tok_per_s": float(np.median(rates)),
        "outputs": outputs,
    }


def bench(gen: int) -> dict:
    from repro.launch.mesh import make_local_mesh

    cfg = reduced(REGISTRY[ARCH])
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    reqs = _requests(cfg.vocab_size, SLOTS, gen, seed=2)
    modes = [("single", None), (f"tp{SHARDS}", make_local_mesh(1, SHARDS))]
    results, rows = [], []
    for name, mesh in modes:
        eng = _mk_engine(model, params, gen, mesh)
        _timed_pass(eng, _requests(cfg.vocab_size, SLOTS, gen, seed=1))
        backends.reset_transfer_stats()
        r = _timed_pass(eng, reqs)
        transfers = backends.TRANSFER_STATS["decode_logits_transfers"]
        for _ in range(2):     # best-of-3 vs shared-host contention
            r2 = _timed_pass(eng, reqs)
            if r2["steady_tok_per_s"] > r["steady_tok_per_s"]:
                r2["outputs"] = r["outputs"]
                r = r2
        r["mode"] = name
        r["logits_transfers"] = transfers
        assert transfers == 0, f"{name}: logits crossed to the host"
        results.append(r)
        rows.append([name, f"{r['steady_tok_per_s']:.0f}",
                     f"{r['wall_s']:.2f}", r["decode_tokens"], transfers])
        csv_line(f"tp_decode/{name}", r["wall_s"] * 1e6 / max(
            r["decode_tokens"], 1), f"tok_s={r['steady_tok_per_s']:.0f}")
    single, tp = results
    assert tp["outputs"] == single["outputs"], \
        "sharded decode diverged from single-device (token parity broken)"
    ratio = tp["steady_tok_per_s"] / single["steady_tok_per_s"]
    print_table(
        f"TP decode ({ARCH} reduced, B={SLOTS}, {gen} gen, K={K}, "
        f"{SHARDS} simulated shards)",
        ["mode", "steady tok/s", "wall s", "tokens", "logits->host"],
        rows, widths=[8, 12, 8, 8, 12])
    print(f"\nsharded/single throughput ratio: {ratio:.2f}x "
          f"(simulated mesh: collectives are pure overhead on CPU)")
    return {"modes": [{k: v for k, v in r.items() if k != "outputs"}
                      for r in results],
            "ratio_tp_vs_single": ratio,
            "tokens_identical": True}


def _run_self(fast: bool, smoke: bool) -> None:
    """Re-exec under a fresh interpreter where the fake-device flag can
    still take effect (jax in THIS process already chose its backend)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    args = [sys.executable, "-m", "benchmarks.tp_decode"]
    if fast:
        args.append("--fast")
    if smoke:
        args.append("--smoke")
    proc = subprocess.run(args, env=env)
    if proc.returncode != 0:
        raise SystemExit(f"tp_decode subprocess failed ({proc.returncode})")


def main(fast: bool = False, smoke: bool = False) -> dict | None:
    if jax.device_count() < SHARDS:
        _run_self(fast, smoke)
        return None
    gen = 32 if (smoke or fast) else 96
    out = {"arch": ARCH, "batch": SLOTS, "prompt_len": PROMPT_LEN,
           "gen_tokens": gen, "page_size": PAGE, "K": K,
           "model_shards": SHARDS, **bench(gen)}
    path = OUT_PATH.replace(".json", ".fast.json") if (fast or smoke) \
        else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main(fast="--fast" in sys.argv, smoke="--smoke" in sys.argv)
