"""Fig. 4 reproduction: auto-scaling Llama-70B from 1 to 4 instances under
infinite request rate (1000 requests).

Paper claims: req/s 8.3 / 14.6 / 20.9 / 23.9 and output tok/s scaling
1x / 1.75x / 2.52x / 2.88x at 1/2/3/4 instances (sublinear because Globus
Compute's relay capacity becomes the ceiling), median latency dropping
54.5 -> 30.1 -> 18.8 -> 16.0 s.  The relay cap is modeled by
``ComputeClient.relay`` (see benchmarks/common.py).
"""
from __future__ import annotations

from benchmarks.common import (LLAMA70B, csv_line, first_system,
                               make_workload, print_table, warm_up)
from repro.core.testbed import drive_workload

N_REQ = 1000
# Globus relay: 2 workers x 24 ms/task-leg; both legs (dispatch + result)
# share the FIFO, reproducing the paper's 'scaling is currently limited by
# the ability of Globus Compute to scale and route requests' ceiling
RELAY = dict(relay_workers=2, relay_cpu=0.024)
# DGX-A100 constants for the paper-validation sweep (8x A100-40GB/node);
# step_overhead 4 ms ~ vLLM scheduler+sampling per iteration
A100 = dict(peak_flops=312e12, hbm_bw=1555e9, step_overhead=0.004)


def run(max_instances: int, n: int = N_REQ, hw: dict | None = None) -> dict:
    # result_cpu: each instance's single Globus endpoint worker serializes
    # result packaging/upload (~120 ms per completed task).  This is what
    # makes ONE instance saturate near 8 req/s while added instances keep
    # scaling (each brings its own worker) until the shared relay binds --
    # the paper's 'limited by the ability of Globus Compute to scale and
    # route requests'.  Calibrated against Fig. 4; Fig. 3/5 reproduce
    # without it because their endpoints aren't result-worker-bound.
    dep_kw = dict(chips_per_instance=8, nodes_per_instance=1, max_slots=128,
                  mfu=0.5, storage_bw=2e9, result_cpu=0.12)
    if hw:
        dep_kw["hw"] = hw
    sysd = first_system(LLAMA70B, max_instances=max_instances,
                        dep_kw=dep_kw, **RELAY)
    # steady-state capacity: the paper measures saturated configurations in
    # which auto-scaling has already brought the instances up (a 70B cold
    # start is ~90 s -- longer than the whole 1000-request run)
    warm_up(sysd, LLAMA70B.name, instances=max_instances)
    wl = make_workload(n, rate=float("inf"), seed=11)
    s = drive_workload(sysd, wl, LLAMA70B.name)
    ep = sysd.endpoints["sophia-ep"]
    s["instances"] = len([i for i in ep.instances[LLAMA70B.name]])
    return s


def sweep(label: str, n: int, hw: dict | None) -> list[dict]:
    rows, out = [], []
    for k in (1, 2, 3, 4):
        s = run(k, n, hw)
        scale = s["output_tok_per_s"] / out[0]["output_tok_per_s"] \
            if out else 1.0
        rows.append([k, s["instances"], f"{s['req_per_s']:.1f}",
                     f"{s['output_tok_per_s']:.0f}", f"{scale:.2f}x",
                     f"{s['median_e2e_s']:.1f}"])
        out.append(s)
        csv_line(f"autoscale/{label}/{k}inst", s["median_e2e_s"] * 1e6,
                 f"req_s={s['req_per_s']:.1f};"
                 f"tok_s={s['output_tok_per_s']:.0f};scale={scale:.2f}")
    print_table(
        f"Fig.4 — auto-scaling (Llama-70B, infinite rate) [{label}]",
        ["max_inst", "spawned", "req/s", "tok/s", "tok/s scale",
         "median e2e s"],
        rows, widths=[8, 8, 7, 7, 11, 12])
    scaling = [round(s["output_tok_per_s"] / out[0]["output_tok_per_s"], 2)
               for s in out]
    lat = [round(s["median_e2e_s"], 1) for s in out]
    print(f"check[{label}]: tok/s scaling {scaling} "
          f"(paper, on A100: [1, 1.75, 2.52, 2.88]); latency {lat} "
          f"(paper: [54.5, 30.1, 18.8, 16.0])")
    return out


def main(fast: bool = False) -> dict:
    n = 300 if fast else N_REQ
    # validation sweep on the paper's own hardware constants, then the
    # TPU-v5e target (slower per-chip HBM -> the 2048-token tail binds
    # earlier, flattening the 3-4 instance points; see EXPERIMENTS.md)
    a100 = sweep("A100-validation", n, A100)
    v5e = sweep("v5e-target", n, None)
    return {"a100": a100, "v5e": v5e}


if __name__ == "__main__":
    main()
