"""Speculative-decoding benchmark (real engine, CPU, reduced config).

Steady-state decode throughput for the PR 2 fused multi-step baseline
(``decode_steps_per_sync=16``, target model only) vs draft-and-verify
speculative decoding at ``spec_tokens`` (k) in {4, 8}: per round the 1-layer
draft's fused loop proposes k tokens and ONE target forward verifies all
k+1 positions, so in the accept-heavy regime the target's weights are read
once per ~k+1 emitted tokens instead of once per token.

CI cannot train a distilled draft, so the benchmark constructs the
draft/target pair the way distillation leaves them: the draft IS the
target's first layer (plus shared embeddings/head), and the target stacks
additional layers whose residual contributions are scaled to ~0 — the
target is genuinely ``TARGET_LAYERS``x the draft's per-step compute, while
its argmax agrees with the draft's almost always. The measured acceptance
rate is reported in the JSON artifact and gated at >= 0.7; greedy outputs
are asserted token-identical to the non-speculative baseline — speculation
must be an optimization, not a different sampler.

Writes ``results/benchmarks/spec_decode.json``.
``python -m benchmarks.run --only spec_decode`` or run this module
directly; ``--smoke`` (via ``benchmarks.run``) shrinks the workload and
relaxes the speedup gate for CI.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from benchmarks.common import csv_line, print_table
# workload shape (ARCH/PROMPT_LEN/SLOTS/PAGE) is decode_loop's: the
# imported request builder and timed pass close over those constants
from benchmarks.decode_loop import (ARCH, PAGE, PROMPT_LEN, SLOTS,
                                    _requests, _timed_pass)
from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving import backends
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig

TARGET_LAYERS = 5          # draft is 1 layer: 5x per-step compute asymmetry
RESIDUAL_EPS = 1e-3        # extra-layer output scale ("distilled" agreement)
# wider than the test-suite reduced config: speculation trades draft steps
# for target-layer compute, so layer compute must dominate the fixed per-op
# dispatch floor for the trade to be visible on CPU (as it is on real HW)
DIMS = dict(d_model=256, d_ff=1024, num_heads=8, num_kv_heads=4,
            head_dim=32, vocab_size=1024)
BASELINE_K = 16            # the PR 2 fused multi-step baseline
OUT_PATH = os.path.join("results", "benchmarks", "spec_decode.json")


def build_pair():
    """(draft cfg/model/params, target cfg/model/params) with the target =
    draft + near-zero residual layers (see module docstring)."""
    draft_cfg = dataclasses.replace(reduced(REGISTRY[ARCH]), num_layers=1,
                                    **DIMS)
    target_cfg = dataclasses.replace(draft_cfg, num_layers=TARGET_LAYERS)
    draft_model = make_model(draft_cfg)
    target_model = make_model(target_cfg)
    dp = draft_model.init_params(jax.random.PRNGKey(0))
    tp = target_model.init_params(jax.random.PRNGKey(1))
    tp["embed"] = dp["embed"]
    tp["final_norm"] = dp["final_norm"]
    if "lm_head" in dp:
        tp["lm_head"] = dp["lm_head"]

    def graft(path, t, d):
        t = t.at[0].set(d[0])                  # layer 0 == the draft
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("wo", "w2"):               # extra layers: ~zero residual
            t = t.at[1:].multiply(jnp.asarray(RESIDUAL_EPS, t.dtype))
        return t

    tp["layers"] = jtu.tree_map_with_path(graft, tp["layers"], dp["layers"])
    return (draft_cfg, draft_model, dp), (target_cfg, target_model, tp)


def _mk_engine(target, draft, gen, *, spec_k):
    _, tm, tp = target
    _, dm, dp = draft
    cfg = EngineConfig(
        max_slots=SLOTS, max_seq_len=PROMPT_LEN + gen + 2 * PAGE,
        backend="paged", page_size=PAGE,
        decode_steps_per_sync=1 if spec_k else BASELINE_K,
        spec_tokens=spec_k)
    if spec_k:
        return ContinuousBatchingEngine(tm, tp, cfg, draft_model=dm,
                                        draft_params=dp)
    return ContinuousBatchingEngine(tm, tp, cfg)


def bench(target, draft, *, gen, ks):
    vocab = target[0].vocab_size
    reqs = _requests(vocab, SLOTS, gen, seed=2)
    modes = [(f"fused K={BASELINE_K}", 0)] + [(f"spec k={k}", k) for k in ks]
    results, rows = [], []
    for name, spec_k in modes:
        eng = _mk_engine(target, draft, gen, spec_k=spec_k)
        # warmup pass compiles every jit bucket this mode will hit
        _timed_pass(eng, _requests(vocab, SLOTS, gen, seed=1))
        accept0 = dict(eng.stats)
        backends.reset_transfer_stats()
        r = _timed_pass(eng, reqs)
        transfers = backends.TRANSFER_STATS["decode_logits_transfers"]
        # best of three passes: contention on a shared host can sit on one
        # mode's whole pass; pass-1 outputs are kept for the identity check
        for _ in range(2):
            r2 = _timed_pass(eng, reqs)
            if r2["steady_tok_per_s"] > r["steady_tok_per_s"]:
                r2["outputs"] = r["outputs"]
                r = r2
        proposed = eng.stats["spec_proposed"] - accept0["spec_proposed"]
        accepted = eng.stats["spec_accepted"] - accept0["spec_accepted"]
        r["mode"], r["spec_tokens"] = name, spec_k
        r["logits_transfers"] = transfers
        r["accept_rate"] = accepted / proposed if proposed else None
        assert r["logits_transfers"] == 0, \
            f"{name}: decode path transferred logits to host"
        results.append(r)
        acc = "-" if r["accept_rate"] is None else f"{r['accept_rate']:.2f}"
        rows.append([name, f"{r['steady_tok_per_s']:.0f}",
                     f"{r['p50_itl_ms']:.2f}", f"{r['p99_itl_ms']:.2f}",
                     r["decode_syncs"], acc])
        csv_line(f"spec_decode/{name.replace(' ', '_')}",
                 r["wall_s"] * 1e6 / max(r["decode_tokens"], 1),
                 f"tok_s={r['steady_tok_per_s']:.0f}")
    base = results[0]["outputs"]
    for r in results[1:]:
        assert r["outputs"] == base, \
            f"{r['mode']} outputs diverged from the non-speculative baseline"
    print_table(
        f"Speculative decoding ({ARCH} reduced, target {TARGET_LAYERS}L / "
        f"draft 1L, B={SLOTS}, {gen} gen tokens)",
        ["mode", "steady tok/s", "p50 ITL ms", "p99 ITL ms", "syncs",
         "accept"],
        rows, widths=[14, 12, 10, 10, 6, 8])
    return results


def main(fast: bool = False, smoke: bool = False) -> dict:
    draft, target = build_pair()
    gen = 64 if (smoke or fast) else 192
    ks = [4] if smoke else [4, 8]
    results = bench(target, draft, gen=gen, ks=ks)
    baseline = results[0]
    best = max(results[1:], key=lambda r: r["steady_tok_per_s"])
    speedup = best["steady_tok_per_s"] / baseline["steady_tok_per_s"]
    out = {"arch": ARCH, "target_layers": TARGET_LAYERS, "draft_layers": 1,
           "batch": SLOTS, "prompt_len": PROMPT_LEN, "gen_tokens": gen,
           "page_size": PAGE, "baseline_steps_per_sync": BASELINE_K,
           "modes": [{k: v for k, v in r.items() if k != "outputs"}
                     for r in results],
           "speedup_spec_vs_fused16": speedup,
           "best_spec_tokens": best["spec_tokens"],
           "accept_rate": best["accept_rate"],
           "tokens_identical": True}
    # fast/smoke runs must not clobber the committed full-mode artifact
    path = OUT_PATH.replace(".json", ".fast.json") if (fast or smoke) \
        else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {path}  (spec k={best['spec_tokens']} vs fused "
          f"K={BASELINE_K}: {speedup:.2f}x, accept={best['accept_rate']:.2f})")
    if best["accept_rate"] < 0.7:
        raise SystemExit(
            f"draft acceptance {best['accept_rate']:.2f} (expected >= 0.7)")
    # the 1.4x acceptance-criterion claim is held to the full-length run;
    # smoke leaves headroom for loaded shared CI runners
    floor = 1.1 if smoke else (1.2 if fast else 1.4)
    if speedup < floor:
        raise SystemExit(
            f"speculative decode speedup is {speedup:.2f}x "
            f"(expected >= {floor}x)")
    return out


if __name__ == "__main__":
    main()
