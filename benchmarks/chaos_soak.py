"""Chaos soak: the federated control plane under a seeded fault schedule.

Two identical two-cluster deployments (retry budget + per-endpoint circuit
breakers + brownout ladder enabled) are driven by the SAME deterministic
workload of streaming interactive and batch requests. The reference run is
fault-free; the chaos run adds a seeded schedule on top of light Poisson
background faults:

  * a NOISY endpoint crash while it is serving live streams (in-flight
    futures error; the gateway fails over and RESUMES each stream on the
    other cluster via restore — the client sees a gap, never a duplicated
    or lost token);
  * a SILENT crash of the failover target later on (futures dropped, no
    error: only the deadline-derived TTFT timeout / stall timeout notice);
  * Poisson heartbeat loss, beat-latency injection, instance kills and
    node failures across the federation.

Acceptance gates (run by CI in ``--smoke``; everything is virtual-clock
deterministic):
  * conservation — every admitted request resolves EXACTLY once: a
    completion or a /v1 taxonomy error, one metrics record each;
  * stream integrity — every surviving stream is token-identical to its
    fault-free replay (same delivered count, assembler-verified contiguous
    offsets, usage accounting agrees);
  * failover resume — at least one mid-stream failover resumed with a
    restored-token counter > 0 (the new engine restored, not regenerated);
  * accounting — retries/timeouts/breaker-opens/budget-withdrawals add up
    against the per-record attempt counts;
  * bounded degradation — interactive p99 TTFT inflation under chaos stays
    within the detection + failover budget.
"""
from __future__ import annotations

import json
import os

from repro.api import FirstClient
from repro.api.errors import APIError
from repro.core.gateway import GatewayConfig
from repro.core.resilience import BreakerPolicy, BrownoutPolicy, RetryPolicy
from repro.core.testbed import (LLAMA70B, build_system, default_deployment,
                                warm_up)

from benchmarks.common import csv_line, print_table

MODEL = LLAMA70B.name
SEED = 1234
# detection + failover budget for the p99 TTFT gate: one deadline-derived
# attempt timeout (<= 30s), backoff, and a worst-case cold start on the
# failover target (~90s job startup + 70B weights at storage bandwidth)
TTFT_INFLATION_BUDGET = 240.0


def _mk_system():
    deps = {"sophia": {MODEL: default_deployment(LLAMA70B)},
            "polaris": {MODEL: default_deployment(LLAMA70B)}}
    sysd = build_system(deps, gateway_config=GatewayConfig(
        retry=RetryPolicy(max_attempts=3, attempt_timeout=300.0,
                          stall_timeout=10.0),
        breaker=BreakerPolicy(),
        brownout=BrownoutPolicy(),
        retry_budget_ratio=0.5,
        retry_seed=SEED,
    ))
    warm_up(sysd, MODEL)                       # sophia hot
    sysd.endpoints["polaris-ep"]._spawn_instance(MODEL)
    sysd.loop.run_until(sysd.loop.now() + 120.0)   # polaris hot too
    return sysd


def _drive(n: int, spacing: float, chaos: bool):
    """Submit ``n`` requests (every 5th is batch, the rest stream) at fixed
    spacing; under ``chaos``, schedule the anchored crashes + the Poisson
    background. Returns (system, futures, assemblers, plan)."""
    sysd = _mk_system()
    base = {k: getattr(sysd.metrics, k) for k in
            ("retries", "timeouts", "breaker_opens")}
    assert all(v == 0 for v in base.values())
    client = FirstClient(sysd.gateway, sysd.token_for("bench"))
    t0 = sysd.loop.now()
    h_arr = n * spacing

    plan = []
    if chaos:
        sysd.faults.rng.seed(SEED)
        # anchors: a noisy crash of the serving endpoint mid-stream, then a
        # silent crash of the failover target after the first recovers
        noisy_t, noisy_dur = t0 + 0.25 * h_arr, 0.3 * h_arr
        silent_t, silent_dur = t0 + 0.75 * h_arr, 0.4 * h_arr
        sysd.faults.crash_endpoint(sysd.endpoints["sophia-ep"], noisy_t,
                                   noisy_dur)
        sysd.faults.crash_endpoint(sysd.endpoints["polaris-ep"], silent_t,
                                   silent_dur, silent=True)
        plan = sysd.faults.plan_chaos(
            sysd.endpoints, sysd.schedulers, horizon=t0 + h_arr,
            start=t0 + 5.0, hb_loss_rate=1 / 150.0, latency_rate=1 / 150.0,
            instance_rate=1 / 120.0, node_rate=1 / 200.0, mean_outage=25.0)
        plan = [{"kind": "crash", "target": "sophia-ep", "t": noisy_t,
                 "duration": noisy_dur},
                {"kind": "silent-crash", "target": "polaris-ep",
                 "t": silent_t, "duration": silent_dur}] + plan

    futs, asms = {}, {}
    for i in range(n):
        rid = f"c{i}"
        arrival = t0 + i * spacing
        batch = i % 5 == 4

        def _go(rid=rid, arrival=arrival, batch=batch):
            # ~40s streams: the anchored crashes land MID-STREAM; the
            # TTFT deadline derives per-attempt timeouts that clear a
            # worst-case cold start on the failover target
            kw = dict(model=MODEL, prompt_tokens=64, max_tokens=1600,
                      request_id=rid, deadline=arrival + 400.0)
            if batch:
                futs[rid] = client.chat(qos="batch", **kw)
            else:
                futs[rid], asms[rid] = client.stream(**kw)

        sysd.loop.call_at(arrival, _go)
    sysd.loop.run_until_idle()
    return sysd, futs, asms, plan


def main(fast: bool = False, smoke: bool = False) -> dict:
    small = fast or smoke
    n, spacing = (24, 4.0) if small else (80, 3.0)

    ref_sys, ref_futs, ref_asms, _ = _drive(n, spacing, chaos=False)
    assert all(f.error is None for f in ref_futs.values())
    assert ref_sys.metrics.retries == 0        # fault-free: no retries
    ref_toks = {rid: f.result().usage.completion_tokens
                for rid, f in ref_futs.items()}
    ref_recs = {r.request_id: r for r in ref_sys.metrics.records}

    sysd, futs, asms, plan = _drive(n, spacing, chaos=True)
    recs = {}
    for r in sysd.metrics.records:
        recs.setdefault(r.request_id, []).append(r)

    failures = []

    # gate 1: conservation — exactly-once resolution, taxonomy-only errors
    survivors, errored = [], []
    for rid, fut in futs.items():
        if not fut.done():
            failures.append(f"{rid} never resolved")
            continue
        if fut.error is None:
            survivors.append(rid)
        else:
            errored.append(rid)
            if not isinstance(fut.error, APIError):
                failures.append(f"{rid} failed outside the /v1 taxonomy: "
                                f"{fut.error!r}")
        if len(recs.get(rid, [])) != 1:
            failures.append(f"{rid} has {len(recs.get(rid, []))} metrics "
                            "records (want exactly 1)")

    # gate 2: stream integrity — survivors token-identical to the replay
    for rid in survivors:
        got = futs[rid].result().usage.completion_tokens
        if got != ref_toks[rid]:
            failures.append(f"{rid}: {got} tokens vs {ref_toks[rid]} in the "
                            "fault-free replay")
        if rid in asms:
            a = asms[rid]
            if not a.finished or a.n_tokens != got:
                failures.append(f"{rid}: client assembled {a.n_tokens} "
                                f"tokens, usage says {got}")

    # gate 3: failover resume — restored, not regenerated
    m = sysd.metrics
    resumed_recs = [rs[0] for rs in recs.values()
                    if rs and rs[0].resumed_tokens > 0]
    if m.failovers_resumed < 1 or m.resumed_tokens <= 0:
        failures.append("no mid-stream failover resumed "
                        f"(failovers_resumed={m.failovers_resumed})")
    if not any(r.attempts >= 2 for r in resumed_recs):
        failures.append("no record shows a resumed retry (attempts >= 2)")
    engine_resumed = sum(
        inst.engine.total_resumed_tokens
        for ep in sysd.endpoints.values()
        for insts in ep.instances.values() for inst in insts)

    # gate 4: accounting adds up
    flat = [r for rs in recs.values() for r in rs]
    if m.retries != sum(r.attempts - 1 for r in flat):
        failures.append(f"retries {m.retries} != attempts-1 sum "
                        f"{sum(r.attempts - 1 for r in flat)}")
    if m.timeouts != sum(r.timeouts for r in flat):
        failures.append(f"timeouts {m.timeouts} != per-record sum")
    if m.breaker_opens != sum(b.opens
                              for b in sysd.gateway.breakers.values()):
        failures.append("breaker_opens disagrees with breaker state")
    if sysd.gateway.retry_budget.withdrawals != m.retries:
        failures.append(f"budget withdrawals "
                        f"{sysd.gateway.retry_budget.withdrawals} != "
                        f"retries {m.retries}")

    # gate 5: bounded interactive p99 TTFT inflation
    def p99_ttft(records, ids):
        ts = sorted(records[rid].ttft if isinstance(records[rid],
                                                    type(flat[0]))
                    else records[rid][0].ttft
                    for rid in ids if rid in records)
        return ts[int(0.99 * (len(ts) - 1))] if ts else 0.0

    stream_ok = [rid for rid in survivors if rid in asms]
    ref_p99 = p99_ttft(ref_recs, [rid for rid in ref_toks if rid in ref_asms])
    chaos_p99 = p99_ttft({k: v[0] for k, v in recs.items() if v}, stream_ok)
    if chaos_p99 > ref_p99 + TTFT_INFLATION_BUDGET:
        failures.append(f"interactive p99 TTFT {chaos_p99:.1f}s exceeds "
                        f"fault-free {ref_p99:.1f}s + "
                        f"{TTFT_INFLATION_BUDGET:.0f}s budget")

    rows = [
        ["requests", n, f"every {spacing:g}s, every 5th batch"],
        ["faults injected", len(sysd.faults.injected),
         f"{len(plan)} planned"],
        ["survivors", len(survivors), f"{len(errored)} taxonomy errors"],
        ["retries", m.retries, f"{m.timeouts} via timeout"],
        ["failovers resumed", m.failovers_resumed,
         f"{m.resumed_tokens} tokens carried over"],
        ["breaker opens", m.breaker_opens,
         f"{len(sysd.gateway.breakers)} endpoints tracked"],
        ["brownout shed", m.brownout_shed,
         sysd.gateway.brownout.snapshot()["step"]],
        ["p99 TTFT", f"{chaos_p99:.1f}s",
         f"vs {ref_p99:.1f}s fault-free"],
        ["gates", "ok" if not failures else "FAILED", ""],
    ]
    print_table("chaos soak (DES, 2-cluster federation, Llama-70B)",
                ["metric", "value", "note"], rows, widths=[18, 10, 34])

    out = {
        "requests": n,
        "planned_faults": len(plan),
        "injected_faults": len(sysd.faults.injected),
        "survivors": len(survivors),
        "taxonomy_errors": len(errored),
        "retries": m.retries,
        "timeouts": m.timeouts,
        "failovers_resumed": m.failovers_resumed,
        "resumed_tokens": m.resumed_tokens,
        "engine_resumed_tokens": engine_resumed,
        "breaker_opens": m.breaker_opens,
        "brownout_shed": m.brownout_shed,
        "p99_ttft_s": round(chaos_p99, 3),
        "ref_p99_ttft_s": round(ref_p99, 3),
        "gates_ok": not failures,
        "gate_failures": failures,
    }
    csv_line("chaos_soak/gates", 0.0,
             f"survivors={len(survivors)};resumed={m.failovers_resumed};"
             f"p99_ttft={chaos_p99:.1f}")

    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks",
                        f"chaos_soak{'.fast' if small else ''}.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.abspath(path)}")

    if failures:
        raise SystemExit("GATE FAILED:\n  " + "\n  ".join(failures))
    print("chaos_soak gates passed")
    return out


if __name__ == "__main__":
    main()
