"""Prefix caching + chunked prefill benchmark (real engine, CPU, reduced
config).

Two experiments, both written to ``results/benchmarks/prefix_cache.json``:

1. **Shared-prefix sweep** — a shared-system-prompt workload (every prompt =
   one shared prefix + a unique tail) at varying share ratios. Measures
   prefill-token throughput (prompt tokens ingested per second) with the
   prefix cache off vs on, steady-state (the shared prefix is warm, as on a
   hot FIRST instance). Acceptance: >= 2x at the 80% share ratio.

2. **Chunked-prefill inter-token latency** — short sequences are decoding
   when one long prompt admits. One-shot prefill stalls every running
   sequence for the whole prompt; with a chunk budget the prompt ingests
   across steps and the max inter-token gap of the running sequences stays
   bounded. Both maxima are recorded.

``python -m benchmarks.run --only prefix_cache`` or run this module directly.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import csv_line, print_table
from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

ARCH = "llama3.2-3b"
PAGE = 32
# long enough that prefill FLOPs dominate framework overhead on CPU; 512 is
# also an exact power-of-two bucket, so the no-cache baseline pays no padding
PROMPT_LEN = 512            # shared prefix + unique tail
OUT_PATH = os.path.join("results", "benchmarks", "prefix_cache.json")


def _mk_engine(model, params, **overrides):
    cfg = EngineConfig(max_slots=4, max_seq_len=640, backend="paged",
                       page_size=PAGE, **overrides)
    return ContinuousBatchingEngine(model, params, cfg)


def _requests(vocab, n, share_ratio, seed=0, max_tokens=1):
    """Prompts = shared prefix (page-aligned share of PROMPT_LEN) + unique
    tails. The prefix depends only on the ratio — warmup and measured
    passes share it, so the cached cell measures the warm steady state.
    ``max_tokens=1`` keeps the run prefill-dominated."""
    n_shared = int(round(share_ratio * PROMPT_LEN / PAGE)) * PAGE
    shared = np.random.default_rng(1000 + n_shared).integers(
        2, vocab, size=n_shared).tolist()
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        tail = rng.integers(2, vocab, size=PROMPT_LEN - n_shared).tolist()
        reqs.append(InferenceRequest(
            model=ARCH, prompt_tokens=shared + tail, request_id=f"r{i}",
            sampling=SamplingParams(max_tokens=max_tokens, temperature=0.0)))
    return reqs


def _drain(eng, reqs):
    for r in reqs:
        eng.add_request(r)
    t0 = time.perf_counter()
    outs = eng.run_to_completion()
    return time.perf_counter() - t0, outs


def bench_share_sweep(model, params, vocab, *, n_req, ratios):
    rows, out = [], []
    for ratio in ratios:
        cells = {}
        for cached in (False, True):
            eng = _mk_engine(model, params, enable_prefix_cache=cached)
            # warmup: compiles every jit bucket AND (cached variant) makes
            # the shared prefix warm — the hot-instance steady state
            _drain(eng, _requests(vocab, 3, ratio, seed=1))
            reqs = _requests(vocab, n_req, ratio, seed=2)
            prompt_tokens = sum(len(r.prompt_tokens) for r in reqs)
            computed0 = eng.stats["prefill_tokens"]   # exclude the warmup
            dt, outs = _drain(eng, reqs)
            assert len(outs) == n_req
            cells["cached" if cached else "baseline"] = {
                "prefill_tok_per_s": prompt_tokens / dt,
                "wall_s": dt,
                "prompt_tokens": prompt_tokens,
                "computed_tokens": None if not cached else
                    eng.stats["prefill_tokens"] - computed0,
                "cache": eng.cache_stats() if cached else None,
            }
        speedup = (cells["cached"]["prefill_tok_per_s"]
                   / cells["baseline"]["prefill_tok_per_s"])
        out.append({"share_ratio": ratio, **cells, "speedup": speedup})
        rows.append([f"{ratio:.2f}",
                     f"{cells['baseline']['prefill_tok_per_s']:.0f}",
                     f"{cells['cached']['prefill_tok_per_s']:.0f}",
                     f"{speedup:.2f}x"])
        csv_line(f"prefix_cache/share_{ratio:.2f}",
                 cells["cached"]["wall_s"] * 1e6 / n_req,
                 f"speedup={speedup:.2f}")
    print_table("Prefix-cache shared-prompt sweep "
                f"({ARCH} reduced, {PROMPT_LEN}-token prompts)",
                ["share", "base tok/s", "cached tok/s", "speedup"],
                rows, widths=[6, 12, 13, 8])
    return out


def bench_chunked_itl(model, params, vocab, *, budget=64, long_prompt=512,
                      n_decode=3, warm_steps=6):
    """Max inter-token latency of already-running sequences while a long
    prompt admits, one-shot vs chunked."""
    rng = np.random.default_rng(3)

    def scenario(chunk_budget):
        eng = _mk_engine(model, params, chunked_prefill_budget=chunk_budget)

        def load(tag, max_tokens):
            for i in range(n_decode):
                eng.add_request(InferenceRequest(
                    model=ARCH,
                    prompt_tokens=rng.integers(2, vocab, size=16).tolist(),
                    request_id=f"{tag}-d{i}",
                    sampling=SamplingParams(max_tokens=max_tokens,
                                            temperature=0.0)))

        # warmup: the long prompt ingests ALONE first so every
        # (chunk-bucket, ctx-bucket) shape the measured admit will hit is
        # compiled; then the decoder shapes
        eng.add_request(InferenceRequest(
            model=ARCH,
            prompt_tokens=rng.integers(2, vocab, size=long_prompt).tolist(),
            request_id="warm-long",
            sampling=SamplingParams(max_tokens=2, temperature=0.0)))
        eng.run_to_completion()
        load("warm", 4)
        eng.run_to_completion()

        # measured pass: decoders run, then the long prompt lands
        load("m", 64)
        for _ in range(warm_steps):
            eng.step()
        last_tok = {rid: time.perf_counter() for rid in eng.running}
        eng.add_request(InferenceRequest(
            model=ARCH,
            prompt_tokens=rng.integers(2, vocab, size=long_prompt).tolist(),
            request_id="m-long",
            sampling=SamplingParams(max_tokens=4, temperature=0.0)))
        max_gap = 0.0
        while eng.has_work():
            eng.step()
            now = time.perf_counter()
            for rid in list(last_tok):
                # every tracked sequence produced one token this step; those
                # no longer running produced their final token in it
                max_gap = max(max_gap, now - last_tok[rid])
                if rid in eng.running:
                    last_tok[rid] = now
                else:
                    del last_tok[rid]
        return max_gap, eng.stats

    itl_one_shot, stats_os = scenario(0)
    itl_chunked, stats_ch = scenario(budget)
    print_table("Chunked prefill: max inter-token latency during long-prompt "
                "admit",
                ["mode", "max ITL (ms)", "prefill chunks"],
                [["one-shot", f"{itl_one_shot*1e3:.1f}",
                  stats_os["prefill_chunks"]],
                 [f"budget={budget}", f"{itl_chunked*1e3:.1f}",
                  stats_ch["prefill_chunks"]]],
                widths=[12, 13, 14])
    csv_line("prefix_cache/itl_one_shot", itl_one_shot * 1e6, "max_itl")
    csv_line("prefix_cache/itl_chunked", itl_chunked * 1e6,
             f"budget={budget}")
    return {"budget": budget, "long_prompt": long_prompt,
            "max_itl_one_shot_s": itl_one_shot,
            "max_itl_chunked_s": itl_chunked,
            "itl_improvement": itl_one_shot / max(itl_chunked, 1e-9)}


def main(fast: bool = False, min_speedup: float = 2.0) -> dict:
    """``min_speedup`` is the 80%-share acceptance gate; CI's ``--smoke``
    lowers it — shared-runner wall clocks swing ~2x between machines and
    the gate should catch regressions, not host variance."""
    cfg = reduced(REGISTRY[ARCH])
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ratios = [0.0, 0.5, 0.8] if not fast else [0.8]
    sweep = bench_share_sweep(model, params, cfg.vocab_size,
                              n_req=6 if fast else 12, ratios=ratios)
    itl = bench_chunked_itl(model, params, cfg.vocab_size)
    result = {"arch": ARCH, "prompt_len": PROMPT_LEN, "page_size": PAGE,
              "share_sweep": sweep, "chunked_prefill": itl}
    # fast/smoke runs must not clobber the committed full-sweep artifact
    path = OUT_PATH.replace(".json", ".fast.json") if fast else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"\nwrote {path}")
    at80 = next((c for c in sweep if abs(c["share_ratio"] - 0.8) < 1e-9),
                None)
    if at80 is not None and at80["speedup"] < min_speedup:
        raise SystemExit(
            f"prefix cache speedup at 80% share is {at80['speedup']:.2f}x "
            f"(expected >= {min_speedup}x)")
    if itl["max_itl_chunked_s"] >= itl["max_itl_one_shot_s"]:
        raise SystemExit("chunked prefill did not reduce max ITL")
    return result


if __name__ == "__main__":
    main()
