"""Fig. 3 reproduction: FIRST vs vLLM-Direct for Llama-70B (TP=8, one node)
at request rates 1 / 5 / 10 / 20 / inf, 1000 ShareGPT-like requests.

Paper claims to validate:
  * low rates: Direct beats FIRST on median latency (3.0 s vs 9.2 s @ 1 req/s)
    -- the Globus round trip costs ~6 s;
  * high rates: FIRST wins BOTH throughput and latency (9.2 vs 5.8 req/s,
    1677 vs 1054 tok/s, 46.9 s vs 80.2 s median @ inf) -- the async gateway
    buffers the burst while Direct's single-threaded front end saturates.
"""
from __future__ import annotations

from benchmarks.common import (DEP_70B, DirectServer, LLAMA70B, csv_line,
                               first_system, make_workload, print_table,
                               summarize, warm_up)
from repro.core.scheduler import ClusterScheduler
from repro.core.testbed import drive_workload
from repro.serving.costmodel import InstanceCost

RATES = [1.0, 5.0, 10.0, 20.0, float("inf")]
N_REQ = 1000


def run_first(rate: float, n: int = N_REQ) -> dict:
    sysd = first_system(LLAMA70B)
    warm_up(sysd, LLAMA70B.name)
    wl = make_workload(n, rate=rate, seed=42)
    return drive_workload(sysd, wl, LLAMA70B.name)


def run_direct(rate: float, n: int = N_REQ) -> dict:
    from repro.core.clock import EventLoop, VirtualClock
    loop = EventLoop(VirtualClock())
    sched = ClusterScheduler(loop, "sophia", num_nodes=24, startup_delay=20.0)
    cost = InstanceCost(cfg=LLAMA70B, chips=DEP_70B["chips_per_instance"],
                        mfu=DEP_70B["mfu"], storage_bw=DEP_70B["storage_bw"])
    srv = DirectServer(loop, sched, cost, max_slots=DEP_70B["max_slots"])
    srv.warm()
    wl = make_workload(n, rate=rate, seed=42)
    for w in wl:
        loop.call_at(w.arrival, srv.submit, w)
    loop.run_until_idle()
    return summarize(srv.records)


def main(fast: bool = False) -> list[dict]:
    n = 250 if fast else N_REQ
    rows, out = [], []
    for rate in RATES:
        f = run_first(rate, n)
        d = run_direct(rate, n)
        label = "inf" if rate == float("inf") else f"{rate:g}"
        rows.append([label, "FIRST", f"{f['req_per_s']:.2f}",
                     f"{f['output_tok_per_s']:.0f}",
                     f"{f['median_e2e_s']:.1f}", f"{f['duration_s']:.0f}"])
        rows.append([label, "Direct", f"{d['req_per_s']:.2f}",
                     f"{d['output_tok_per_s']:.0f}",
                     f"{d['median_e2e_s']:.1f}", f"{d['duration_s']:.0f}"])
        out.append({"rate": rate, "first": f, "direct": d})
        csv_line(f"rate_sweep/first@{label}", f["median_e2e_s"] * 1e6,
                 f"req_s={f['req_per_s']:.2f};tok_s={f['output_tok_per_s']:.0f}")
        csv_line(f"rate_sweep/direct@{label}", d["median_e2e_s"] * 1e6,
                 f"req_s={d['req_per_s']:.2f};tok_s={d['output_tok_per_s']:.0f}")
    print_table(
        "Fig.3 — FIRST vs vLLM Direct (Llama-70B, TP=8, 1 instance)",
        ["rate req/s", "scenario", "req/s", "tok/s", "median e2e s",
         "duration s"],
        rows, widths=[10, 8, 7, 7, 12, 10])
    hi = out[-1]
    lo = out[0]
    print(f"\ncheck: @1 req/s Direct latency < FIRST: "
          f"{lo['direct']['median_e2e_s']:.1f} < {lo['first']['median_e2e_s']:.1f}"
          f" | @inf FIRST beats Direct: "
          f"req/s {hi['first']['req_per_s']:.1f} vs {hi['direct']['req_per_s']:.1f}, "
          f"median {hi['first']['median_e2e_s']:.0f}s vs "
          f"{hi['direct']['median_e2e_s']:.0f}s")
    return out


if __name__ == "__main__":
    main()
