"""/v1 streaming benchmark: what the typed API layer makes VISIBLE.

Before this layer the gateway returned one completion-time future — TTFT
and inter-token latency existed only inside the engine. This suite drives
the same DES deployment twice (non-streamed vs ``stream=true``) and
reports the gateway-observed streaming latencies, then exercises the
disconnect path (client cancel mid-stream frees the engine slot).

Acceptance gates (``smoke=True``, run by CI):
  * streamed and non-streamed requests produce identical token counts;
  * every streamed request records gateway-side TTFT strictly before its
    completion time, with at least 2 frames;
  * cancelling a stream mid-flight aborts the engine-side sequence.

Virtual-clock DES: results are deterministic, no wall-clock sensitivity.
"""
from __future__ import annotations

import json
import os
import statistics

from benchmarks.common import (DEP_70B, GLOBUS_HOP, LLAMA70B, csv_line,
                               first_system, make_workload, print_table,
                               warm_up)
from repro.api import FirstClient, StreamAssembler, errors


def _drive(n: int, stream: bool):
    sysd = first_system(LLAMA70B, dep_kw=DEP_70B)
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("bench"))
    wl = make_workload(n, rate=4.0, seed=17)
    done, asms = {}, {}

    def submit(w):
        kw = dict(model=LLAMA70B.name, prompt_tokens=w.prompt_tokens,
                  max_tokens=w.max_tokens, request_id=w.request_id)
        if stream:
            fut, asm = client.stream(**kw)
            asms[w.request_id] = asm
        else:
            fut = client.chat(**kw)
        fut.add_done_callback(
            lambda f, w=w: done.__setitem__(w.request_id, f))

    for w in wl:
        sysd.loop.call_at(w.arrival, submit, w)
    sysd.loop.run_until_idle()
    assert all(f.error is None for f in done.values())
    toks = {rid: f.result().usage.completion_tokens
            for rid, f in done.items()}
    return sysd, toks, asms


def run_cancel_probe() -> dict:
    """One long stream cancelled mid-flight: the engine slot must free."""
    sysd = first_system(LLAMA70B, dep_kw=DEP_70B)
    warm_up(sysd, LLAMA70B.name)
    client = FirstClient(sysd.gateway, sysd.token_for("bench"))
    fut, asm = client.stream(model=LLAMA70B.name, prompt_tokens=128,
                             max_tokens=5000, request_id="probe")
    sysd.loop.call_after(GLOBUS_HOP * 2 + 30.0,
                         lambda: client.cancel("probe"))
    sysd.loop.run_until_idle()
    inst = sysd.endpoints["sophia-ep"].instances[LLAMA70B.name][0]
    return {"cancelled": isinstance(fut.error, errors.RequestCancelled),
            "frames_before_cancel": len(asm.deltas),
            "engine_load_after": inst.engine.load,
            "engine_aborted": inst.engine.total_aborted}


def main(fast: bool = False, smoke: bool = False) -> dict:
    n = 16 if (fast or smoke) else 64
    _, ref_toks, _ = _drive(n, stream=False)
    sysd, stream_toks, asms = _drive(n, stream=True)

    recs = {r.request_id: r for r in sysd.metrics.records if r.streamed}
    ttfts = sorted(r.ttft for r in recs.values())
    e2es = sorted(r.e2e for r in recs.values())
    s = sysd.metrics.summary()
    probe = run_cancel_probe()

    rows = [
        ["requests", n, ""],
        ["parity (tokens)", "ok" if stream_toks == ref_toks else "MISMATCH",
         "streamed == non-streamed"],
        ["median TTFT", f"{statistics.median(ttfts):.2f}s",
         "gateway-observed, hop included"],
        ["median e2e", f"{statistics.median(e2es):.2f}s", ""],
        ["median ITL", f"{s.get('stream_median_itl_s', 0):.3f}s",
         "per stream frame"],
        ["p99 ITL", f"{s.get('stream_p99_itl_s', 0):.3f}s", ""],
        ["cancel probe", "ok" if probe["cancelled"] else "FAILED",
         f"{probe['frames_before_cancel']} frames then disconnect"],
    ]
    print_table("/v1 streaming at the gateway (DES, Llama-70B)",
                ["metric", "value", "note"], rows, widths=[18, 14, 30])

    out = {
        "requests": n,
        "parity_ok": stream_toks == ref_toks,
        "median_ttft_s": statistics.median(ttfts),
        "median_e2e_s": statistics.median(e2es),
        "median_itl_s": s.get("stream_median_itl_s", 0.0),
        "p99_itl_s": s.get("stream_p99_itl_s", 0.0),
        "min_frames": min(r.stream_frames for r in recs.values()),
        "cancel_probe": probe,
    }
    csv_line("api_stream/parity", 0.0,
             f"parity={out['parity_ok']};ttft={out['median_ttft_s']:.2f}")

    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks",
                        f"api_stream{'.fast' if (fast or smoke) else ''}"
                        ".json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.abspath(path)}")

    # acceptance gates — deterministic on the virtual clock, safe for CI
    if not out["parity_ok"]:
        raise SystemExit("GATE FAILED: streamed tokens != non-streamed")
    if out["min_frames"] < 2:
        raise SystemExit("GATE FAILED: a streamed request saw < 2 frames")
    bad_ttft = [rid for rid, r in recs.items()
                if not (0 < r.ttft < r.e2e)]
    if bad_ttft:
        raise SystemExit(f"GATE FAILED: TTFT not before completion for "
                         f"{bad_ttft}")
    if not probe["cancelled"] or probe["engine_load_after"] != 0 \
            or probe["engine_aborted"] != 1:
        raise SystemExit(f"GATE FAILED: cancel probe {probe}")
    print("api_stream gates passed")
    return out


if __name__ == "__main__":
    main()
