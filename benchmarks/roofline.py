"""§Roofline: achieved-vs-peak bandwidth for the serving attention ops,
plus the three derived roofline terms per (arch x shape x mesh) from the
dry-run records in results/dryrun/*.json (when present).

**Kernel bandwidth** (always runs, CI smoke included): times the four
attention ops on the decode/prefill hot path — ``paged_attention``,
``fused_decode_attention``, ``paged_flash_prefill``, ``flash_attention``
— against a memory-traffic model (KV pages touched + q + output) and
reports achieved bytes/s as a fraction of peak. Decode-shaped attention
is memory-bound, so this fraction IS the roofline headroom. On TPU the
compiled Pallas kernels run against the chip's HBM_BW; on non-TPU hosts
the jnp reference implementations run (interpret-mode Pallas would time
the interpreter, not the op — the references are what the engine executes
hot on CPU) against a peak *measured in-process* by a jitted streaming
baseline, so the fraction stays a same-host ratio (contended-CPU noise
convention). Writes ``results/benchmarks/roofline.json``.

**Dry-run terms** (full runs with results/dryrun/ populated):

  compute_s    = dot_flops / PEAK_FLOPS          (per-chip, post-SPMD HLO)
  memory_s     = (traffic - convert) / HBM_BW    (TPU-projected: CPU-backend
                                                  bf16->f32 convert copies
                                                  excluded, see hlo_analysis)
  collective_s = collective_bytes / LINK_BW      (per-chip ICI bytes)

All inputs are PER-CHIP: the dry-run parses the post-SPMD per-device module
and multiplies while-loop bodies by trip counts (XLA's cost_analysis counts
them once).  MODEL_FLOPS uses the 6ND/2ND convention (attention flops
excluded), so ratio > 1 means attention-heavy, < 1 means padding/remat/
redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.configs import REGISTRY, SHAPES
from repro.kernels.flash_attention.ops import (flash_attention,
                                               paged_flash_prefill)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import (fused_decode_attention,
                                               kernels_compiled,
                                               paged_attention)
from repro.kernels.paged_attention.ref import (fused_decode_attention_ref,
                                               paged_attention_ref,
                                               paged_prefill_attention_ref)

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link
HBM_PER_CHIP = 16 << 30  # v5e: 16 GiB

BW_OUT_PATH = os.path.join("results", "benchmarks", "roofline.json")


# ---------------------------------------------------------------- kernel BW
def _best_time(fn, *args, iters=5):
    """Best-of-N wall clock of a jitted call (compile + warm excluded).
    Best-of, not mean: on a shared host contention only ever adds time."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _measured_peak(iters):
    """Streaming peak of THIS host, measured in-process: a jitted x + 1.0
    over an array far larger than L2, 2 (read+write) x nbytes. Keeps the
    achieved/peak fraction a same-host ratio instead of comparing CPU
    wall clock against a TPU datasheet number."""
    n = 1 << 24                               # 64 MiB f32
    x = jnp.arange(n, dtype=jnp.float32)
    f = jax.jit(lambda a: a + 1.0)
    return 2.0 * x.nbytes / _best_time(f, x, iters=iters)


def kernel_bandwidth(fast: bool = False, smoke: bool = False) -> dict:
    """Achieved-vs-peak bandwidth for the four serving attention ops."""
    on_tpu = kernels_compiled()
    reduced = fast or smoke
    iters = 3 if reduced else 5
    B, KH, G, D, page = 4, 4, 4, 64, 16
    H = KH * G
    pps = 16 if reduced else 64               # pages per sequence
    S = pps * page
    NP = B * pps                              # pool sized to touched pages
    key = jax.random.PRNGKey(0)
    kq, kk = jax.random.split(key)
    kp = jax.random.normal(kk, (NP, page, KH, D), jnp.float32)
    vp = kp * 0.5
    tables = jnp.arange(NP, dtype=jnp.int32).reshape(B, pps)
    lens = jnp.full((B,), S, jnp.int32)       # full: every page is read
    q = jax.random.normal(kq, (B, H, D), jnp.float32)
    Kt = 16
    k_tail = jax.random.normal(kq, (B, Kt, KH, D), jnp.float32)
    v_tail = k_tail * 0.5
    tail_lens = jnp.full((B,), Kt, jnp.int32)
    C = 64 if reduced else 128                # prefill chunk
    qc = jax.random.normal(kq, (B, C, H, D), jnp.float32)
    Sq = 256 if reduced else 512              # dense flash sequence
    qd = jax.random.normal(kq, (B, Sq, H, D), jnp.float32)
    kd = jax.random.normal(kk, (B, Sq, KH, D), jnp.float32)
    vd = kd * 0.5

    if on_tpu:
        impl, peak = "pallas", HBM_BW
        dec, fus = paged_attention, fused_decode_attention
        pre, fla = paged_flash_prefill, flash_attention
    else:
        impl, peak = "reference (XLA)", _measured_peak(iters)
        dec = jax.jit(paged_attention_ref)
        fus = jax.jit(fused_decode_attention_ref)
        pre = jax.jit(paged_prefill_attention_ref,
                      static_argnames=("q_offset", "kv_len"))
        fla = jax.jit(attention_ref)

    kv = kp.nbytes + vp.nbytes
    cases = [
        # (op, bytes model, timed call)
        ("paged_attention", kv + 2 * q.nbytes,
         lambda: dec(q, kp, vp, tables, lens)),
        ("fused_decode_attention",
         kv + 2 * q.nbytes + k_tail.nbytes + v_tail.nbytes,
         lambda: fus(q, kp, vp, tables, lens, k_tail, v_tail, tail_lens)),
        ("paged_flash_prefill", kv + 2 * qc.nbytes,
         lambda: pre(qc, kp, vp, tables, S - C, S)),
        ("flash_attention",
         qd.nbytes + kd.nbytes + vd.nbytes + qd.nbytes,
         lambda: fla(qd, kd, vd)),
    ]
    rows, recs = [], []
    for name, nbytes, call in cases:
        t = _best_time(call, iters=iters)
        bw = nbytes / t
        frac = bw / peak
        recs.append({"op": name, "bytes": nbytes, "time_s": t,
                     "achieved_bytes_per_s": bw, "frac_of_peak": frac})
        rows.append([name, f"{nbytes / 2**20:.1f}", f"{t * 1e3:.3f}",
                     f"{bw / 1e9:.2f}", f"{frac * 100:.1f}%"])
    print_table(
        f"§Roofline kernel bandwidth [{impl}] — B={B} KH={KH} G={G} D={D}, "
        f"ctx {S}, peak {peak / 1e9:.1f} GB/s "
        f"({'HBM datasheet' if on_tpu else 'measured stream'})",
        ["op", "MiB moved", "best ms", "GB/s", "of peak"],
        rows, widths=[24, 10, 9, 8, 8])
    out = {"device": jax.default_backend(), "impl": impl,
           "peak_bytes_per_s": peak, "ctx_len": S, "batch": B,
           "kv_heads": KH, "group": G, "head_dim": D, "cases": recs}
    path = BW_OUT_PATH.replace(".json", ".fast.json") if reduced \
        else BW_OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {path}")
    # sanity gate, not a perf gate: a broken op (NaN timing, zero bytes,
    # wildly super-peak "bandwidth" from a mis-sized traffic model) fails;
    # honest sub-peak fractions (compute-bound flash, interpreter-free
    # reference on a noisy CPU) pass and are simply reported
    for r in recs:
        lo, hi = 0.0, 100.0 * peak
        if not (lo < r["achieved_bytes_per_s"] < hi):
            raise SystemExit(
                f"roofline: {r['op']} achieved "
                f"{r['achieved_bytes_per_s']:.3g} B/s is outside sane "
                f"bounds (peak {peak:.3g})")
    return out


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = REGISTRY[arch]
    sh = SHAPES[shape_name]
    n = cfg.num_active_params
    if sh.kind == "train":
        return 6.0 * n * sh.global_batch * sh.seq_len / devices
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len / devices
    return 2.0 * n * sh.global_batch / devices          # decode: one token


def terms(rec: dict) -> dict:
    flops = rec.get("dot_flops", 0.0)
    traffic = rec.get("traffic_bytes", 0.0) - rec.get("convert_bytes", 0.0)
    coll = rec.get("collective_bytes", 0.0)
    compute_s = flops / PEAK_FLOPS
    memory_s = traffic / HBM_BW
    coll_s = coll / LINK_BW
    bound = max((compute_s, "compute"), (memory_s, "memory"),
                (coll_s, "collective"))[1]
    step_s = max(compute_s, memory_s, coll_s)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bound": bound, "step_s": step_s,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        # roofline fraction: useful model flops per second vs peak
        "roofline_frac": (mf / step_s) / PEAK_FLOPS if step_s else 0.0,
    }


def load(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main(fast: bool = False, smoke: bool = False,
         out_dir: str = "results/dryrun") -> dict:
    bw = kernel_bandwidth(fast=fast, smoke=smoke)
    derived = []
    if glob.glob(os.path.join(out_dir, "*.json")):
        derived = _table(out_dir, "baseline (paper-faithful)")
        if glob.glob("results/dryrun_opt/*.json"):
            _table("results/dryrun_opt", "optimized (EXPERIMENTS.md §Perf)")
    elif not smoke:
        print(f"\n(no dry-run records under {out_dir}/ — derived-terms "
              f"table skipped; run the launch dry-run to populate it)")
    return {"kernel_bandwidth": bw, "derived_terms": derived}


def _table(out_dir: str, label: str) -> list[dict]:
    recs = [r for r in load(out_dir) if r.get("ok")]
    fails = [r for r in load(out_dir) if not r.get("ok")]
    rows = []
    out = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = terms(r)
        hbm = (r.get("argument_size_in_bytes", 0)
               + r.get("temp_size_in_bytes", 0)
               + r.get("output_size_in_bytes", 0)
               - r.get("alias_size_in_bytes", 0))
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            f"{t['compute_s']*1e3:.2f}", f"{t['memory_s']*1e3:.2f}",
            f"{t['collective_s']*1e3:.2f}", t["bound"],
            f"{t['useful_ratio']:.2f}", f"{t['roofline_frac']*100:.1f}%",
            f"{hbm/2**30:.1f}",
        ])
        out.append({**r, **t})
    print_table(
        f"§Roofline [{label}] — per (arch x shape x mesh), per-chip terms",
        ["arch", "shape", "mesh", "compute ms", "memory ms", "coll ms",
         "bound", "6ND/HLO", "roofline", "GiB/chip"],
        rows, widths=[21, 11, 6, 10, 9, 8, 10, 7, 8, 8])
    if fails:
        print(f"\nFAILED cells: "
              f"{[(r['arch'], r['shape'], r['mesh']) for r in fails]}")
    over = [r for r in out
            if (r.get("argument_size_in_bytes", 0)
                + r.get("temp_size_in_bytes", 0)
                - r.get("alias_size_in_bytes", 0)) > HBM_PER_CHIP]
    print(f"\n{len(out)} cells OK; {len(fails)} failed; "
          f"{len(over)} cells exceed 16 GiB/chip (flagged for FSDP/remat)")
    return out


if __name__ == "__main__":
    main()
