"""§Roofline: derive the three roofline terms per (arch x shape x mesh)
from the dry-run records in results/dryrun/*.json.

  compute_s    = dot_flops / PEAK_FLOPS          (per-chip, post-SPMD HLO)
  memory_s     = (traffic - convert) / HBM_BW    (TPU-projected: CPU-backend
                                                  bf16->f32 convert copies
                                                  excluded, see hlo_analysis)
  collective_s = collective_bytes / LINK_BW      (per-chip ICI bytes)

All inputs are PER-CHIP: the dry-run parses the post-SPMD per-device module
and multiplies while-loop bodies by trip counts (XLA's cost_analysis counts
them once).  MODEL_FLOPS uses the 6ND/2ND convention (attention flops
excluded), so ratio > 1 means attention-heavy, < 1 means padding/remat/
redundant compute.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_table
from repro.configs import REGISTRY, SHAPES

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link
HBM_PER_CHIP = 16 << 30  # v5e: 16 GiB


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = REGISTRY[arch]
    sh = SHAPES[shape_name]
    n = cfg.num_active_params
    if sh.kind == "train":
        return 6.0 * n * sh.global_batch * sh.seq_len / devices
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len / devices
    return 2.0 * n * sh.global_batch / devices          # decode: one token


def terms(rec: dict) -> dict:
    flops = rec.get("dot_flops", 0.0)
    traffic = rec.get("traffic_bytes", 0.0) - rec.get("convert_bytes", 0.0)
    coll = rec.get("collective_bytes", 0.0)
    compute_s = flops / PEAK_FLOPS
    memory_s = traffic / HBM_BW
    coll_s = coll / LINK_BW
    bound = max((compute_s, "compute"), (memory_s, "memory"),
                (coll_s, "collective"))[1]
    step_s = max(compute_s, memory_s, coll_s)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["devices"])
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bound": bound, "step_s": step_s,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        # roofline fraction: useful model flops per second vs peak
        "roofline_frac": (mf / step_s) / PEAK_FLOPS if step_s else 0.0,
    }


def load(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main(fast: bool = False, out_dir: str = "results/dryrun") -> list[dict]:
    out = _table(out_dir, "baseline (paper-faithful)")
    if glob.glob("results/dryrun_opt/*.json"):
        _table("results/dryrun_opt", "optimized (EXPERIMENTS.md §Perf)")
    return out


def _table(out_dir: str, label: str) -> list[dict]:
    recs = [r for r in load(out_dir) if r.get("ok")]
    fails = [r for r in load(out_dir) if not r.get("ok")]
    rows = []
    out = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = terms(r)
        hbm = (r.get("argument_size_in_bytes", 0)
               + r.get("temp_size_in_bytes", 0)
               + r.get("output_size_in_bytes", 0)
               - r.get("alias_size_in_bytes", 0))
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            f"{t['compute_s']*1e3:.2f}", f"{t['memory_s']*1e3:.2f}",
            f"{t['collective_s']*1e3:.2f}", t["bound"],
            f"{t['useful_ratio']:.2f}", f"{t['roofline_frac']*100:.1f}%",
            f"{hbm/2**30:.1f}",
        ])
        out.append({**r, **t})
    print_table(
        f"§Roofline [{label}] — per (arch x shape x mesh), per-chip terms",
        ["arch", "shape", "mesh", "compute ms", "memory ms", "coll ms",
         "bound", "6ND/HLO", "roofline", "GiB/chip"],
        rows, widths=[21, 11, 6, 10, 9, 8, 10, 7, 8, 8])
    if fails:
        print(f"\nFAILED cells: "
              f"{[(r['arch'], r['shape'], r['mesh']) for r in fails]}")
    over = [r for r in out
            if (r.get("argument_size_in_bytes", 0)
                + r.get("temp_size_in_bytes", 0)
                - r.get("alias_size_in_bytes", 0)) > HBM_PER_CHIP]
    print(f"\n{len(out)} cells OK; {len(fails)} failed; "
          f"{len(over)} cells exceed 16 GiB/chip (flagged for FSDP/remat)")
    return out


if __name__ == "__main__":
    main()
