"""Shared machinery for the paper-reproduction benchmarks.

Calibration constants map the DES onto the paper's §5 measurements:

* ``GLOBUS_HOP`` (2.4 s each way) reproduces the rate-1 latency gap in
  Fig. 3 (FIRST 9.2 s vs direct 3.0 s median: ~6 s of Globus Compute cloud
  round trip + gateway handling).
* ``DirectServer`` models the backend's own OpenAI HTTP front end (vLLM's
  API server, historically single-threaded — paper §5.3.1 / vllm#12705):
  request admission and response streaming share ONE thread, so under load
  the front end, not the engine, caps throughput.
* ``ExternalAPIModel`` models a commercial API (Fig. 5): low per-request
  latency, client-side rate limiting.
* Engine/instance timing comes from ``repro.serving.costmodel`` for the
  TPU-v5e target (the paper used A100s; DESIGN.md §2 records the swap).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.clock import Future
from repro.core.gateway import GatewayConfig
from repro.serving.costmodel import InstanceCost
from repro.core.instances import ModelInstance, SimRequest
from repro.core.testbed import (GEMMA27B, LLAMA8B, LLAMA70B, build_system,
                                default_deployment, warm_up)
from repro.data.workload import make_workload

GLOBUS_HOP = 2.4            # s, gateway <-> endpoint via the cloud relay
SLOTS = 128                 # engine continuous-batching slots (vLLM's
                            # max_num_seqs default is 256; 128 keeps the
                            # 70B KV cache within one node's HBM)
MFU = 0.5

# 70B deployment used across Fig. 3/4 benchmarks: 1 node, 8 chips (TP=8)
DEP_70B = dict(chips_per_instance=8, nodes_per_instance=1, max_slots=SLOTS,
               mfu=MFU, storage_bw=2e9)
# 8B deployment for Fig. 5: 4 chips (TP=4)
DEP_8B = dict(chips_per_instance=4, nodes_per_instance=1, max_slots=SLOTS,
              mfu=MFU, storage_bw=2e9)


def first_system(model_cfg=LLAMA70B, *, max_instances: int = 1,
                 relay_workers: int | None = None, relay_cpu: float = 0.02,
                 dep_kw: dict | None = None, workers: int = 64):
    """A FIRST deployment as benchmarked in §5.2: one Sophia-like cluster."""
    dep = default_deployment(
        model_cfg, max_instances=max_instances, scale_cooldown=8.0,
        **(dep_kw or DEP_70B))
    sysd = build_system(
        {"sophia": {model_cfg.name: dep}},
        gateway_config=GatewayConfig(workers=workers),
        dispatch_latency=GLOBUS_HOP, startup_delay=20.0,
    )
    if relay_workers:
        from repro.core.compute import _Relay
        sysd.compute.relay = _Relay(sysd.loop, relay_workers, relay_cpu)
    sysd.compute.result_latency = GLOBUS_HOP
    return sysd


class SerialExecutor:
    """N-thread serialized CPU executor on the virtual clock."""

    def __init__(self, loop, threads: int = 1):
        self.loop = loop
        self.threads = threads
        self.busy = 0
        self.queue: list = []

    def submit(self, cost: float, fn):
        self.queue.append((cost, fn))
        self._pump()

    def _pump(self):
        while self.busy < self.threads and self.queue:
            cost, fn = self.queue.pop(0)
            self.busy += 1

            def _run(fn=fn):
                self.busy -= 1
                fn()
                self._pump()

            self.loop.call_after(cost, _run)


@dataclass
class APIServerCost(InstanceCost):
    """Engine cost when the backend's OWN single-threaded API front end
    shares the serving process (the 'vLLM Direct' pathology, vllm#12705):
    every engine step stalls for ``chunk_cpu`` per running sequence while
    the thread detokenizes/streams HTTP chunks, and every admission pays
    ``admit_cpu`` of request handling.  FIRST avoids this tax by invoking
    the engine through pre-registered compute functions — the gateway,
    running elsewhere, absorbs the API work (paper §5.3.1)."""
    admit_cpu: float = 0.004
    chunk_cpu: float = 0.00025

    def decode_step_time(self, batch: int, ctx: int = 1024,
                         steps_per_sync: int = 1) -> float:
        # the HTTP thread detokenizes/streams every token regardless of how
        # the engine batches its device syncs, so chunk_cpu is per token
        return (super().decode_step_time(batch, ctx, steps_per_sync)
                + batch * self.chunk_cpu)

    def prefill_time(self, prompt_tokens: int, batch: int = 1) -> float:
        return super().prefill_time(prompt_tokens, batch) + self.admit_cpu


class DirectServer:
    """'vLLM Direct' scenario: client -> backend's own API server -> engine,
    all on the compute node (no gateway, no FaaS hop)."""

    def __init__(self, loop, scheduler, cost: InstanceCost, *,
                 max_slots: int = SLOTS):
        self.loop = loop
        api_cost = APIServerCost(cfg=cost.cfg, chips=cost.chips,
                                 mfu=cost.mfu, storage_bw=cost.storage_bw)
        self.instance = ModelInstance(
            loop, cost.cfg.name, api_cost, scheduler, max_slots=max_slots,
            idle_timeout=None)
        self.records: list[dict] = []

    def warm(self):
        self.loop.run_until_idle()
        assert self.instance.state.value == "running"

    def submit(self, w) -> Future:
        fut = Future()
        arrival = self.loop.now()
        sreq = SimRequest(request_id=w.request_id,
                          prompt_tokens=w.prompt_tokens,
                          max_tokens=w.max_tokens)

        def on_done(result):
            rec = {"request_id": w.request_id, "arrival": arrival,
                   "finish": self.loop.now(),
                   "output_tokens": result["output_tokens"]}
            self.records.append(rec)
            fut.set_result(rec)

        self.instance.submit(sreq, None, on_done)
        return fut


class ExternalAPIModel:
    """Commercial cloud API (Fig. 5 comparison): per-request latency is low
    and roughly constant, but the provider enforces a request-rate cap; the
    benchmarking client throttles to it (429 backoff), so arrivals are
    shaped to ``rate_limit`` and e2e reflects service latency only."""

    def __init__(self, loop, latency: float = 2.0, rate_limit: float = 6.7):
        self.loop = loop
        self.latency = latency
        self.rate_limit = rate_limit
        self.records: list[dict] = []

    def run(self, workload) -> dict:
        t = 0.0
        for w in workload:
            t += 1.0 / self.rate_limit          # client-side throttle
            start = t

            def _finish(w=w, start=start):
                self.records.append({
                    "request_id": w.request_id, "arrival": start,
                    "finish": self.loop.now(),
                    "output_tokens": w.max_tokens})

            self.loop.call_at(start + self.latency, _finish)
        self.loop.run_until_idle()
        return summarize(self.records)


def summarize(records: list[dict]) -> dict:
    import statistics
    if not records:
        return {"completed": 0}
    start = min(r["arrival"] for r in records)
    end = max(r["finish"] for r in records)
    dur = max(end - start, 1e-9)
    toks = sum(r["output_tokens"] for r in records)
    e2e = sorted(r["finish"] - r["arrival"] for r in records)
    return {"completed": len(records), "duration_s": dur,
            "req_per_s": len(records) / dur, "output_tok_per_s": toks / dur,
            "median_e2e_s": statistics.median(e2e), "output_tokens": toks}


def fmt_row(cols, widths=None):
    widths = widths or [16] * len(cols)
    return " | ".join(str(c)[:w].ljust(w) for c, w in zip(cols, widths))


def print_table(title: str, header: list, rows: list[list], widths=None):
    print(f"\n## {title}")
    print(fmt_row(header, widths))
    print("-|-".join("-" * (widths[i] if widths else 16)
                     for i in range(len(header))))
    for r in rows:
        print(fmt_row(r, widths))


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"CSV,{name},{us_per_call:.3f},{derived}")
