"""QoS scheduling benchmark (real engine, CPU, reduced config).

Interactive latency under a saturating batch-class background flood, for
three scheduling configurations of the SAME engine:

* ``fcfs``            — the legacy single queue: interactive requests sit
                        behind every queued batch request.
* ``priority``        — interactive admits before queued batch work, but
                        still waits for a running batch sequence to free a
                        slot.
* ``priority+preempt``— a blocked interactive arrival evicts a running
                        batch sequence (its pages are published to the
                        prefix cache and freed); the victim restores later
                        by recompute-via-prefix-cache, so its work is not
                        lost.

The flood keeps every slot busy for the whole run, so interactive TTFT
under FCFS measures the batch drain time — the pathology the scheduler
refactor exists to fix. Acceptance (full mode): priority+preempt improves
interactive p99 TTFT by >= 2x over FCFS while keeping total token
throughput within 10%.

Writes ``results/benchmarks/qos_preemption.json`` (smoke/fast runs write
``qos_preemption.fast.json`` and relax the gates for shared CI runners).
``python -m benchmarks.run --only qos_preemption`` or run directly.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import csv_line, print_table
from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

ARCH = "llama3.2-3b"
PAGE = 16
SLOTS = 4
OUT_PATH = os.path.join("results", "benchmarks", "qos_preemption.json")

MODES = [
    ("fcfs", dict(scheduling_policy="fcfs", enable_preemption=False)),
    ("priority", dict(scheduling_policy="priority",
                      enable_preemption=False)),
    ("priority+preempt", dict(scheduling_policy="priority",
                              enable_preemption=True)),
]


def _requests(vocab, *, n_batch, batch_gen, n_interactive, interactive_gen,
              seed=0):
    rng = np.random.default_rng(seed)
    batch = [InferenceRequest(
        model=ARCH, qos="batch",
        prompt_tokens=rng.integers(2, vocab, size=32).tolist(),
        request_id=f"b{i}",
        sampling=SamplingParams(max_tokens=batch_gen, temperature=0.0))
        for i in range(n_batch)]
    interactive = [InferenceRequest(
        model=ARCH, qos="interactive",
        prompt_tokens=rng.integers(2, vocab, size=24).tolist(),
        request_id=f"i{i}",
        sampling=SamplingParams(max_tokens=interactive_gen, temperature=0.0))
        for i in range(n_interactive)]
    return batch, interactive


def _mk_engine(model, params, max_seq, mode_kw):
    # page pool sized at 2x the slot working set so a preempted victim's
    # published pages can PARK in the prefix-cache LRU instead of being
    # evicted by the very admission that displaced it — that headroom is
    # what makes restore-via-prefix-cache near-free; chunked prefill keeps
    # restore prefills from stalling the decode batch (bounded ITL)
    pages_per_seq = -(-max_seq // PAGE)
    cfg = EngineConfig(max_slots=SLOTS, max_seq_len=max_seq,
                       backend="paged", page_size=PAGE,
                       num_pages=2 * SLOTS * pages_per_seq + 1,
                       chunked_prefill_budget=32,
                       enable_prefix_cache=True, **mode_kw)
    return ContinuousBatchingEngine(model, params, cfg)


def _drive(eng, batch, interactive, arrive_every):
    """Batch flood lands at t=0; one interactive request joins every
    ``arrive_every`` engine steps. Returns wall time plus per-class TTFT
    and interactive inter-token delivery gaps (both wall-clock seconds)."""
    import copy
    for r in copy.deepcopy(batch):
        eng.add_request(r)
    pending = list(copy.deepcopy(interactive))
    ttft = {"batch": [], "interactive": []}
    itl = []
    seen: dict[str, int] = {}
    last: dict[str, float] = {}
    total_tokens = 0
    steps = 0
    t0 = time.perf_counter()
    while eng.has_work() or pending:
        # interactive arrivals start only after the flood has saturated
        # the slots (steps > 0), one every ``arrive_every`` steps
        if pending and steps > 0 and steps % arrive_every == 0:
            eng.add_request(pending.pop(0))
        fin = eng.step()
        steps += 1
        now = time.perf_counter()
        live = {rid: (run, len(run.output_tokens))
                for rid, run in eng.running.items()}
        for o in fin:
            run_len = len(o.output_tokens)
            live[o.request_id] = (None, run_len)
            ttft_s = o.metrics.first_token_time - o.metrics.arrival_time
            cls = "interactive" if o.request_id.startswith("i") else "batch"
            ttft[cls].append(ttft_s)
        for rid, (_run, n) in live.items():
            delta = n - seen.get(rid, 0)
            if delta > 0:
                total_tokens += delta
                if rid.startswith("i"):
                    # delivery gaps after the first token (TTFT is its own
                    # metric; ITL should not double-count the queue wait)
                    if rid in last:
                        itl.append(now - last[rid])
                    itl.extend([0.0] * (delta - 1))
                last[rid] = now
                seen[rid] = n
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "steps": steps, "total_tokens": total_tokens,
            "tok_per_s": total_tokens / wall, "ttft": ttft,
            "interactive_itl": itl}


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q) * 1e3)  # -> ms


def _warm_long_prefill(eng, vocab, max_seq):
    """Compile the full-width chunked-prefill shapes at every context-page
    bucket: one long prompt ingested 32 tokens per step walks the chunk
    through all the (chunk=32, ctx bucket) jit combos a cache-missing
    restore can hit mid-measurement."""
    rng = np.random.default_rng(4)
    plen = max_seq - PAGE
    eng.add_request(InferenceRequest(
        model=ARCH, qos="batch",
        prompt_tokens=rng.integers(2, vocab, size=plen).tolist(),
        request_id="warm-long",
        sampling=SamplingParams(max_tokens=2, temperature=0.0)))
    while eng.has_work():
        eng.step()


def _warm_restore_buckets(eng, vocab, batch_gen):
    """Compile every restore-prefill shape the measured pass can hit: the
    chunked-prefill jit specializes per power-of-two context-page bucket,
    and a restore's context grows with the victim's emitted stream — so
    preempt/restore one long sequence each time its history crosses into
    a new bucket (an uncompiled bucket would otherwise land a multi-second
    compile in the middle of the measured pass)."""
    rng = np.random.default_rng(3)
    req = InferenceRequest(
        model=ARCH, qos="batch",
        prompt_tokens=rng.integers(2, vocab, size=32).tolist(),
        request_id="warm-restore",
        sampling=SamplingParams(max_tokens=batch_gen, temperature=0.0))
    eng.add_request(req)
    seen_buckets = set()
    while eng.has_work():
        eng.step()
        run = eng.running.get("warm-restore")
        if run is None:
            continue
        pages = -(-run.cache_len // eng.cfg.page_size)
        bucket = 1
        while bucket < pages:
            bucket *= 2
        if bucket not in seen_buckets and run.cache_len > eng.cfg.page_size:
            seen_buckets.add(bucket)
            eng.preempt("warm-restore")


def bench(model, params, vocab, *, n_batch, batch_gen, n_interactive,
          interactive_gen, arrive_every):
    max_seq = 32 + batch_gen + PAGE
    results, rows = [], []
    engines, counters = {}, {}
    for name, mode_kw in MODES:
        eng = _mk_engine(model, params, max_seq, mode_kw)
        # warmup ON THE MEASURED ENGINE (jit caches live per backend
        # instance): same generation lengths and arrival cadence so every
        # prefill/restore ctx bucket this mode will hit is compiled,
        # including the restore-prefill shapes preemption adds
        wb, wi = _requests(vocab, n_batch=SLOTS, batch_gen=batch_gen,
                           n_interactive=2,
                           interactive_gen=interactive_gen, seed=1)
        _drive(eng, wb, wi, arrive_every)
        _warm_long_prefill(eng, vocab, max_seq)
        if mode_kw.get("enable_preemption"):
            _warm_restore_buckets(eng, vocab, batch_gen)
        engines[name] = eng
        counters[name] = dict(eng.stats)     # exclude warmup from counters
    b, i = _requests(vocab, n_batch=n_batch, batch_gen=batch_gen,
                     n_interactive=n_interactive,
                     interactive_gen=interactive_gen, seed=2)
    # best of four passes, ROUND-ROBIN across modes: shared-host
    # contention drifts on a seconds scale, so running each mode's passes
    # back-to-back would charge whole modes differently — interleaving
    # spreads the drift evenly and the per-mode best compares like to like
    passes = 4
    best: dict[str, dict] = {}
    for _ in range(passes):
        for name, eng in engines.items():
            r = _drive(eng, b, i, arrive_every)
            if name not in best or r["tok_per_s"] > best[name]["tok_per_s"]:
                best[name] = r
    for name, mode_kw in MODES:
        eng = engines[name]
        r = best[name]
        r["mode"] = name
        for k in ("preemptions", "restores", "restore_cached_tokens"):
            r[k] = (eng.stats[k] - counters[name][k]) // passes
        ti = r["ttft"]["interactive"]
        r["interactive"] = {
            "p50_ttft_ms": _pct(ti, 50), "p99_ttft_ms": _pct(ti, 99),
            "p50_itl_ms": _pct(r["interactive_itl"], 50),
            "p99_itl_ms": _pct(r["interactive_itl"], 99)}
        r["batch_p50_ttft_ms"] = _pct(r["ttft"]["batch"], 50)
        del r["ttft"], r["interactive_itl"]
        results.append(r)
        rows.append([name, f"{r['interactive']['p50_ttft_ms']:.0f}",
                     f"{r['interactive']['p99_ttft_ms']:.0f}",
                     f"{r['interactive']['p99_itl_ms']:.1f}",
                     f"{r['tok_per_s']:.0f}", r["preemptions"]])
        csv_line(f"qos_preemption/{name}",
                 r["interactive"]["p99_ttft_ms"] * 1e3,
                 f"tok_s={r['tok_per_s']:.0f}")
    print_table(
        f"QoS under batch flood ({ARCH} reduced, B={SLOTS}, "
        f"{n_batch}x{batch_gen} batch vs {n_interactive}x{interactive_gen} "
        f"interactive)",
        ["mode", "int p50 TTFT ms", "int p99 TTFT ms", "int p99 ITL ms",
         "total tok/s", "preempts"],
        rows, widths=[18, 15, 15, 14, 12, 8])
    return results


def main(fast: bool = False, smoke: bool = False) -> dict:
    cfg = reduced(REGISTRY[ARCH])
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if smoke or fast:
        kw = dict(n_batch=6, batch_gen=48, n_interactive=3,
                  interactive_gen=8, arrive_every=6)
    else:
        kw = dict(n_batch=8, batch_gen=192, n_interactive=8,
                  interactive_gen=10, arrive_every=12)
    results = bench(model, params, cfg.vocab_size, **kw)
    by = {r["mode"]: r for r in results}
    pre = by["priority+preempt"]
    fcfs = by["fcfs"]
    ttft_speedup = (fcfs["interactive"]["p99_ttft_ms"]
                    / pre["interactive"]["p99_ttft_ms"])
    thpt_ratio = pre["tok_per_s"] / fcfs["tok_per_s"]
    out = {"arch": ARCH, "batch_slots": SLOTS, "page_size": PAGE, **kw,
           "modes": results,
           "p99_ttft_speedup_preempt_vs_fcfs": ttft_speedup,
           "throughput_ratio_preempt_vs_fcfs": thpt_ratio}
    path = OUT_PATH.replace(".json", ".fast.json") if (fast or smoke) \
        else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {path}  (interactive p99 TTFT: preempt "
          f"{ttft_speedup:.1f}x better than FCFS; throughput ratio "
          f"{thpt_ratio:.2f})")
    # acceptance: the 2x / within-10% claims hold for the committed
    # full-mode artifact; reduced smoke runs keep headroom for loaded
    # shared CI runners (shorter floods leave preemption less to win)
    ttft_floor = 1.3 if (smoke or fast) else 2.0
    thpt_floor = 0.7 if (smoke or fast) else 0.9
    if ttft_speedup < ttft_floor:
        raise SystemExit(
            f"preemption interactive p99 TTFT speedup is "
            f"{ttft_speedup:.2f}x (expected >= {ttft_floor}x)")
    if thpt_ratio < thpt_floor:
        raise SystemExit(
            f"preemption cut total throughput to {thpt_ratio:.2f}x of "
            f"FCFS (floor {thpt_floor}x)")
    if pre["preemptions"] < 1:
        raise SystemExit("preemption mode never actually preempted")
    return out


if __name__ == "__main__":
    main()
