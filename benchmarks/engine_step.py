"""Real-engine microbenchmark (CPU, reduced configs): wall-clock per
continuous-batching engine step for the slots vs paged KV backends, and
prefill/decode token throughput.  This is the substrate the DES calibrates
against; on TPU the same engine runs the full-size models.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_line, print_table
from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

ARCHS = ["llama3.2-3b", "phi3.5-moe-42b-a6.6b", "mamba2-130m"]


def bench(arch: str, backend: str, *, slots: int = 8, n_req: int = 16,
          prompt_len: int = 32, gen: int = 16) -> dict:
    cfg = reduced(REGISTRY[arch])
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def load(eng):
        for i in range(n_req):
            toks = rng.integers(2, cfg.vocab_size,
                                size=prompt_len).tolist()
            eng.add_request(InferenceRequest(
                model=arch, prompt_tokens=toks, request_id=f"r{i}",
                sampling=SamplingParams(max_tokens=gen, temperature=0.0)))

    ecfg = EngineConfig(max_slots=slots, max_seq_len=prompt_len + gen + 8,
                        backend=backend, page_size=16)
    eng = ContinuousBatchingEngine(model, params, ecfg)
    load(eng)
    eng.step()                      # warmup (jit compile)
    t0 = time.perf_counter()
    outs = eng.run_to_completion()
    dt = time.perf_counter() - t0
    steps = eng.stats["steps"] - 1
    toks = eng.stats["decode_tokens"] + eng.stats["prefill_tokens"]
    return {"arch": arch, "backend": backend, "steps": steps,
            "s_per_step": dt / max(steps, 1), "tok_per_s": toks / dt,
            "finished": len(outs) + eng.stats["finished"]}


def main(fast: bool = False) -> list[dict]:
    archs = ARCHS[:2] if fast else ARCHS
    rows, out = [], []
    for arch in archs:
        backends = ["slots"] if REGISTRY[arch].family in ("ssm", "hybrid") \
            else ["slots", "paged"]
        for be in backends:
            r = bench(arch, be)
            rows.append([arch, be, r["steps"],
                         f"{r['s_per_step']*1e3:.1f}",
                         f"{r['tok_per_s']:.0f}"])
            out.append(r)
            csv_line(f"engine_step/{arch}/{be}", r["s_per_step"] * 1e6,
                     f"tok_s={r['tok_per_s']:.0f}")
    print_table("Engine microbench (reduced configs, CPU)",
                ["arch", "backend", "steps", "ms/step", "tok/s"],
                rows, widths=[22, 7, 6, 8, 8])
    return out


if __name__ == "__main__":
    main()
