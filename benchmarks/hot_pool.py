"""Hot-node pools + disaggregated prefill/decode serving on a replay trace.

Part 1 — hot pool vs cold-start-on-demand. The SAME bursty diurnal trace
(Poisson bursts separated by dead gaps, the arrival shape of §3.2's
interactive science workloads) is replayed against two single-cluster
policies:

  * cold  — no floor, short idle timeout: the instance releases in every
    gap and each burst front pays the full cold start (job startup +
    weight load), exactly the on-demand behavior hot pools exist to fix;
  * hot   — ``min_hot=1`` + a keepalive that outlives the gaps: the pool
    pins one warm instance through the lulls.

Acceptance gates (CI runs this in ``--smoke``; all virtual-clock
deterministic):
  * interactive p99 TTFT improves >= 5x under the hot pool;
  * the hot pool's node-hours stay <= 1.2x the demand-matched cold
    baseline (warm capacity is cheap on this trace, not free);
  * every request completes in both runs.

Part 2 — disaggregated roles. A prefill-heavy pool on one cluster hands
every sequence to a decode-heavy pool on a second cluster after the first
token (KV transfer priced by ``InstanceCost.handoff_time``; admission on
the decode side goes through the restore machinery). Gates: token
conservation — every request still produces exactly ``max_tokens``, the
two engines' output counters partition the total, handoffs out == in with
zero fallbacks, and the decode engine restored one carried token per
request.
"""
from __future__ import annotations

import json
import os

from repro.core.scheduler import JobState
from repro.core.testbed import LLAMA8B, build_system, default_deployment
from repro.data.workload import make_bursty_workload

from benchmarks.common import csv_line, print_table

MODEL = LLAMA8B.name
SEED = 42
GAP = 50.0          # s of silence between bursts
RATE = 4.0          # req/s inside a burst
LEAD = 40.0         # s before the first burst (lets the pool pre-warm)
IDLE_TIMEOUT = 35.0  # cold policy: release after 35 s idle (< GAP)
KEEPALIVE = 300.0   # hot policy: outlives every gap (> GAP)

TTFT_SPEEDUP_GATE = 5.0
NODE_HOURS_GATE = 1.2


def _mk(policy: str):
    kw = dict(max_slots=48, max_instances=1, storage_bw=2e9)
    if policy == "cold":
        dep = default_deployment(LLAMA8B, idle_timeout=IDLE_TIMEOUT, **kw)
    else:
        dep = default_deployment(LLAMA8B, min_hot=1, keepalive=KEEPALIVE,
                                 **kw)
    return build_system({"sophia": {MODEL: dep}})


def _replay(policy: str, wl):
    sysd = _mk(policy)
    token = sysd.token_for("bench")
    futs = {}
    for w in wl:
        sysd.loop.call_at(w.arrival + LEAD, lambda w=w: futs.__setitem__(
            w.request_id, sysd.gateway.submit(token, {
                "request_id": w.request_id, "model": MODEL,
                "prompt_tokens": w.prompt_tokens,
                "max_tokens": w.max_tokens})))
    sysd.loop.run_until_idle()
    t_end = sysd.loop.now()

    errors = sum(1 for f in futs.values() if f.error is not None)
    ttfts = sorted(r.ttft for r in sysd.metrics.records)
    p99 = ttfts[int(0.99 * (len(ttfts) - 1))] if ttfts else 0.0

    # node-hours over the trace window [first arrival, last completion]:
    # the pool's pre-warm lead is provisioning, not steady-state serving
    node_s = 0.0
    for sched in sysd.schedulers.values():
        for job in sched.jobs.values():
            if job.state == JobState.QUEUED:
                continue
            end = (job.end_time
                   if job.state in (JobState.ENDED, JobState.FAILED)
                   else t_end)
            node_s += max(0.0, min(end, t_end)
                          - max(job.start_time, LEAD)) * job.num_nodes
    spawns = sum(1 for sched in sysd.schedulers.values()
                 for job in sched.jobs.values()
                 if job.state != JobState.QUEUED)
    return {"n": len(futs), "errors": errors, "p99_ttft_s": p99,
            "median_ttft_s": ttfts[len(ttfts) // 2] if ttfts else 0.0,
            "node_hours": node_s / 3600.0, "spawns": spawns,
            "horizon_s": t_end}


def _disagg(n: int):
    """Prefill-heavy pool on sophia, decode-heavy on polaris; every
    sequence moves after its first token."""
    kw = dict(max_slots=48, storage_bw=40e9, min_hot=1, keepalive=1e9)
    deps = {
        "sophia": {MODEL: default_deployment(LLAMA8B, role="prefill-heavy",
                                             **kw)},
        "polaris": {MODEL: default_deployment(LLAMA8B, role="decode-heavy",
                                              **kw)},
    }
    sysd = build_system(deps)
    sysd.loop.run_until(60.0)          # both pool floors warm
    token = sysd.token_for("bench")
    wl = make_bursty_workload(n_bursts=1, burst_n=n, rate=RATE, gap=0.0,
                              seed=SEED, prefix="d")
    futs = {}
    for w in wl:
        sysd.loop.call_at(w.arrival + sysd.loop.now(),
                          lambda w=w: futs.__setitem__(
                              w.request_id, sysd.gateway.submit(token, {
                                  "request_id": w.request_id,
                                  "model": MODEL,
                                  "prompt_tokens": w.prompt_tokens,
                                  "max_tokens": w.max_tokens})))
    sysd.loop.run_until_idle()

    want = {w.request_id: w.max_tokens for w in wl}
    ep_p = sysd.endpoints["sophia-ep"]
    ep_d = sysd.endpoints["polaris-ep"]
    eng_p = ep_p.instances[MODEL][0].engine
    eng_d = ep_d.instances[MODEL][0].engine
    short = sum(1 for rid, f in futs.items()
                if f.error is not None
                or f.result()["output_tokens"] != want[rid])
    return {
        "n": n,
        "short_or_errored": short,
        "total_tokens_wanted": sum(want.values()),
        "prefill_tokens": eng_p.total_output_tokens,
        "decode_tokens": eng_d.total_output_tokens,
        "handoffs_out": ep_p.stats["handoffs_out"],
        "handoffs_in": ep_d.stats["handoffs_in"],
        "handoff_fallbacks": ep_p.stats["handoff_fallbacks"],
        "decode_restored_tokens": eng_d.total_resumed_tokens,
    }


def main(fast: bool = False, smoke: bool = False) -> dict:
    small = fast or smoke
    n_bursts, burst_n, n_disagg = (3, 24, 16) if small else (6, 80, 60)
    wl = make_bursty_workload(n_bursts=n_bursts, burst_n=burst_n, rate=RATE,
                              gap=GAP, seed=SEED)

    cold = _replay("cold", wl)
    hot = _replay("hot", wl)
    dis = _disagg(n_disagg)

    ttft_ratio = cold["p99_ttft_s"] / max(hot["p99_ttft_s"], 1e-9)
    node_ratio = hot["node_hours"] / max(cold["node_hours"], 1e-9)

    failures = []
    if cold["errors"] or hot["errors"]:
        failures.append(f"errors: cold={cold['errors']} hot={hot['errors']}")
    if ttft_ratio < TTFT_SPEEDUP_GATE:
        failures.append(
            f"p99 TTFT speedup {ttft_ratio:.1f}x < {TTFT_SPEEDUP_GATE}x "
            f"(cold {cold['p99_ttft_s']:.2f}s, hot {hot['p99_ttft_s']:.2f}s)")
    if node_ratio > NODE_HOURS_GATE:
        failures.append(
            f"hot pool node-hours {node_ratio:.2f}x cold baseline "
            f"(> {NODE_HOURS_GATE}x)")
    if dis["short_or_errored"]:
        failures.append(f"{dis['short_or_errored']} disaggregated requests "
                        "lost tokens or errored")
    if dis["prefill_tokens"] != dis["n"]:
        failures.append(f"prefill engine produced {dis['prefill_tokens']} "
                        f"tokens, want one first token x {dis['n']}")
    if dis["prefill_tokens"] + dis["decode_tokens"] \
            != dis["total_tokens_wanted"]:
        failures.append(
            f"engines emitted {dis['prefill_tokens'] + dis['decode_tokens']}"
            f" tokens, trace wants {dis['total_tokens_wanted']} "
            "(handoff lost or duplicated tokens)")
    if not (dis["handoffs_out"] == dis["handoffs_in"] == dis["n"]):
        failures.append(f"handoffs out={dis['handoffs_out']} "
                        f"in={dis['handoffs_in']}, want {dis['n']} each")
    if dis["handoff_fallbacks"]:
        failures.append(f"{dis['handoff_fallbacks']} handoffs fell back "
                        "to local decode with a healthy decode pool up")
    if dis["decode_restored_tokens"] != dis["n"]:
        failures.append(f"decode engine restored "
                        f"{dis['decode_restored_tokens']} carried tokens, "
                        f"want {dis['n']}")

    rows = [
        ["trace", f"{n_bursts}x{burst_n} reqs",
         f"{RATE:g}/s bursts, {GAP:g}s gaps"],
        ["cold p99 TTFT", f"{cold['p99_ttft_s']:.2f}s",
         f"{cold['spawns']} spawns (one per burst)"],
        ["hot p99 TTFT", f"{hot['p99_ttft_s']:.2f}s",
         f"{hot['spawns']} spawn (pool floor)"],
        ["TTFT speedup", f"{ttft_ratio:.1f}x",
         f">= {TTFT_SPEEDUP_GATE:g}x gate"],
        ["node-hours", f"{hot['node_hours']:.3f}",
         f"{node_ratio:.2f}x cold ({cold['node_hours']:.3f}), "
         f"<= {NODE_HOURS_GATE:g}x gate"],
        ["handoffs", f"{dis['handoffs_out']}/{dis['n']}",
         f"{dis['handoff_fallbacks']} fallbacks"],
        ["token split", f"{dis['prefill_tokens']}+{dis['decode_tokens']}",
         f"= {dis['total_tokens_wanted']} wanted"],
        ["gates", "ok" if not failures else "FAILED", ""],
    ]
    print_table("hot pools + disaggregated prefill/decode (DES, Llama-8B)",
                ["metric", "value", "note"], rows, widths=[16, 14, 38])

    out = {
        "trace": {"n_bursts": n_bursts, "burst_n": burst_n, "rate": RATE,
                  "gap_s": GAP, "seed": SEED},
        "cold": cold,
        "hot": hot,
        "ttft_p99_speedup": round(ttft_ratio, 3),
        "node_hours_ratio": round(node_ratio, 3),
        "disaggregated": dis,
        "gates_ok": not failures,
        "gate_failures": failures,
    }
    csv_line("hot_pool/gates", 0.0,
             f"ttft_speedup={ttft_ratio:.1f}x;node_hours={node_ratio:.2f}x;"
             f"handoffs={dis['handoffs_out']}")

    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "benchmarks",
                        f"hot_pool{'.fast' if small else ''}.json")
    with open(os.path.abspath(path), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.abspath(path)}")

    if failures:
        raise SystemExit("GATE FAILED:\n  " + "\n  ".join(failures))
    print("hot_pool gates passed")
    return out


if __name__ == "__main__":
    main()
