"""§5.3.1 batch-mode reproduction: online serving vs the dedicated offline
batch job (paper §4.4), Llama-70B.

Paper claims: batch mode reached 2117 tok/s vs 1432 tok/s online for a
1000-request job (409 s end to end), with cold-start amortization making
>=10k-request jobs 'highly efficient' (25k tok/s/model in the §6.3 case
study, on multiple instances).
"""
from __future__ import annotations

from benchmarks.common import (LLAMA70B, csv_line, first_system,
                               make_workload, print_table, warm_up)
from repro.core.testbed import drive_workload

SIZES = [100, 1000, 10_000]


def run_online(n: int) -> dict:
    sysd = first_system(LLAMA70B)
    warm_up(sysd, LLAMA70B.name)
    wl = make_workload(n, rate=float("inf"), seed=9)
    return drive_workload(sysd, wl, LLAMA70B.name)


def run_batch(n: int) -> dict:
    sysd = first_system(LLAMA70B)
    wl = make_workload(n, rate=float("inf"), seed=9)
    reqs = [{"request_id": w.request_id, "prompt_tokens": w.prompt_tokens,
             "max_tokens": w.max_tokens} for w in wl]
    job = sysd.batch.submit_batch(LLAMA70B.name, reqs)
    sysd.loop.run_until_idle()
    st = job.status()
    dur = job.finish_time - job.submit_time
    work = job.finish_time - job.start_time if job.start_time else dur
    return {"completed": st["completed"], "duration_s": dur,
            "output_tokens": st["output_tokens"],
            "output_tok_per_s": st["output_tokens"] / dur,
            "tok_per_s_hot": st["output_tokens"] / max(work, 1e-9),
            "cold_start_s": dur - work}


def main(fast: bool = False) -> dict:
    sizes = [100, 1000] if fast else SIZES
    rows, out = [], {}
    online = run_online(1000 if not fast else 300)
    rows.append(["online (hot)", online["completed"],
                 f"{online['output_tok_per_s']:.0f}", "-",
                 f"{online['duration_s']:.0f}", "-"])
    out["online"] = online
    for n in sizes:
        b = run_batch(n)
        rows.append([f"batch {n}", b["completed"],
                     f"{b['output_tok_per_s']:.0f}",
                     f"{b['tok_per_s_hot']:.0f}",
                     f"{b['duration_s']:.0f}", f"{b['cold_start_s']:.0f}"])
        out[f"batch_{n}"] = b
        csv_line(f"batch_mode/{n}", 0.0,
                 f"tok_s={b['output_tok_per_s']:.0f};"
                 f"hot_tok_s={b['tok_per_s_hot']:.0f}")
    print_table("§5.3.1 — online vs batch mode (Llama-70B)",
                ["scenario", "done", "tok/s e2e", "tok/s hot", "duration s",
                 "cold s"],
                rows, widths=[12, 6, 9, 9, 10, 7])
    big = out.get("batch_10000") or out[f"batch_{sizes[-1]}"]
    print(f"\ncheck: batch(hot) {big['tok_per_s_hot']:.0f} tok/s > online "
          f"{online['output_tok_per_s']:.0f} tok/s; cold start amortized "
          f"{out[f'batch_{sizes[0]}']['cold_start_s']:.0f}s over "
          f"{sizes[0]} vs {sizes[-1]} reqs "
          f"({out[f'batch_{sizes[0]}']['output_tok_per_s']:.0f} -> "
          f"{big['output_tok_per_s']:.0f} tok/s e2e)")
    return out


if __name__ == "__main__":
    main()
