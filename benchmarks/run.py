"""Benchmark orchestrator: one harness per paper table/figure.

  rate_sweep    Fig. 3  FIRST vs vLLM-Direct across request rates
  autoscale     Fig. 4  1->4 instance scaling under saturation
  external_api  Fig. 5  FIRST (8B) vs rate-limited external API
  concurrency   Tbl. 1  WebUI closed-loop session sweep
  batch_mode    §5.3.1  online vs dedicated offline batch job
  engine_step   (real)  CPU wall-clock of the JAX engine, reduced configs
  prefix_cache  (real)  KV prefix reuse + chunked-prefill ITL, JSON output
  roofline      §Roofline  terms from results/dryrun/*.json

``python -m benchmarks.run [--fast] [--only NAME]``.  Machine-readable
lines are prefixed ``CSV,name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (autoscale, batch_mode, concurrency, engine_step,
                        external_api, prefix_cache, rate_sweep, roofline)

SUITES = {
    "rate_sweep": rate_sweep.main,
    "autoscale": autoscale.main,
    "external_api": external_api.main,
    "concurrency": concurrency.main,
    "batch_mode": batch_mode.main,
    "engine_step": engine_step.main,
    "prefix_cache": prefix_cache.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced request counts / fewer cells")
    ap.add_argument("--only", default=None, choices=[*SUITES, None])
    args = ap.parse_args()

    names = [args.only] if args.only else list(SUITES)
    failures = []
    for name in names:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            SUITES[name](fast=args.fast)
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except Exception:                       # noqa: BLE001
            failures.append(name)
            print(f"[{name}] FAILED:\n{traceback.format_exc()}")
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")
    print("\nall benchmark suites passed")


if __name__ == "__main__":
    main()
