"""Benchmark orchestrator: one harness per paper table/figure.

  rate_sweep    Fig. 3  FIRST vs vLLM-Direct across request rates
  autoscale     Fig. 4  1->4 instance scaling under saturation
  external_api  Fig. 5  FIRST (8B) vs rate-limited external API
  concurrency   Tbl. 1  WebUI closed-loop session sweep
  batch_mode    §5.3.1  online vs dedicated offline batch job
  engine_step   (real)  CPU wall-clock of the JAX engine, reduced configs
  prefix_cache  (real)  KV prefix reuse + chunked-prefill ITL, JSON output
  decode_loop   (real)  fused decode fast path vs legacy, JSON output
  spec_decode   (real)  draft-and-verify speculative decoding, JSON output
  qos_preemption (real) interactive TTFT under a batch flood: FCFS vs
                        priority vs priority+preemption, JSON output
  api_stream    (DES)   /v1 token streaming at the gateway: parity,
                        TTFT/ITL, cancel propagation, JSON output
  tp_decode     (real)  tensor-parallel fused decode on a simulated
                        4-shard mesh: token parity + throughput ratio,
                        JSON output
  chaos_soak    (DES)   seeded fault schedule against the federation:
                        exactly-once conservation, mid-stream failover
                        resume, bounded TTFT inflation, JSON output
  hot_pool      (DES)   hot-node pool vs cold-start-on-demand on a bursty
                        replay trace, plus disaggregated prefill/decode
                        handoff token conservation, JSON output
  roofline      §Roofline  achieved-vs-peak bandwidth for the serving
                        attention ops (JSON output), plus derived terms
                        from results/dryrun/*.json when present

``python -m benchmarks.run [--fast] [--smoke] [--only NAME]``.
``--smoke`` runs only the real-engine perf-path suites at minimal sizes
with their acceptance gates on — the CI regression check.  Machine-readable
lines are prefixed ``CSV,name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (api_stream, autoscale, batch_mode, chaos_soak,
                        concurrency, decode_loop, engine_step, external_api,
                        hot_pool, prefix_cache, qos_preemption, rate_sweep,
                        roofline, spec_decode, tp_decode)

SUITES = {
    "rate_sweep": rate_sweep.main,
    "autoscale": autoscale.main,
    "external_api": external_api.main,
    "concurrency": concurrency.main,
    "batch_mode": batch_mode.main,
    "engine_step": engine_step.main,
    "prefix_cache": prefix_cache.main,
    "decode_loop": decode_loop.main,
    "spec_decode": spec_decode.main,
    "qos_preemption": qos_preemption.main,
    "api_stream": api_stream.main,
    "tp_decode": tp_decode.main,
    "chaos_soak": chaos_soak.main,
    "hot_pool": hot_pool.main,
    "roofline": roofline.main,
}

# real-engine suites with self-enforced acceptance thresholds: these are
# the ones a perf-path regression breaks, so CI runs exactly these
SMOKE_SUITES = ["engine_step", "prefix_cache", "decode_loop", "spec_decode",
                "qos_preemption", "api_stream", "tp_decode", "chaos_soak",
                "hot_pool", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced request counts / fewer cells")
    ap.add_argument("--smoke", action="store_true",
                    help="perf-path regression check: real-engine suites "
                         "only, minimal sizes (implies --fast)")
    ap.add_argument("--only", default=None, choices=[*SUITES, None])
    args = ap.parse_args()

    if args.only:
        names = [args.only]
    elif args.smoke:
        names = list(SMOKE_SUITES)
    else:
        names = list(SUITES)
    failures = []
    for name in names:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t0 = time.time()
        kw = {"fast": args.fast or args.smoke}
        if args.smoke and name in ("decode_loop", "spec_decode",
                                   "qos_preemption", "api_stream",
                                   "tp_decode", "chaos_soak", "hot_pool",
                                   "roofline"):
            kw["smoke"] = True
        if args.smoke and name == "prefix_cache":
            kw["min_speedup"] = 1.5     # shared-runner wall-clock headroom
        try:
            SUITES[name](**kw)
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except (Exception, SystemExit):         # noqa: BLE001
            # acceptance gates signal via SystemExit — catch it so one
            # failed gate still lets the remaining suites run and the
            # failure summary aggregate
            failures.append(name)
            print(f"[{name}] FAILED:\n{traceback.format_exc()}")
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")
    print("\nall benchmark suites passed")


if __name__ == "__main__":
    main()
