"""Decode fast-path benchmark (real engine, CPU, reduced config).

Steady-state decode throughput and inter-token latency for the legacy
host-driven decode path vs the fused device-resident path at
``decode_steps_per_sync`` (K) in {1, 4, 16}. The legacy path ships the full
``(max_slots, V)`` logits to the host and re-dispatches a sampling call
every token; the fused path runs decode+sample+stop checks in one donated
jitted call and, at K>1, loops K steps on device per host sync — so its
per-token cost is dominated by the model step, not transfers/dispatch.

Inter-token latency is measured at token *delivery*: with K>1 tokens
surface in bursts (intra-burst gap 0, inter-burst gap = the sync period),
so the p99 column makes the throughput/latency trade explicit.

A third mode stacks ``use_kernel=True`` on the fused loop at K=16: the
decode step stops re-gathering unchanged pages every token (the gathered
context view is cached across the K-step window and only the in-window
tail KV rides in small dense buffers; on TPU the Pallas decode-tail
kernel reads the pages directly and the view disappears entirely). The
saved work scales with context length, so the kernel-vs-reference gate
runs on a long-context pair (prompt 256) where re-gather dominates; the
short-prompt kernel row is recorded ungated for the identity matrix.

Greedy outputs are asserted token-identical across every mode — the fast
path must be an optimization, not a different sampler.

All gates are *ratios* between modes measured in the same process on the
same host (contended-CPU noise convention) — never absolute tok/s.

Writes ``results/benchmarks/decode_loop.json``.
``python -m benchmarks.run --only decode_loop`` or run this module
directly; ``--smoke`` (via ``benchmarks.run``) shrinks the workload and
relaxes the speedup gate for CI.
"""
from __future__ import annotations

import copy
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import csv_line, print_table
from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving import backends
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

ARCH = "llama3.2-3b"
PAGE = 32
PROMPT_LEN = 32
LONG_PROMPT = 256      # kernel-gate workload: re-gather cost ~ context
SLOTS = 4
OUT_PATH = os.path.join("results", "benchmarks", "decode_loop.json")


def _requests(vocab, n, gen, seed=0, plen=PROMPT_LEN):
    rng = np.random.default_rng(seed)
    return [InferenceRequest(
        model=ARCH,
        prompt_tokens=rng.integers(2, vocab, size=plen).tolist(),
        request_id=f"r{i}",
        sampling=SamplingParams(max_tokens=gen, temperature=0.0))
        for i in range(n)]


def _mk_engine(model, params, gen, *, fused, K, use_kernel=False,
               plen=PROMPT_LEN):
    cfg = EngineConfig(
        max_slots=SLOTS, max_seq_len=plen + gen + PAGE,
        backend="paged", page_size=PAGE, fused_decode=fused,
        decode_steps_per_sync=K, use_kernel=use_kernel)
    return ContinuousBatchingEngine(model, params, cfg)


def _timed_pass(eng, reqs):
    """Drive one full workload, recording per-token delivery gaps and the
    per-step token rate. ``steady_tok_per_s`` is the median per-step rate —
    robust to a contention spike hitting one step of one mode's pass on a
    shared host, which total wall clock is not — and is the 'steady-state
    decode tok/s' the acceptance gate compares."""
    for r in copy.deepcopy(reqs):
        eng.add_request(r)
    outputs = {}
    seen: dict[str, int] = {}
    last: dict[str, float] = {}
    gaps: list[float] = []
    rates: list[float] = []
    dec0 = eng.stats["decode_tokens"]
    sync0 = eng.stats["decode_syncs"]
    t0 = time.perf_counter()
    prev = t0
    while eng.has_work():
        fin = eng.step()
        now = time.perf_counter()
        live = {rid: len(run.output_tokens)
                for rid, run in eng.running.items()}
        for o in fin:
            live[o.request_id] = len(o.output_tokens)
            outputs[o.request_id] = list(o.output_tokens)
        step_tokens = 0
        for rid, n in live.items():
            delta = n - seen.get(rid, 0)
            if delta > 0:
                step_tokens += delta
                gaps.append(now - last.get(rid, t0))   # burst head gap
                gaps.extend([0.0] * (delta - 1))       # rest arrive together
                last[rid] = now
                seen[rid] = n
        if step_tokens:
            rates.append(step_tokens / (now - prev))
        prev = now
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "decode_tokens": eng.stats["decode_tokens"] - dec0,
        "decode_syncs": eng.stats["decode_syncs"] - sync0,
        "tok_per_s": (eng.stats["decode_tokens"] - dec0) / wall,
        "steady_tok_per_s": float(np.median(rates)),
        "p50_itl_ms": float(np.percentile(gaps, 50) * 1e3),
        "p99_itl_ms": float(np.percentile(gaps, 99) * 1e3),
        "outputs": outputs,
    }


def _run_modes(model, params, vocab, *, gen, plen, modes):
    reqs = _requests(vocab, SLOTS, gen, seed=2, plen=plen)
    results, rows = [], []
    for name, fused, k, use_kernel in modes:
        eng = _mk_engine(model, params, gen, fused=fused, K=k,
                         use_kernel=use_kernel, plen=plen)
        # warmup: compiles every jit bucket this mode will hit
        _timed_pass(eng, _requests(vocab, SLOTS, gen, seed=1, plen=plen))
        backends.reset_transfer_stats()
        # best of three passes: on a small shared host, contention can sit
        # on one mode's whole pass and would skew the ratios. The identity
        # assertion below always compares pass-1 outputs (greedy decode is
        # deterministic, so later passes produce the same tokens).
        r = _timed_pass(eng, reqs)
        transfers = backends.TRANSFER_STATS["decode_logits_transfers"]
        for _ in range(2):
            r2 = _timed_pass(eng, reqs)
            if r2["steady_tok_per_s"] > r["steady_tok_per_s"]:
                r2["outputs"] = r["outputs"]
                r = r2
        r["mode"], r["K"], r["prompt_len"] = name, k, plen
        r["logits_transfers"] = transfers     # per pass (deterministic)
        if fused:
            assert r["logits_transfers"] == 0, \
                "fused path transferred logits to host"
        results.append(r)
        rows.append([f"{name} K={k}", f"{r['steady_tok_per_s']:.0f}",
                     f"{r['p50_itl_ms']:.2f}", f"{r['p99_itl_ms']:.2f}",
                     r["decode_syncs"], r["logits_transfers"]])
        csv_line(f"decode_loop/{name}_K{k}", r["wall_s"] * 1e6 / max(
            r["decode_tokens"], 1), f"tok_s={r['steady_tok_per_s']:.0f}")
    return results, rows


def bench(model, params, vocab, *, gen, ks):
    modes = ([("legacy", False, 1, False)]
             + [("fused", True, k, False) for k in ks]
             + [("kernel", True, max(ks), True)])
    results, rows = _run_modes(model, params, vocab, gen=gen,
                               plen=PROMPT_LEN, modes=modes)
    base = results[0]["outputs"]
    for r in results[1:]:
        assert r["outputs"] == base, \
            f"{r['mode']} K={r['K']} outputs diverged from legacy"
    print_table(
        f"Decode fast path ({ARCH} reduced, B={SLOTS}, {gen} gen tokens)",
        ["mode", "steady tok/s", "p50 ITL ms", "p99 ITL ms", "syncs",
         "logits->host"],
        rows, widths=[12, 12, 10, 10, 6, 12])
    # long-context pair: same fused K=16 loop with and without the kernel
    # path, at a prompt where per-step page re-gather dominates the step.
    # This is the operating point the kernel-vs-reference gate measures.
    lmodes = [("fused-long", True, max(ks), False),
              ("kernel-long", True, max(ks), True)]
    lresults, lrows = _run_modes(model, params, vocab, gen=gen,
                                 plen=LONG_PROMPT, modes=lmodes)
    assert lresults[1]["outputs"] == lresults[0]["outputs"], \
        "kernel path diverged from the fused reference at long context"
    print_table(
        f"Decode fast path, long context ({ARCH} reduced, B={SLOTS}, "
        f"prompt {LONG_PROMPT}, {gen} gen tokens)",
        ["mode", "steady tok/s", "p50 ITL ms", "p99 ITL ms", "syncs",
         "logits->host"],
        lrows, widths=[16, 12, 10, 10, 6, 12])
    return results, lresults


def main(fast: bool = False, smoke: bool = False) -> dict:
    cfg = reduced(REGISTRY[ARCH])
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # smoke keeps gen long enough for steady state to dominate — short
    # runs under-credit K=16 (end-of-sequence waste is a larger share)
    # and give its median rate too few sync samples to reject contention
    gen = 64 if (smoke or fast) else 192
    ks = [1, 16] if smoke else [1, 4, 16]
    results, lresults = bench(model, params, cfg.vocab_size, gen=gen,
                              ks=ks)
    legacy = results[0]
    fused16 = next(r for r in results if r["mode"] == "fused"
                   and r["K"] == 16)
    kernel16 = next(r for r in results if r["mode"] == "kernel")
    speedup = fused16["steady_tok_per_s"] / legacy["steady_tok_per_s"]
    kshort = kernel16["steady_tok_per_s"] / fused16["steady_tok_per_s"]
    kspeedup = (lresults[1]["steady_tok_per_s"]
                / lresults[0]["steady_tok_per_s"])
    out = {"arch": ARCH, "batch": SLOTS, "prompt_len": PROMPT_LEN,
           "long_prompt_len": LONG_PROMPT,
           "gen_tokens": gen, "page_size": PAGE,
           "modes": [{k: v for k, v in r.items() if k != "outputs"}
                     for r in results + lresults],
           "speedup_fused16_vs_legacy": speedup,
           "speedup_kernel16_vs_fused16": kshort,
           "speedup_kernel_vs_ref_long_ctx": kspeedup,
           "tokens_identical": True}
    # fast/smoke runs must not clobber the committed full-mode artifact
    path = OUT_PATH.replace(".json", ".fast.json") if (fast or smoke) \
        else OUT_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {path}  (fused K=16 vs legacy: {speedup:.2f}x, "
          f"kernel vs fused reference at prompt {LONG_PROMPT}: "
          f"{kspeedup:.2f}x)")
    # the 2x claim is held to the full-length run only; reduced runs
    # (smoke/fast: gen=64) under-credit K=16 — end-of-sequence waste is a
    # larger share and the median has fewer sync samples — and the smoke
    # floor additionally leaves headroom for loaded shared CI runners
    floor = 1.3 if smoke else (1.5 if fast else 2.0)
    if speedup < floor:
        raise SystemExit(
            f"fused decode speedup at K=16 is {speedup:.2f}x "
            f"(expected >= {floor}x)")
    # kernel-vs-reference gate: the kernel path must beat the fused
    # gather-reference loop it replaces, at the same K on the long-context
    # pair — a pure ratio between two passes of the same process, immune
    # to absolute host speed. Full runs hold the 1.3x claim; reduced runs
    # (shorter gen -> shorter mean context) get headroom.
    kfloor = 1.1 if smoke else (1.2 if fast else 1.3)
    if kspeedup < kfloor:
        raise SystemExit(
            f"kernel decode speedup vs fused reference at K=16, prompt "
            f"{LONG_PROMPT} is {kspeedup:.2f}x (expected >= {kfloor}x)")
    return out


if __name__ == "__main__":
    main()
