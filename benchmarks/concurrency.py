"""Table 1 reproduction: WebUI closed-loop concurrency sweep.

N simulated chat sessions each hold one in-flight request at a time
(send -> wait for full response -> immediately send the next).  Throughput
(output tok/s and completed req/s) is measured inside a 60 s and a 120 s
window, for Llama-8B / Gemma-27B / Llama-70B, concurrency 50..700.

Paper claims: near-linear scaling 50 -> 500 with diminishing returns at
700; 60 s windows consistently beat 120 s.  Known deltas (EXPERIMENTS.md):
our DES saturates at the result-worker cap by conc~300 (the paper's growth
to 700 is consistent with autoscaled extra instances mid-sweep), and the
60s>120s inversion needs backend degradation we do not model.
"""
from __future__ import annotations

import random

from benchmarks.common import (GEMMA27B, LLAMA8B, LLAMA70B, csv_line,
                               first_system, print_table, warm_up)
from repro.data.workload import sharegpt_lengths

CONCURRENCY = [50, 100, 300, 500, 700]
WINDOWS = [60.0, 120.0]

# result_cpu=0.12: the per-instance Globus result-worker serialization --
# the paper's Table 1 saturates at ~11-15 req/s for ALL model sizes, the
# signature of a model-independent pipeline cap (same knob as Fig. 4).
MODELS = {
    LLAMA8B.name: (LLAMA8B, dict(chips_per_instance=4, max_slots=64,
                                 mfu=0.5, storage_bw=2e9, result_cpu=0.12,
                                 nodes_per_instance=1)),
    GEMMA27B.name: (GEMMA27B, dict(chips_per_instance=8, max_slots=64,
                                   mfu=0.5, storage_bw=2e9, result_cpu=0.12,
                                   nodes_per_instance=1)),
    LLAMA70B.name: (LLAMA70B, dict(chips_per_instance=8, max_slots=64,
                                   mfu=0.5, storage_bw=2e9, result_cpu=0.12,
                                   nodes_per_instance=1)),
}
MAX_INSTANCES = 1           # one shared instance per model (WebUI deploy)
THINK_S = 3.0               # UI render + user turn gap between messages


def run(model_key: str, sessions: int, window: float) -> dict:
    cfg, dep_kw = MODELS[model_key]
    sysd = first_system(cfg, max_instances=MAX_INSTANCES, dep_kw=dep_kw,
                        relay_workers=4, relay_cpu=0.02, workers=256)
    warm_up(sysd, cfg.name, instances=MAX_INSTANCES)
    token = sysd.token_for("webui")
    rng = random.Random(1234 + sessions)
    completions: list[dict] = []
    counter = [0]
    start = sysd.loop.now()                   # warm-up already advanced time

    def start_session(sid: int):
        def send():
            (p, o), = sharegpt_lengths(rng, 1)
            counter[0] += 1
            fut = sysd.gateway.submit(token, {
                "request_id": f"s{sid}-{counter[0]}", "model": cfg.name,
                "prompt_tokens": p, "max_tokens": o,
                "temperature": 1.0,           # chat: no response-cache hits
            })
            t0 = sysd.loop.now()

            def done(f):
                if f.error is None:
                    completions.append({
                        "arrival": t0, "finish": sysd.loop.now(),
                        "output_tokens": f.result()["output_tokens"]})
                if sysd.loop.now() - start < window:
                    sysd.loop.call_after(THINK_S, send)   # closed loop

            fut.add_done_callback(done)

        send()

    for s in range(sessions):
        start_session(s)
    sysd.loop.run_until(start + window + 1e-6)
    inside = [c for c in completions if c["finish"] - start <= window]
    toks = sum(c["output_tokens"] for c in inside)
    return {"tok_s": toks / window, "req_s": len(inside) / window,
            "completed": len(inside)}


def main(fast: bool = False) -> list[dict]:
    conc = [50, 300, 700] if fast else CONCURRENCY
    models = [LLAMA8B.name, LLAMA70B.name] if fast else list(MODELS)
    rows, out = [], []
    for mk in models:
        for c in conc:
            cells = {}
            for w in WINDOWS:
                r = run(mk, c, w)
                cells[w] = r
                out.append({"model": mk, "conc": c, "window": w, **r})
                csv_line(f"concurrency/{mk}/c{c}/w{int(w)}", 0.0,
                         f"tok_s={r['tok_s']:.0f};req_s={r['req_s']:.2f}")
            rows.append([mk, c,
                         f"{cells[60.0]['tok_s']:.0f}",
                         f"{cells[60.0]['req_s']:.2f}",
                         f"{cells[120.0]['tok_s']:.0f}",
                         f"{cells[120.0]['req_s']:.2f}"])
    print_table("Table 1 — WebUI concurrency sweep",
                ["model", "conc", "60s tok/s", "60s req/s", "120s tok/s",
                 "120s req/s"],
                rows, widths=[14, 5, 9, 9, 10, 10])
    return out


if __name__ == "__main__":
    main()
