"""Fig. 5 reproduction: FIRST (Llama-8B, TP=4) vs an external commercial API
(GPT-4o-mini class) under infinite request rate.

Paper claims: FIRST 25.1 req/s / 3283 tok/s / 16.3 s median; OpenAI API
6.7 req/s / 1199 tok/s / 2.0 s median -- the common trade-off: self-hosted
HPC inference wins on throughput, the managed API wins on single-request
latency (and is rate-limited service-side).
"""
from __future__ import annotations

from benchmarks.common import (DEP_8B, ExternalAPIModel, LLAMA8B, csv_line,
                               first_system, make_workload, print_table,
                               warm_up)
from repro.core.clock import EventLoop, VirtualClock
from repro.core.testbed import drive_workload

N_REQ = 1000


def main(fast: bool = False) -> dict:
    n = 300 if fast else N_REQ
    sysd = first_system(LLAMA8B, dep_kw=DEP_8B)
    warm_up(sysd, LLAMA8B.name)
    wl = make_workload(n, rate=float("inf"), seed=23)
    f = drive_workload(sysd, wl, LLAMA8B.name)

    ext = ExternalAPIModel(EventLoop(VirtualClock()),
                           latency=2.0, rate_limit=6.7)
    e = ext.run(make_workload(n, rate=float("inf"), seed=23))

    rows = [
        ["FIRST (Llama-8B)", f"{f['req_per_s']:.1f}",
         f"{f['output_tok_per_s']:.0f}", f"{f['median_e2e_s']:.1f}"],
        ["External API", f"{e['req_per_s']:.1f}",
         f"{e['output_tok_per_s']:.0f}", f"{e['median_e2e_s']:.1f}"],
    ]
    print_table("Fig.5 — FIRST vs external API (infinite rate)",
                ["scenario", "req/s", "tok/s", "median e2e s"],
                rows, widths=[18, 7, 7, 12])
    print(f"\ncheck: FIRST req/s {f['req_per_s']:.1f} > API "
          f"{e['req_per_s']:.1f} (paper 25.1 vs 6.7); API median "
          f"{e['median_e2e_s']:.1f}s < FIRST {f['median_e2e_s']:.1f}s "
          f"(paper 2.0 vs 16.3)")
    csv_line("external_api/first", f["median_e2e_s"] * 1e6,
             f"req_s={f['req_per_s']:.1f};tok_s={f['output_tok_per_s']:.0f}")
    csv_line("external_api/api", e["median_e2e_s"] * 1e6,
             f"req_s={e['req_per_s']:.1f};tok_s={e['output_tok_per_s']:.0f}")
    return {"first": f, "external": e}


if __name__ == "__main__":
    main()
