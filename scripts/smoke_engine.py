"""Dev smoke: engine (slots + paged) greedy generations match direct LM loop."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams


def direct_generate(model, params, prompt, n):
    logits, cache = model.prefill(params, {"tokens": jnp.asarray([prompt])},
                                  max_len=len(prompt) + n + 1,
                                  moe_mode="dense")
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        logits, cache = model.decode_step(params,
                                          jnp.asarray([toks[-1]]), cache)
        toks.append(int(jnp.argmax(logits[0])))
    return toks


prompts = [list(range(5, 25)), list(range(40, 52)), list(range(7, 40)),
           list(range(90, 122))]

for arch in ["llama3.2-3b", "phi3.5-moe-42b-a6.6b", "mamba2-130m",
             "zamba2-2.7b"]:
    cfg = reduced(REGISTRY[arch])
    model = make_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    expected = [direct_generate(model, params, p, 8) for p in prompts]

    backends = ["slots"] if cfg.family in ("ssm", "hybrid") else \
        ["slots", "paged"]
    for be in backends:
        eng = ContinuousBatchingEngine(
            model, params,
            EngineConfig(max_slots=3, max_seq_len=256, backend=be,
                         page_size=32))
        for i, p in enumerate(prompts):
            eng.add_request(InferenceRequest(
                model=arch, prompt_tokens=p, request_id=f"r{i}",
                sampling=SamplingParams(max_tokens=8, temperature=0.0)))
        outs = {o.request_id: o for o in eng.run_to_completion()}
        for i in range(len(prompts)):
            got = outs[f"r{i}"].output_tokens
            assert got == expected[i], \
                f"{arch}/{be} r{i}: {got} != {expected[i]}"
        print(f"{arch} [{be}]: OK ({eng.stats})")

print("ENGINE OK")
