"""Dev check: prefill + N decode steps == forward logits (teacher forcing)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import make_model

B, S, EXTRA = 2, 64, 4

for name in ["yi-34b", "qwen1.5-4b", "granite-34b", "phi3.5-moe-42b-a6.6b",
             "mamba2-130m", "zamba2-2.7b"]:
    cfg = reduced(REGISTRY[name])
    if cfg.moe:
        # capacity dropping is not teacher-forcing-consistent by design; use
        # no-drop capacity so grouped (prefill) == dense (decode) exactly.
        from dataclasses import replace
        cfg = replace(cfg, moe=replace(cfg.moe,
                                       capacity_factor=cfg.moe.num_experts
                                       / cfg.moe.top_k))
    model = make_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng)
    toks = jax.random.randint(rng, (B, S + EXTRA), 0, cfg.vocab_size)

    # full forward logits for positions [S-1, S+EXTRA-1)
    batch_full = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    x = model.embed_inputs(params, batch_full)
    if cfg.family in ("ssm", "hybrid"):
        from repro.models import hybrid as be
    else:
        from repro.models import transformer as be
    hidden, _ = be.forward(params, x, cfg, remat=False)
    full_logits = model.logits(params, hidden)   # (B, S+EXTRA, V)

    # prefill on first S tokens, then decode the rest teacher-forced
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :S]},
                                    max_len=S + EXTRA)
    errs = [np.abs(np.asarray(logits_p) - np.asarray(full_logits[:, S - 1])).max()]
    for t in range(EXTRA):
        logits_d, cache = model.decode_step(params, toks[:, S + t], cache)
        errs.append(np.abs(np.asarray(logits_d)
                           - np.asarray(full_logits[:, S + t])).max())
    print(f"{name}: max_abs_err per step {['%.2e' % e for e in errs]}")
    assert max(errs) < 2e-3, f"{name} inconsistent: {errs}"

print("CONSISTENCY OK")
