#!/usr/bin/env python
"""Pretty firstlint runner: clickable file:line findings for editors/CI logs.

    PYTHONPATH=src python scripts/lint_findings.py [paths...]

Wraps ``python -m repro.analysis --format=json`` and prints one
``path:line:col`` line per finding (the format terminals and editors link),
grouped by rule, plus the suppression count so waivers stay visible.
Exit code mirrors the analyzer: 0 clean, 1 findings.
"""
import json
import pathlib
import subprocess
import sys


def main(argv):
    repo = pathlib.Path(__file__).resolve().parent.parent
    paths = argv or ["src", "tests", "benchmarks", "scripts", "examples"]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", *paths, "--format=json"],
        cwd=repo, capture_output=True, text=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(repo / "src")})
    if proc.returncode not in (0, 1):
        sys.stderr.write(proc.stdout + proc.stderr)
        return proc.returncode
    doc = json.loads(proc.stdout)
    by_rule: dict = {}
    for f in doc["findings"]:
        by_rule.setdefault(f["rule"], []).append(f)
    for rule in sorted(by_rule):
        print(f"{rule} ({len(by_rule[rule])}):")
        for f in by_rule[rule]:
            print(f"  {f['path']}:{f['line']}:{f['col']}  {f['message']}")
    print(f"{doc['files_checked']} files checked, "
          f"{len(doc['findings'])} findings, "
          f"{doc['suppressed']} suppressed")
    return 1 if doc["findings"] else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
