"""Quick dev smoke: every reduced arch runs train_loss / prefill / decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import make_model

B, S = 2, 64
failures = []
for name, cfg in sorted(REGISTRY.items()):
    rcfg = reduced(cfg)
    model = make_model(rcfg)
    rng = jax.random.PRNGKey(0)
    try:
        params = model.init_params(rng)
        n = sum(x.size for x in jax.tree.leaves(params))
        if rcfg.input_kind == "embeds":
            batch = {"embeds": jax.random.normal(rng, (B, S, rcfg.d_model)),
                     "labels": jnp.zeros((B, S), jnp.int32)}
        else:
            toks = jax.random.randint(rng, (B, S), 0, rcfg.vocab_size)
            batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        loss, metrics = jax.jit(model.train_loss)(params, batch)
        assert jnp.isfinite(loss), f"{name}: loss not finite"
        grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
        gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        assert jnp.isfinite(gn), f"{name}: grad not finite"
        msg = f"{name}: params={n} loss={float(loss):.4f}"
        if not rcfg.is_encoder:
            logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=S + 8))(params, batch)
            assert logits.shape == (B, rcfg.vocab_size)
            toks2 = jnp.argmax(logits, -1)
            logits2, cache = jax.jit(model.decode_step)(params, toks2, cache)
            assert logits2.shape == (B, rcfg.vocab_size)
            assert jnp.isfinite(logits2).all()
            msg += " decode-ok"
        else:
            logits, _ = jax.jit(model.prefill)(params, batch)
            assert logits.shape == (B, S, rcfg.vocab_size)
            msg += " encode-ok"
        print(msg)
    except Exception as e:
        failures.append((name, repr(e)))
        print(f"{name}: FAIL {e!r}")

if failures:
    sys.exit(1)
print("ALL OK")
