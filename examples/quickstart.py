"""Quickstart: the two planes of the FIRST reproduction in one script.

1. CONTROL PLANE (discrete-event, virtual clock): build a Sophia-like
   deployment, authenticate, and serve OpenAI-style requests through the
   Inference Gateway -> Globus-Compute analogue -> hot model instance.
2. DATA PLANE (real JAX on CPU): the same serving substrate running an
   actual reduced-config model through the continuous-batching engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.api import FirstClient
from repro.configs import REGISTRY, reduced
from repro.core.testbed import LLAMA70B, build_system, default_deployment
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

# ---------------------------------------------------------------------------
# 1) control plane: a 70B deployment on a 24-node cluster, typed /v1 client
# ---------------------------------------------------------------------------
print("== control plane (DES) ==")
system = build_system(
    {"sophia": {LLAMA70B.name: default_deployment(LLAMA70B)}})
client = FirstClient(system.gateway, system.token_for("alice"))

# first request: cold start (queue -> node acquisition -> weight load)
fut = client.chat(model=LLAMA70B.name, prompt_tokens=256, max_tokens=64)
system.loop.run_until(30.0)
print("while loading, /jobs reports:", client.jobs())
system.loop.run_until_idle()
r = fut.result()                    # typed ChatCompletionResponse
print(f"cold request done at t={system.loop.now():.1f}s "
      f"(usage={r.usage.to_dict()} from {r.endpoint_id})")

# second request: the node is HOT and the client STREAMS — TTFT and
# inter-token latency are visible at the API boundary now
t0 = system.loop.now()
fut, stream = client.stream(model=LLAMA70B.name, prompt_tokens=300,
                            max_tokens=64, temperature=0.7)
system.loop.run_until_idle()
gaps = stream.inter_token_gaps
print(f"hot request streamed in {system.loop.now() - t0:.2f}s (vs ~90s "
      f"cold): TTFT {stream.ttft - t0:.2f}s, "
      f"{len(stream.deltas)} frames, median ITL "
      f"{sorted(gaps)[len(gaps) // 2]:.3f}s")

# ---------------------------------------------------------------------------
# 2) data plane: real model, real engine, greedy decoding
# ---------------------------------------------------------------------------
print("\n== data plane (real JAX engine) ==")
cfg = reduced(REGISTRY["llama3.2-3b"])
model = make_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
engine = ContinuousBatchingEngine(
    model, params, EngineConfig(max_slots=4, max_seq_len=128,
                                backend="paged", page_size=16,
                                enable_prefix_cache=True,
                                chunked_prefill_budget=32))
rng = np.random.default_rng(0)
system_prompt = rng.integers(2, cfg.vocab_size, size=32).tolist()
from repro.api import StreamAssembler, to_inference_request
from repro.api.schemas import CompletionRequest

streams = {}
for i in range(6):
    # shared system prompt + unique tail: after the first request the
    # prefix cache serves the shared pages without recomputing them
    prompt = system_prompt + rng.integers(2, cfg.vocab_size, size=8).tolist()
    # typed /v1 request -> engine request; every request streams
    req = CompletionRequest(model=cfg.name, prompt_tokens=prompt,
                            request_id=f"req-{i}", max_tokens=16,
                            temperature=0.0, stream=True).validate()
    streams[req.request_id] = StreamAssembler()
    engine.add_request(to_inference_request(req),
                       on_delta=streams[req.request_id])
outs = engine.run_to_completion()
assert all(streams[o.request_id].tokens == o.output_tokens for o in outs), \
    "streamed frames must reassemble to the exact output"

for o in sorted(outs, key=lambda o: o.request_id):
    print(f"{o.request_id}: {o.num_output_tokens} tokens "
          f"({o.finish_reason}) -> {o.output_tokens[:8]}...")
print("engine stats:", engine.stats)
print("prefix cache:", engine.cache_stats())
