"""Quickstart: the two planes of the FIRST reproduction in one script.

1. CONTROL PLANE (discrete-event, virtual clock): build a Sophia-like
   deployment, authenticate, and serve OpenAI-style requests through the
   Inference Gateway -> Globus-Compute analogue -> hot model instance.
2. DATA PLANE (real JAX on CPU): the same serving substrate running an
   actual reduced-config model through the continuous-batching engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.core.testbed import LLAMA70B, build_system, default_deployment
from repro.models import make_model
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.request import InferenceRequest, SamplingParams

# ---------------------------------------------------------------------------
# 1) control plane: a 70B deployment on a 24-node cluster
# ---------------------------------------------------------------------------
print("== control plane (DES) ==")
system = build_system(
    {"sophia": {LLAMA70B.name: default_deployment(LLAMA70B)}})
token = system.token_for("alice")

# first request: cold start (queue -> node acquisition -> weight load)
fut = system.gateway.submit(token, {
    "model": LLAMA70B.name, "prompt_tokens": 256, "max_tokens": 64})
system.loop.run_until(30.0)
print("while loading, /jobs reports:", system.gateway.jobs_status())
system.loop.run_until_idle()
r = fut.result()
print(f"cold request done at t={system.loop.now():.1f}s "
      f"({r['output_tokens']} tokens from {r['endpoint']})")

# second request: the node is HOT -> low latency (temperature>0 bypasses
# the gateway's deterministic-response cache)
t0 = system.loop.now()
fut = system.gateway.submit(token, {
    "model": LLAMA70B.name, "prompt_tokens": 300, "max_tokens": 64,
    "temperature": 0.7})
system.loop.run_until_idle()
print(f"hot request served in {system.loop.now() - t0:.2f}s "
      f"(vs ~{90:.0f}s cold)")

# ---------------------------------------------------------------------------
# 2) data plane: real model, real engine, greedy decoding
# ---------------------------------------------------------------------------
print("\n== data plane (real JAX engine) ==")
cfg = reduced(REGISTRY["llama3.2-3b"])
model = make_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
engine = ContinuousBatchingEngine(
    model, params, EngineConfig(max_slots=4, max_seq_len=128,
                                backend="paged", page_size=16,
                                enable_prefix_cache=True,
                                chunked_prefill_budget=32))
rng = np.random.default_rng(0)
system_prompt = rng.integers(2, cfg.vocab_size, size=32).tolist()
for i in range(6):
    # shared system prompt + unique tail: after the first request the
    # prefix cache serves the shared pages without recomputing them
    prompt = system_prompt + rng.integers(2, cfg.vocab_size, size=8).tolist()
    engine.add_request(InferenceRequest(
        model=cfg.name, prompt_tokens=prompt, request_id=f"req-{i}",
        sampling=SamplingParams(max_tokens=16, temperature=0.0)))
outs = engine.run_to_completion()
for o in sorted(outs, key=lambda o: o.request_id):
    print(f"{o.request_id}: {o.num_output_tokens} tokens "
          f"({o.finish_reason}) -> {o.output_tokens[:8]}...")
print("engine stats:", engine.stats)
print("prefix cache:", engine.cache_stats())
