"""Batch mode (paper §4.4): JSONL in, dedicated job, offline engine.

Writes a JSON-Lines request file (one complete inference request per line,
as the /v1/batches endpoint takes), then processes it twice:
  * control plane: a dedicated DES job with cold start amortization;
  * data plane: the real offline engine on a reduced model.

Run:  PYTHONPATH=src python examples/batch_inference.py
"""
import json
import os
import tempfile

import jax
import numpy as np

from repro.api import FirstClient
from repro.configs import REGISTRY, reduced
from repro.core.testbed import LLAMA70B, build_system, default_deployment
from repro.models import make_model
from repro.serving.engine import EngineConfig
from repro.serving.offline import run_batch
from repro.serving.request import InferenceRequest, SamplingParams

# ---------------------------------------------------------------------------
# write the NDJSON input file (OpenAI batch line shape: custom_id + body)
# ---------------------------------------------------------------------------
rng = np.random.default_rng(7)
jsonl = os.path.join(tempfile.gettempdir(), "first_batch_input.jsonl")
with open(jsonl, "w") as f:
    for i in range(500):
        f.write(json.dumps({
            "custom_id": f"b{i}",
            "method": "POST", "url": "/v1/completions",
            "body": {"model": LLAMA70B.name,
                     "prompt_tokens": int(rng.integers(16, 512)),
                     "max_tokens": int(rng.integers(16, 256))},
        }) + "\n")
print(f"wrote {jsonl}")

# ---------------------------------------------------------------------------
# control plane: /v1/batches -> dedicated cluster job
# ---------------------------------------------------------------------------
system = build_system(
    {"sophia": {LLAMA70B.name: default_deployment(LLAMA70B)}})
client = FirstClient(system.gateway, system.token_for("alice"))
with open(jsonl) as f:
    items = [json.loads(line) for line in f]
fut = client.create_batch(items)
system.loop.run_until(120.0)        # cold start in progress
bid = fut.result().id
print("while loading:", client.batch_status(bid).to_dict())
system.loop.run_until_idle()
st = client.batch_status(bid)
dur = st.completed_at - st.created_at
results = client.batch_results(bid)
usage0 = results[0]["response"].usage
print(f"completed: {st.completed} requests, {st.output_tokens} tokens "
      f"in {dur:.0f}s -> {st.output_tokens/dur:.0f} tok/s "
      f"(cold start {st.in_progress_at - st.created_at:.0f}s amortized); "
      f"per-request result[0] usage={usage0.to_dict()}")

# ---------------------------------------------------------------------------
# data plane: the real offline engine (reduced model, CPU)
# ---------------------------------------------------------------------------
cfg = reduced(REGISTRY["qwen1.5-4b"])
model = make_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
reqs = [InferenceRequest(
            model=cfg.name,
            prompt_tokens=rng.integers(2, cfg.vocab_size, size=24).tolist(),
            request_id=f"real-{i}",
            sampling=SamplingParams(max_tokens=12, temperature=0.0))
        for i in range(32)]
outs, stats = run_batch(model, params, reqs,
                        EngineConfig(max_slots=16, max_seq_len=64))
print(f"\nreal offline engine: {len(outs)} requests, "
      f"{stats['output_tokens']} tokens, "
      f"{stats['output_tok_per_s']:.0f} tok/s on CPU "
      f"({stats['steps']} engine steps)")
