"""Batch mode (paper §4.4): JSONL in, dedicated job, offline engine.

Writes a JSON-Lines request file (one complete inference request per line,
as the /v1/batches endpoint takes), then processes it twice:
  * control plane: a dedicated DES job with cold start amortization;
  * data plane: the real offline engine on a reduced model.

Run:  PYTHONPATH=src python examples/batch_inference.py
"""
import json
import os
import tempfile

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.core.testbed import LLAMA70B, build_system, default_deployment
from repro.models import make_model
from repro.serving.engine import EngineConfig
from repro.serving.offline import run_batch
from repro.serving.request import InferenceRequest, SamplingParams

# ---------------------------------------------------------------------------
# write the JSONL input file
# ---------------------------------------------------------------------------
rng = np.random.default_rng(7)
jsonl = os.path.join(tempfile.gettempdir(), "first_batch_input.jsonl")
with open(jsonl, "w") as f:
    for i in range(500):
        f.write(json.dumps({
            "request_id": f"b{i}",
            "prompt_tokens": int(rng.integers(16, 512)),
            "max_tokens": int(rng.integers(16, 256)),
        }) + "\n")
print(f"wrote {jsonl}")

# ---------------------------------------------------------------------------
# control plane: /v1/batches -> dedicated cluster job
# ---------------------------------------------------------------------------
system = build_system(
    {"sophia": {LLAMA70B.name: default_deployment(LLAMA70B)}})
with open(jsonl) as f:
    requests = [json.loads(line) for line in f]
job = system.batch.submit_batch(LLAMA70B.name, requests)
print("submitted:", system.batch.status(job.batch_id))
system.loop.run_until(120.0)        # cold start in progress
print("while loading:", system.batch.status(job.batch_id))
system.loop.run_until_idle()
st = system.batch.status(job.batch_id)
dur = job.finish_time - job.submit_time
print(f"completed: {st['completed']} requests, {st['output_tokens']} tokens "
      f"in {dur:.0f}s -> {st['output_tokens']/dur:.0f} tok/s "
      f"(cold start {job.start_time - job.submit_time:.0f}s amortized)")

# ---------------------------------------------------------------------------
# data plane: the real offline engine (reduced model, CPU)
# ---------------------------------------------------------------------------
cfg = reduced(REGISTRY["qwen1.5-4b"])
model = make_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
reqs = [InferenceRequest(
            model=cfg.name,
            prompt_tokens=rng.integers(2, cfg.vocab_size, size=24).tolist(),
            request_id=f"real-{i}",
            sampling=SamplingParams(max_tokens=12, temperature=0.0))
        for i in range(32)]
outs, stats = run_batch(model, params, reqs,
                        EngineConfig(max_slots=16, max_seq_len=64))
print(f"\nreal offline engine: {len(outs)} requests, "
      f"{stats['output_tokens']} tokens, "
      f"{stats['output_tok_per_s']:.0f} tok/s on CPU "
      f"({stats['steps']} engine steps)")
