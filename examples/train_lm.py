"""End-to-end training driver example: train a small LM on CPU with the
full substrate — synthetic data pipeline, remat'd scan-over-layers step,
grad accumulation, AdamW, checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import os
import tempfile
import time

import jax

from repro.configs import REGISTRY, reduced
from repro.data.tokens import TokenDataset
from repro.distributed.checkpoint import (latest_checkpoint, load_checkpoint,
                                          save_checkpoint)
from repro.models import make_model
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-3b")
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--ckpt-every", type=int, default=25)
args = ap.parse_args()

cfg = reduced(REGISTRY[args.arch])
model = make_model(cfg)
ckpt_dir = os.path.join(tempfile.gettempdir(), "first_train_ckpt")
os.makedirs(ckpt_dir, exist_ok=True)

data = TokenDataset(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                    seed=0)
step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3),
                                  num_microbatches=2))

# resume if a checkpoint exists, else fresh init
latest = latest_checkpoint(ckpt_dir)
if latest:
    state, meta = load_checkpoint(latest)
    params, opt_state = state["params"], state["opt"]
    data.restore(meta["data"])
    start = meta["step"]
    print(f"resumed from {latest} at step {start}")
else:
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    start = 0

t0 = time.time()
for step in range(start, args.steps):
    batch = data.next_batch()
    params, opt_state, metrics = step_fn(params, opt_state, batch)
    if step % 10 == 0 or step == args.steps - 1:
        print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
              f"({(time.time()-t0):.1f}s)")
    if (step + 1) % args.ckpt_every == 0:
        path = os.path.join(ckpt_dir, f"ckpt_{step+1:06d}")
        save_checkpoint(path, {"params": params, "opt": opt_state},
                        step=step + 1, metadata={"step": step + 1,
                                                 "data": data.state()})
        print(f"checkpointed -> {path}")
print("done; rerun this script to resume from the last checkpoint")
