"""Federated serving (paper §4.5): two clusters, one agnostic API.

Demonstrates the priority-based endpoint selection (active instance >
free nodes > configured order, load tie-break within a rule),
auto-scaling under burst load, fail-over when a whole cluster drops out,
and QoS classes: interactive requests jump a batch flood on a
priority-scheduled deployment (with preemption, they evict running batch
work and the victims restore via the prefix cache).

Run:  PYTHONPATH=src python examples/federated_serving.py
"""
from repro.api import FirstClient
from repro.core.gateway import GatewayConfig
from repro.core.testbed import (LLAMA70B, build_system, default_deployment,
                                drive_workload, warm_up)
from repro.data.workload import make_workload

MODEL = LLAMA70B.name

system = build_system(
    {
        "sophia": {MODEL: default_deployment(
            LLAMA70B, max_instances=2, storage_bw=40e9, scale_cooldown=5.0,
            # QoS: interactive admits before batch; blocked interactive
            # arrivals may evict running batch work (restores are charged
            # a prefix-cache-discounted re-prefill, hit rate 0.9)
            scheduling_policy="priority", enable_preemption=True,
            restore_hit_rate=0.9)},
        "polaris": {MODEL: default_deployment(
            LLAMA70B, max_instances=2, storage_bw=40e9, scale_cooldown=5.0)},
    },
    gateway_config=GatewayConfig(workers=128),
    startup_delay=5.0,
)

# 1) cold federation: no instance anywhere -> rule 2 picks by free nodes
ep = system.router.select_endpoint(MODEL)
print(f"cold selection -> {ep} (rule: {system.router.decisions[-1][2]})")

# 2) warm sophia; rule 1 now prefers the active instance
warm_up(system, MODEL)
ep = system.router.select_endpoint(MODEL)
print(f"warm selection -> {ep} (rule: {system.router.decisions[-1][2]})")

# 3) burst load: auto-scaler adds a second sophia instance
wl = make_workload(400, rate=float("inf"), seed=1)
s = drive_workload(system, wl, MODEL)
inst = system.endpoints["sophia-ep"].instances[MODEL]
print(f"burst of 400: {s['req_per_s']:.1f} req/s, "
      f"{s['output_tok_per_s']:.0f} tok/s, sophia instances={len(inst)}")

# 4) sophia outage -> health monitor reroutes to polaris transparently
system.health.mark_down("sophia-ep")
system.loop.run_until(system.loop.now() + 15.0)
client = FirstClient(system.gateway, system.token_for("alice"))
fut = client.chat(model=MODEL, prompt_tokens=64, max_tokens=32)
system.loop.run_until_idle()
print(f"after sophia outage: served by {fut.result().endpoint_id} "
      f"(rule: {system.router.decisions[-1][2]})")

# 5) /jobs view across the federation
print("federation /jobs:", system.gateway.jobs_status())

# 6) QoS: restore sophia, take polaris down (so everything lands on the
#    priority-scheduled cluster) and flood it with batch-class work, then
#    submit one interactive request mid-flood — the deployment preempts a
#    batch victim, so the interactive answer returns while the flood is
#    still draining
system.health.mark_up("sophia-ep")
system.health.mark_down("polaris-ep")
system.loop.run_until(system.loop.now() + 15.0)
t0 = system.loop.now()
batch_futs = [client.chat(model=MODEL, request_id=f"flood-{j}",
                          prompt_tokens=256, max_tokens=1500, qos="batch")
              for j in range(96)]
interactive = {}


def ask_interactive():
    # the interactive request STREAMS: its gateway-observed TTFT shows the
    # preemption actually worked while the flood is still draining
    fut, asm = client.stream(model=MODEL, request_id="chat-1",
                             prompt_tokens=72, max_tokens=24,
                             qos="interactive")
    interactive["fut"], interactive["asm"] = fut, asm
    interactive["t"] = system.loop.now()


system.loop.call_at(t0 + 20.0, ask_interactive)       # mid-flood
system.loop.run_until_idle()
assert interactive["fut"].error is None
recs = {r.request_id: r for r in system.metrics.records}
flood_e2e = sorted(recs[f"flood-{j}"].e2e for j in range(96)
                   if f"flood-{j}" in recs)
preempts = sum(i.engine.total_preemptions
               for i in system.endpoints["sophia-ep"].instances[MODEL])
asm = interactive["asm"]
print(f"QoS: interactive TTFT {asm.ttft - interactive['t']:.2f}s / e2e "
      f"{recs['chat-1'].e2e:.2f}s over {len(asm.deltas)} stream frames vs "
      f"batch median {flood_e2e[len(flood_e2e) // 2]:.1f}s "
      f"(sophia preemptions={preempts}, decision detail: "
      f"{next(d for d in reversed(system.router.decisions) if 'qos=interactive' in d[3])[3]})")
