"""Federated serving (paper §4.5): two clusters, one agnostic API.

Demonstrates the priority-based endpoint selection (active instance >
free nodes > configured order), auto-scaling under burst load, and
fail-over when a whole cluster drops out.

Run:  PYTHONPATH=src python examples/federated_serving.py
"""
from repro.core.gateway import GatewayConfig
from repro.core.testbed import (LLAMA70B, build_system, default_deployment,
                                drive_workload, warm_up)
from repro.data.workload import make_workload

MODEL = LLAMA70B.name

system = build_system(
    {
        "sophia": {MODEL: default_deployment(
            LLAMA70B, max_instances=2, storage_bw=40e9, scale_cooldown=5.0)},
        "polaris": {MODEL: default_deployment(
            LLAMA70B, max_instances=2, storage_bw=40e9, scale_cooldown=5.0)},
    },
    gateway_config=GatewayConfig(workers=128),
    startup_delay=5.0,
)

# 1) cold federation: no instance anywhere -> rule 2 picks by free nodes
ep = system.router.select_endpoint(MODEL)
print(f"cold selection -> {ep} (rule: {system.router.decisions[-1][2]})")

# 2) warm sophia; rule 1 now prefers the active instance
warm_up(system, MODEL)
ep = system.router.select_endpoint(MODEL)
print(f"warm selection -> {ep} (rule: {system.router.decisions[-1][2]})")

# 3) burst load: auto-scaler adds a second sophia instance
wl = make_workload(400, rate=float("inf"), seed=1)
s = drive_workload(system, wl, MODEL)
inst = system.endpoints["sophia-ep"].instances[MODEL]
print(f"burst of 400: {s['req_per_s']:.1f} req/s, "
      f"{s['output_tok_per_s']:.0f} tok/s, sophia instances={len(inst)}")

# 4) sophia outage -> health monitor reroutes to polaris transparently
system.health.mark_down("sophia-ep")
system.loop.run_until(system.loop.now() + 15.0)
token = system.token_for("alice")
fut = system.gateway.submit(token, {"model": MODEL, "prompt_tokens": 64,
                                    "max_tokens": 32})
system.loop.run_until_idle()
print(f"after sophia outage: served by {fut.result()['endpoint']} "
      f"(rule: {system.router.decisions[-1][2]})")

# 5) /jobs view across the federation
print("federation /jobs:", system.gateway.jobs_status())
