"""Regression locks for the §Perf optimizations: the optimized code paths
must stay numerically equivalent to their reference formulations, and the
HLO analyzer must keep counting loop trips exactly.

Tiny models come from the session-scoped builders in tests/conftest.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.models.layers import (decode_attention, decode_attention_appended)
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# MoE gather-combine == scatter-combine (the C3 §Perf change)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dbrx-132b", "phi3.5-moe-42b-a6.6b"])
@pytest.mark.parametrize("seed", [0, 1])
def test_moe_gather_combine_matches_scatter(arch, seed):
    cfg = reduced(REGISTRY[arch])
    p = init_moe(jax.random.PRNGKey(7), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed),
                          (2, 96, cfg.d_model), jnp.float32)
    out_g, aux_g = moe_ffn(x, p, cfg, combine="gather")
    out_s, aux_s = moe_ffn(x, p, cfg, combine="scatter")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_s),
                               atol=2e-5, rtol=1e-4)
    assert float(abs(aux_g - aux_s)) < 1e-6


def test_moe_gather_combine_grad_matches_scatter():
    cfg = reduced(REGISTRY["dbrx-132b"])
    p = init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, cfg.d_model),
                          jnp.float32)

    def loss(params, combine):
        out, aux = moe_ffn(x, params, cfg, combine=combine)
        return jnp.sum(out ** 2) + aux

    gg = jax.grad(lambda p_: loss(p_, "gather"))(p)
    gs = jax.grad(lambda p_: loss(p_, "scatter"))(p)
    for k in gg:
        np.testing.assert_allclose(np.asarray(gg[k]), np.asarray(gs[k]),
                                   atol=5e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# appended decode attention == write-then-attend reference (A1/A2 change)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 8])
def test_decode_attention_appended_matches_reference(window):
    B, Smax, H, KH, D = 3, 32, 8, 4, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    # history cache laid out kv-heads-major (B, KH, Smax, D)
    kc = jnp.asarray(rng.normal(size=(B, KH, Smax, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, KH, Smax, D)), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(B, KH, D)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, KH, D)), jnp.float32)
    prev = jnp.asarray([5, 17, 31 - 1], jnp.int32)

    out = decode_attention_appended(q, kc, vc, k_new, v_new,
                                    prev_len=prev, window=window)

    # reference: write kv at prev_len into a seq-major cache, then attend
    kc_sm = jnp.swapaxes(kc, 1, 2)                       # (B, Smax, KH, D)
    vc_sm = jnp.swapaxes(vc, 1, 2)
    bidx = jnp.arange(B)
    kc_sm = kc_sm.at[bidx, prev].set(k_new)
    vc_sm = vc_sm.at[bidx, prev].set(v_new)
    ref = decode_attention(q, kc_sm, vc_sm, cur_len=prev + 1, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_scatter_new_kv_writes_expected_positions():
    from repro.models.transformer import _scatter_new_kv
    L, B, KH, S, hd = 2, 3, 2, 8, 4
    cache = jnp.zeros((L, B, KH, S, hd), jnp.float32)
    new = jnp.ones((L, B, KH, hd), jnp.float32) * \
        jnp.arange(1, B + 1)[None, :, None, None]
    lens = jnp.asarray([0, 3, 7], jnp.int32)
    out = np.asarray(_scatter_new_kv(cache, new, lens))
    for b, pos in enumerate([0, 3, 7]):
        np.testing.assert_allclose(out[:, b, :, pos, :], b + 1)
        mask = np.ones(S, bool)
        mask[pos] = False
        assert np.all(out[:, b, :, mask, :] == 0)


# ---------------------------------------------------------------------------
# HLO analyzer: trip counts exact on controlled scans
# ---------------------------------------------------------------------------

def test_hlo_analysis_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze
    W = jnp.zeros((128, 128), jnp.float32)
    x = jnp.zeros((8, 128), jnp.float32)
    Ws = jnp.zeros((10, 128, 128), jnp.float32)
    one = 2 * 8 * 128 * 128

    hlo1 = jax.jit(lambda x: x @ W).lower(x).compile().as_text()
    assert analyze(hlo1)["dot_flops"] == one

    hlo10 = jax.jit(
        lambda x: jax.lax.scan(lambda c, w: (c @ w, None), x, Ws)[0]
    ).lower(x).compile().as_text()
    assert analyze(hlo10)["dot_flops"] == 10 * one

    def nested(x, Ws):
        def micro(c, _):
            y, _ = jax.lax.scan(
                lambda h, w: (jax.checkpoint(lambda h, w: h @ w)(h, w), None),
                c, Ws)
            return y, None
        return jax.lax.scan(micro, x, None, length=5)[0]

    hlo50 = jax.jit(nested).lower(x, Ws).compile().as_text()
    assert analyze(hlo50)["dot_flops"] == 50 * one

    # XLA's own cost_analysis counts the body once — the reason this
    # module exists; guard that assumption so a jax upgrade that fixes it
    # makes us revisit
    cost = jax.jit(
        lambda x: jax.lax.scan(lambda c, w: (c @ w, None), x, Ws)[0]
    ).lower(x).compile().cost_analysis()
    if isinstance(cost, list):      # pre-0.4.30 jax returns [dict]
        cost = cost[0]
    assert cost["flops"] <= 2 * one


def test_hlo_analysis_traffic_slice_aware():
    from repro.launch.hlo_analysis import analyze
    big = jnp.zeros((64, 256), jnp.float32)

    def f(big, i):
        sl = jax.lax.dynamic_slice(big, (i, 0), (1, 256))
        return jnp.sum(sl * 2.0)

    hlo = jax.jit(f).lower(big, jnp.int32(0)).compile().as_text()
    t = analyze(hlo)["traffic_bytes"]
    # must be order slice-size (few KB), not the full 64 KB x ops
    assert t < 32 * 1024, t


def test_chunked_ce_matches_full_loss(mamba):
    """Blockwise cross-entropy (§Perf, big-vocab train cells) must match the
    full-logit loss and its gradients."""
    from repro.distributed.hints import ShardingHints, use_hints
    cfg, model, params = mamba
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                     cfg.vocab_size),
    }
    l0, _ = model.train_loss(params, batch)
    g0 = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    with use_hints(ShardingHints(ce_chunk=48)):    # 256-vocab -> 6 chunks+pad
        l1, _ = model.train_loss(params, batch)
        g1 = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    assert abs(float(l0 - l1)) < 1e-5
    import jax.tree_util as jtu
    for a, b in zip(jtu.tree_leaves(g0), jtu.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-4)
