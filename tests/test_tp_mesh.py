"""Tensor-parallel serving on a simulated mesh.

Four layers of proof on top of the mesh axis of test_parity_matrix.py:

* ``make_local_mesh`` validates its request against the visible device
  count up front (a too-large mesh would otherwise die as an opaque shape
  error inside the first jit).
* **Placement invariants** — after a real sharded engine run, params are
  TP-sharded over ``model``, paged KV pools shard the kv-head axis (MHA)
  or fall back to head_dim (GQA whose 2 kv heads don't divide 4 shards),
  while everything the sampler touches (decode state, device tables,
  lens) is fully replicated and no logits ever cross to the host.
* **MoE expert parallelism** — a reduced phi3.5-moe (4 experts = one per
  shard) decodes token-identically to single-device with its expert
  stacks sharded over ``model``.
* **Allocator replica consistency** — the paged allocator is host-side
  and replicated per shard by construction; a hypothesis churn property
  drives 4 replicas through one random admit/COW/rollback/free sequence
  and requires bit-identical snapshots plus conservation at every step.
"""
from collections import Counter

import jax
import numpy as np
import pytest

from repro.launch.mesh import make_local_mesh
from repro.serving import backends
from repro.serving.kv_cache import OutOfPages, PagedKVCache

try:        # the property test widens the seed space when hypothesis exists;
    # the fixed-seed churn tests below always run (hypothesis is a dev-only
    # dependency, see test_property.py)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_SHARDS = 4


@pytest.fixture(scope="module")
def mesh4():
    if jax.device_count() < N_SHARDS:
        pytest.skip("needs >= 4 devices; run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return make_local_mesh(1, N_SHARDS)


# -- make_local_mesh validation ----------------------------------------------

def test_make_local_mesh_shapes():
    m = make_local_mesh(1, 4)
    assert m.axis_names == ("data", "model")
    assert m.shape["data"] == 1 and m.shape["model"] == 4
    m2 = make_local_mesh(2, 4)
    assert m2.shape["data"] == 2


def test_make_local_mesh_rejects_oversize():
    with pytest.raises(ValueError, match="visible"):
        make_local_mesh(1, jax.device_count() + 1)


def test_make_local_mesh_rejects_nonpositive():
    with pytest.raises(ValueError, match="positive"):
        make_local_mesh(0, 4)
    with pytest.raises(ValueError, match="positive"):
        make_local_mesh(1, -2)


def test_kernel_backend_serves_under_mesh(qwen, engine_factory, mesh4,
                                          request_factory, run_engine):
    """use_kernel under a mesh is no longer rejected: the decode kernels
    run per-shard via shard_map over the kv-head axis (qwen's 4 MHA kv
    heads divide 4 shards), token-identical to the unsharded non-kernel
    engine."""
    cfg, model, params = qwen
    reqs = request_factory(cfg.vocab_size, n=3, plen=12, max_tokens=10)
    ref_eng = engine_factory(model, params, backend="paged",
                             max_seq_len=64, page_size=16)
    ref, _ = run_engine(ref_eng, reqs)
    backends.reset_transfer_stats()
    eng = engine_factory(model, params, backend="paged", use_kernel=True,
                         mesh=mesh4, max_seq_len=64, page_size=16)
    got, eng = run_engine(eng, reqs)
    assert got == ref
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    assert eng.backend._kernel_sharded


def test_kernel_backend_mesh_gqa_fallback(llama, engine_factory, mesh4,
                                          request_factory, run_engine):
    """GQA head counts that don't divide the model axis (llama's 2 kv
    heads on 4 shards) can't shard_map the kernel — the backend must fall
    back to the sharded reference path, still token-identical."""
    cfg, model, params = llama
    assert cfg.num_kv_heads % N_SHARDS != 0
    reqs = request_factory(cfg.vocab_size, n=3, plen=12, max_tokens=10)
    ref_eng = engine_factory(model, params, backend="paged",
                             max_seq_len=64, page_size=16)
    ref, _ = run_engine(ref_eng, reqs)
    eng = engine_factory(model, params, backend="paged", use_kernel=True,
                         mesh=mesh4, max_seq_len=64, page_size=16)
    got, eng = run_engine(eng, reqs)
    assert got == ref
    assert not eng.backend._kernel_sharded


# -- placement invariants ----------------------------------------------------

def _spec(arr, nd):
    """PartitionSpec padded to ``nd`` entries (trailing Nones explicit)."""
    s = tuple(arr.sharding.spec)
    return s + (None,) * (nd - len(s))


def _run_sharded(lm, mesh4, engine_factory, request_factory, run_engine):
    cfg, model, params = lm
    reqs = request_factory(cfg.vocab_size, n=3, plen=12, max_tokens=10)
    backends.reset_transfer_stats()
    eng = engine_factory(model, params, backend="paged", mesh=mesh4,
                        max_seq_len=64, page_size=16)
    got, eng = run_engine(eng, reqs)
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    return got, eng.backend


def test_paged_placement_mha_heads_sharded(qwen, mesh4, engine_factory,
                                           request_factory, run_engine):
    _, be = _run_sharded(qwen, mesh4, engine_factory, request_factory,
                         run_engine)
    # attention/MLP columns over model (Megatron TP)
    layers = be.params["layers"]
    assert _spec(layers["attn"]["wq"], 3)[-1] == "model"
    assert _spec(layers["mlp"]["w1"], 3)[-1] == "model"
    assert _spec(layers["attn"]["wo"], 3)[-2] == "model"
    # 4 kv heads / 4 shards: the pool (L, NP, page, KH, hd) splits on KH
    assert _spec(be.pools["k"], 5) == (None, None, None, "model", None)
    assert _spec(be.pools["v"], 5) == (None, None, None, "model", None)
    _assert_sampler_state_replicated(be)


def test_paged_placement_gqa_head_dim_fallback(llama, mesh4, engine_factory,
                                               request_factory, run_engine):
    _, be = _run_sharded(llama, mesh4, engine_factory, request_factory,
                         run_engine)
    # 2 kv heads don't divide 4 shards -> head_dim shards instead
    assert _spec(be.pools["k"], 5) == (None, None, None, None, "model")
    assert _spec(be.pools["v"], 5) == (None, None, None, None, "model")
    _assert_sampler_state_replicated(be)


def _assert_sampler_state_replicated(be):
    """The zero-logits-transfer contract: everything the fused sampler
    carries — decode state, block tables, lens — lives replicated, so each
    shard samples the same token from full logits."""
    assert be._dec_st is not None, "fused decode never ran"
    for name, leaf in be._dec_st.items():
        assert leaf.sharding.is_fully_replicated, f"_dec_st[{name}] sharded"
    tables_d, lens_d = be._dev_tables
    assert tables_d.sharding.is_fully_replicated
    assert lens_d.sharding.is_fully_replicated


# -- MoE expert parallelism --------------------------------------------------

def test_moe_expert_parallel_decode(lm_factory, mesh4, engine_factory,
                                    request_factory, run_engine):
    cfg, model, params = lm_factory("phi3.5-moe-42b-a6.6b")
    reqs = request_factory(cfg.vocab_size, n=2, plen=10, max_tokens=8)
    ref_eng = engine_factory(model, params, backend="slots",
                             fused_decode=False, max_seq_len=64)
    ref, _ = run_engine(ref_eng, reqs)

    backends.reset_transfer_stats()
    eng = engine_factory(model, params, backend="paged", mesh=mesh4,
                         max_seq_len=64, page_size=16)
    got, eng = run_engine(eng, reqs)
    assert got == ref, "expert-parallel decode diverged from single-device"
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    # expert stacks (L, E, d, f) put one expert per shard; the router
    # stays replicated so every shard computes the same top-k gates
    moe_p = eng.backend.params["layers"]["moe"]
    for w in ("w1", "w2", "w3"):
        assert _spec(moe_p[w], 4)[1] == "model", w
    assert moe_p["router"].sharding.is_fully_replicated


# -- sharded engine churn: prefix cache + COW stay consistent ---------------

def test_sharded_prefix_cache_cow_parity(qwen, mesh4, engine_factory,
                                         request_factory, run_engine,
                                         shared_prefix_prompts):
    """Shared-prefix admission (COW on the recomputed tail page) produces
    the same streams sharded as on one device, and actually hits."""
    cfg, model, params = qwen
    prompts = shared_prefix_prompts(cfg.vocab_size, 4, n_shared=32,
                                    n_tail=11)
    reqs = request_factory(cfg.vocab_size, prompts=prompts, max_tokens=8)
    kw = dict(backend="paged", max_seq_len=96, page_size=16,
              enable_prefix_cache=True)
    ref, ref_eng = run_engine(
        engine_factory(model, params, **kw), reqs)
    got, eng = run_engine(
        engine_factory(model, params, mesh=mesh4, **kw), reqs)
    assert got == ref
    assert eng.backend.kv.stats["hit_tokens"] > 0
    assert eng.backend.kv.stats["hit_tokens"] == \
        ref_eng.backend.kv.stats["hit_tokens"]


# -- costmodel: the DES mirror of tensor parallelism -------------------------

def test_costmodel_model_shards():
    from repro.configs import REGISTRY
    from repro.serving.costmodel import InstanceCost

    cfg = REGISTRY["llama3.2-3b"]
    c1 = InstanceCost(cfg=cfg, chips=8)
    c4 = InstanceCost(cfg=cfg, chips=8, model_shards=4)
    # shards=1 must be a bit-exact no-op (every existing DES output holds)
    assert c1._collective_time(8) == 0.0
    # sharding adds all-reduce time on the same chip count...
    assert c4.decode_step_time(8) > c1.decode_step_time(8)
    assert c4.prefill_time(256) > c1.prefill_time(256)
    # ...and buys per-shard HBM headroom in exchange
    assert c4.hbm_bytes_per_shard() == pytest.approx(
        c1.hbm_bytes_per_shard() / 4)
    with pytest.raises(ValueError, match="divide"):
        InstanceCost(cfg=cfg, chips=8, model_shards=3)
    with pytest.raises(ValueError, match=">= 1"):
        InstanceCost(cfg=cfg, chips=8, model_shards=0)


def test_deployment_mirrors_model_shards():
    from repro.configs import REGISTRY
    from repro.core.testbed import default_deployment

    dep = default_deployment(REGISTRY["llama3.2-3b"], model_shards=4)
    assert dep.model_shards == 4
    assert dep.cost.model_shards == 4


# -- allocator replica consistency (hypothesis) ------------------------------

def _check_conservation(c: PagedKVCache):
    """Refcounts partition exactly the pages held by block tables, and
    every non-trash page is in exactly one of {referenced, LRU, free}."""
    held = Counter(p for t in c._tables.values() for p in t)
    assert dict(held) == c._ref, "refcounts out of sync with block tables"
    free, lru, ref = set(c._free), set(c._lru), set(c._ref)
    assert not (free & lru) and not (free & ref) and not (lru & ref)
    assert free | lru | ref == set(range(1, c.num_pages))


def _drive_replicas(seed: int, n_ops: int):
    """Drive one allocator replica per simulated shard through the SAME
    random op sequence (admit with prefix reuse, COW'd appends,
    speculative rollback, free). Per-shard page tables must stay
    bit-identical at every step — this is the contract that lets
    tensor-parallel serving keep ONE host-side allocator (or one per
    shard process) without any cross-shard sync."""
    caches = [PagedKVCache(20, 4, enable_prefix_cache=True)
              for _ in range(N_SHARDS)]
    rng = np.random.default_rng(seed)
    live: set[str] = set()
    next_id = 0

    def on_all(fn):
        """Apply one op to every replica; outcomes (result or OutOfPages)
        must agree, like shard processes seeing the same request stream."""
        outs = []
        for c in caches:
            try:
                outs.append(("ok", fn(c)))
            except OutOfPages:
                outs.append(("oom", None))
        assert all(o == outs[0] for o in outs[1:]), "replicas diverged"
        return outs[0]

    for _ in range(n_ops):
        op = ["admit", "append", "rollback", "free"][
            int(rng.integers(0, 4))]
        if op == "admit":
            # half the prompts share a leading page chain -> prefix hits
            base = int(rng.integers(0, 2)) * 1000
            n_tok = int(rng.integers(3, 14))
            toks = [base + t for t in range(n_tok)]
            sid = f"s{next_id}"
            next_id += 1
            status, _ = on_all(
                lambda c: c.allocate_with_prefix(sid, list(toks)))
            if status == "ok":
                on_all(lambda c: c.commit_prefix(sid, list(toks)))
                live.add(sid)
        elif op == "append" and live:
            sid = sorted(live)[int(rng.integers(0, len(live)))]
            # COW before the write, exactly as the decode step does
            on_all(lambda c: (c.writable_page(sid, c.length(sid)),
                              c.append_token(sid))[0] is not None)
        elif op == "rollback" and live:
            sid = sorted(live)[int(rng.integers(0, len(live)))]
            cur = caches[0].length(sid)
            tgt = int(rng.integers(max(cur - 3, 0), cur + 1))
            on_all(lambda c: c.rollback_to(sid, tgt))
        elif op == "free" and live:
            sid = sorted(live)[int(rng.integers(0, len(live)))]
            on_all(lambda c: c.free(sid))
            live.discard(sid)
        snaps = [c.snapshot() for c in caches]
        assert all(s == snaps[0] for s in snaps[1:]), \
            "allocator replicas drifted apart"
        _check_conservation(caches[0])


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7])
def test_allocator_replicas_never_diverge(seed):
    _drive_replicas(seed, 40)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), n_ops=st.integers(8, 60))
    def test_allocator_replicas_never_diverge_property(seed, n_ops):
        _drive_replicas(seed, n_ops)
