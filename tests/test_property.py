"""Property-based tests (hypothesis) on system invariants: the workload
generator, the paged-KV allocator, the gateway rate limiter, sharding rules,
and the federation selector."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clock import EventLoop, VirtualClock
from repro.core.gateway import RateLimiter
from repro.data.workload import make_workload
from repro.serving.kv_cache import OutOfPages, PagedKVCache

# ---------------------------------------------------------------------------
# workload generator
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 200), rate=st.one_of(
    st.just(float("inf")), st.floats(0.1, 100.0)),
    seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_workload_invariants(n, rate, seed):
    wl = make_workload(n, rate=rate, seed=seed)
    assert len(wl) == n
    assert len({w.request_id for w in wl}) == n          # unique ids
    arr = [w.arrival for w in wl]
    assert all(a >= 0 for a in arr)
    assert arr == sorted(arr)                            # non-decreasing
    if math.isinf(rate):
        assert all(a == 0.0 for a in arr)                # saturation mode
    for w in wl:
        assert 4 <= w.prompt_tokens <= 2048
        assert 4 <= w.max_tokens <= 2048
    # determinism
    wl2 = make_workload(n, rate=rate, seed=seed)
    assert [(w.prompt_tokens, w.max_tokens, w.arrival) for w in wl] == \
        [(w.prompt_tokens, w.max_tokens, w.arrival) for w in wl2]


# ---------------------------------------------------------------------------
# paged KV allocator
# ---------------------------------------------------------------------------


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_paged_kv_allocator_invariants(data):
    num_pages = data.draw(st.integers(2, 64))
    page = data.draw(st.sampled_from([8, 16, 64, 128]))
    kv = PagedKVCache(num_pages, page)
    live: dict[str, int] = {}
    for i in range(data.draw(st.integers(1, 60))):
        op = data.draw(st.sampled_from(["alloc", "append", "free"]))
        if op == "alloc":
            n = data.draw(st.integers(1, 3 * page))
            sid = f"s{i}"
            if kv.can_allocate(n):
                pages = kv.allocate(sid, n)
                assert len(pages) == kv.pages_needed(n)
                assert 0 not in pages                    # trash page reserved
                live[sid] = n
            else:
                try:
                    kv.allocate(sid, n)
                    raise AssertionError("allocate should have raised")
                except OutOfPages:
                    pass
        elif op == "append" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            try:
                kv.append_token(sid)
                live[sid] += 1
            except OutOfPages:
                assert kv.free_pages == 0
        elif op == "free" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            kv.free(sid)
            del live[sid]
        # invariant: no page is owned twice, free + owned == num_pages - 1
        owned = [p for s in live for p in kv._tables[s]]
        assert len(owned) == len(set(owned))
        assert len(owned) + kv.free_pages == num_pages - 1
        for sid, n in live.items():
            assert len(kv._tables[sid]) >= kv.pages_needed(max(n, 1))


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_prefix_cache_allocator_invariants(data):
    """With prefix caching on, pages may be shared — the invariants become:
    refcounts exactly count owning tables, and {referenced, LRU-cached-free,
    plain-free} partition the non-trash pool."""
    from collections import Counter

    num_pages = data.draw(st.integers(4, 64))
    page = data.draw(st.sampled_from([4, 8, 16]))
    kv = PagedKVCache(num_pages, page, enable_prefix_cache=True)
    # a small prompt vocabulary makes shared prefixes (and hash hits) likely
    pool = [data.draw(st.lists(st.integers(0, 3), min_size=1,
                               max_size=3 * page)) for _ in range(3)]
    live: dict[str, int] = {}
    for i in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(["alloc", "append", "free"]))
        if op == "alloc":
            toks = list(data.draw(st.sampled_from(pool)))
            sid = f"s{i}"
            try:
                pages, n_cached = kv.allocate_with_prefix(sid, toks)
                kv.commit_prefix(sid, toks)
                live[sid] = len(toks)
                assert n_cached <= max(len(toks) - 1, 0)
                assert len(pages) == kv.pages_needed(max(len(toks), 1))
                assert 0 not in pages
            except OutOfPages:
                pass
        elif op == "append" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            try:
                kv.writable_page(sid, kv.length(sid))   # backend-side COW
                kv.append_token(sid)
                live[sid] += 1
            except OutOfPages:
                pass
        elif op == "free" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            kv.free(sid)
            del live[sid]
        owned = Counter(p for s in live for p in kv._tables[s])
        for p, n in owned.items():
            assert kv.ref_count(p) == n
        assert set(kv._free).isdisjoint(owned)
        assert set(kv._lru).isdisjoint(owned)
        assert set(kv._free).isdisjoint(kv._lru)
        assert (len(kv._free) + len(kv._lru) + len(owned)
                == num_pages - 1)


# ---------------------------------------------------------------------------
# real-engine invariants under admit/abort/preempt/step churn
# ---------------------------------------------------------------------------


_TINY = {}


def _tiny_lm():
    """Lazy module-level tiny model (hypothesis forbids function-scoped
    fixtures inside @given bodies; one build serves every example)."""
    if not _TINY:
        import jax
        from repro.configs import REGISTRY, reduced
        from repro.models import make_model
        cfg = reduced(REGISTRY["llama3.2-3b"])
        model = make_model(cfg)
        _TINY["m"] = (cfg, model, model.init_params(jax.random.PRNGKey(0)))
    return _TINY["m"]


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_engine_invariants_under_churn(data):
    """Random admit/abort/preempt/step sequences against the REAL paged
    engine, across scheduling policies: live slots never exceed
    ``max_slots``, page refcounts exactly count owning block tables (the
    {referenced, LRU, free} partition holds), and every non-aborted
    request is emitted exactly once — none lost, none duplicated."""
    from collections import Counter

    from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
    from repro.serving.request import InferenceRequest, SamplingParams

    cfg, model, params = _tiny_lm()
    policy = data.draw(st.sampled_from(["fcfs", "priority", "edf"]))
    eng = ContinuousBatchingEngine(model, params, EngineConfig(
        max_slots=2, max_seq_len=48, backend="paged", page_size=8,
        enable_prefix_cache=data.draw(st.booleans(), label="prefix_cache"),
        chunked_prefill_budget=data.draw(st.sampled_from([0, 6])),
        scheduling_policy=policy,
        enable_preemption=data.draw(st.booleans(), label="preempt")))
    rng = np.random.default_rng(0)
    added, aborted, emitted = {}, set(), {}

    def check():
        assert len(eng.running) + len(eng.prefilling) <= eng.cfg.max_slots
        kv = eng.backend.kv
        owned = Counter(p for t in kv._tables.values() for p in t)
        for p, n in owned.items():
            assert kv.ref_count(p) == n
        assert set(kv._free).isdisjoint(owned)
        assert set(kv._lru).isdisjoint(owned)
        assert (len(kv._free) + len(kv._lru) + len(set(owned))
                == kv.num_pages - 1)

    def drain_outputs(outs):
        for o in outs:
            emitted[o.request_id] = emitted.get(o.request_id, 0) + 1

    n_req = 0
    for _ in range(data.draw(st.integers(3, 14))):
        op = data.draw(st.sampled_from(
            ["add", "step", "step", "abort", "preempt"]))
        if op == "add":
            rid = f"r{n_req}"
            n_req += 1
            plen = data.draw(st.integers(2, 12))
            req = InferenceRequest(
                model="m", request_id=rid,
                prompt_tokens=rng.integers(
                    2, cfg.vocab_size, size=plen).tolist(),
                qos=data.draw(st.sampled_from(["interactive", "batch"])),
                deadline=data.draw(st.sampled_from([None, 1.0, 9.9])),
                sampling=SamplingParams(
                    max_tokens=data.draw(st.integers(1, 6))))
            eng.add_request(req)
            added[rid] = req
        elif op == "abort" and added:
            rid = data.draw(st.sampled_from(sorted(added)))
            if eng.abort(rid):
                aborted.add(rid)
        elif op == "preempt" and eng.running:
            eng.preempt(data.draw(st.sampled_from(sorted(eng.running))))
        elif op == "step":
            drain_outputs(eng.step())
        check()
    for _ in range(400):
        if not eng.has_work():
            break
        drain_outputs(eng.step())
        check()
    assert not eng.has_work(), "engine failed to drain"
    assert set(emitted) == set(added) - aborted     # none lost
    assert all(v == 1 for v in emitted.values())    # none emitted twice
    assert eng.stats["finished"] == len(emitted)


# ---------------------------------------------------------------------------
# gateway rate limiter
# ---------------------------------------------------------------------------


@given(rate=st.floats(0.5, 50.0), burst=st.floats(1.0, 20.0),
       dts=st.lists(st.floats(0.0, 2.0), min_size=1, max_size=80))
@settings(max_examples=40, deadline=None)
def test_rate_limiter_never_exceeds_budget(rate, burst, dts):
    loop = EventLoop(VirtualClock())
    rl = RateLimiter(loop, rate, burst)
    granted = 0
    t = 0.0
    for dt in dts:
        t += dt
        loop.clock._advance_to(t) if hasattr(loop.clock, "_advance_to") \
            else None
        loop.call_at(t, lambda: None)
        loop.run_until(t)
        if rl.allow("u"):
            granted += 1
        # budget: initial burst + accrued tokens
        assert granted <= burst + rate * t + 1e-6


# ---------------------------------------------------------------------------
# sharding rules validity
# ---------------------------------------------------------------------------


def test_sharding_specs_always_divide():
    # every PartitionSpec a rule emits must evenly divide the dim it shards
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import REGISTRY
    from repro.distributed.sharding import ShardingRules
    from repro.models import make_model

    # production mesh shape arithmetic without building a device mesh
    sizes = {"data": 16, "model": 16, "pod": 2}

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for name in ("qwen1.5-4b", "yi-34b", "dbrx-132b", "mamba2-130m",
                 "zamba2-2.7b", "hubert-xlarge"):
        cfg = REGISTRY[name]
        model = make_model(cfg)
        shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        for train in (True, False):
            rules = ShardingRules(FakeMesh(), cfg, train=train)
            specs = rules.param_specs(shapes)
            flat_specs, _ = jax.tree_util.tree_flatten(
                specs, is_leaf=lambda x: isinstance(x, P))
            flat_shapes, _ = jax.tree_util.tree_flatten(shapes)
            for spec, leaf in zip(flat_specs, flat_shapes):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    n = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        n *= sizes[a]
                    assert dim % n == 0, (name, spec, leaf.shape)


# ---------------------------------------------------------------------------
# federation selector ordering
# ---------------------------------------------------------------------------


class _EP:
    """Stub endpoint with controllable hot/free/queue/hosts state."""

    def __init__(self, hot, free, hosts=True, need=1, queued=0):
        self._hot = hot
        self._free = free
        self._hosts = hosts
        self.deployments = {"m": type("D", (), {
            "nodes_per_instance": need})()}
        self.scheduler = type("S", (), {
            "available_nodes": lambda s=None, f=free: f,
            "queue_depth": lambda s=None, q=queued: q})()

    def hosts(self, model):
        return self._hosts

    def model_states(self, model):
        return ["running"] if self._hot else []


def _least_loaded(eps, cands):
    """The rule-1/2 tie-break winner: shallowest scheduler queue, then
    most free nodes, then candidate (registry) order."""
    return min(cands, key=lambda e: (eps[e].scheduler.queue_depth(),
                                     -eps[e].scheduler.available_nodes(),
                                     cands.index(e)))


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_federation_never_returns_unhealthy_under_flaps(data):
    """Random endpoint states + random health flaps: select_endpoint NEVER
    returns an unhealthy (or non-hosting) endpoint, and within the healthy
    candidates it follows the §4.5 priority rules with the load tie-break
    (queue depth, then free nodes, then registry order)."""
    from repro.core.federation import FederationError, FederationRouter

    n = data.draw(st.integers(1, 5))
    ids = [f"e{i}" for i in range(n)]
    eps = {e: _EP(hot=data.draw(st.booleans(), label=f"hot_{e}"),
                  free=data.draw(st.integers(0, 3), label=f"free_{e}"),
                  hosts=data.draw(st.booleans(), label=f"hosts_{e}"),
                  queued=data.draw(st.integers(0, 2), label=f"queued_{e}"))
           for e in ids}
    order = data.draw(st.permutations(ids))
    router = FederationRouter(eps, {"m": order})
    for _ in range(data.draw(st.integers(1, 6))):
        flap = data.draw(st.sampled_from(ids))
        router.set_healthy(flap, data.draw(st.booleans()))
        healthy = [e for e in order
                   if router._healthy.get(e, False) and eps[e]._hosts]
        if not healthy:
            with pytest.raises(FederationError):
                router.select_endpoint("m")
            continue
        choice = router.select_endpoint("m")
        assert choice in healthy                      # never unhealthy/dead
        rule = router.decisions[-1][2]
        hot = [e for e in healthy if eps[e]._hot]
        free = [e for e in healthy if eps[e]._free >= 1]
        if hot:
            # rule 1 wins, at the least-loaded hot endpoint
            assert (choice, rule) == (_least_loaded(eps, hot),
                                      "active-instance")
        elif free:
            assert (choice, rule) == (_least_loaded(eps, free), "free-nodes")
        else:
            assert (choice, rule) == (healthy[0], "configured-order")
        if rule != "configured-order":
            # the tie-break inputs are recorded in the decision detail
            assert "queue_depth=" in router.decisions[-1][3]


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_scheduler_never_loses_or_double_starts_jobs(data):
    """Random submit/release/cancel/fail/restore/advance orderings: every
    job starts at most once, nodes are conserved (free + held + down
    partition the cluster), and no job is lost — each submitted job is
    always queued, started, or terminally ended/failed/cancelled."""
    from repro.core.scheduler import ClusterScheduler, JobState

    num_nodes = data.draw(st.integers(1, 6))
    loop = EventLoop(VirtualClock())
    sched = ClusterScheduler(loop, "c", num_nodes,
                             startup_delay=data.draw(
                                 st.sampled_from([0.0, 1.0, 5.0])),
                             backfill=data.draw(st.booleans()))
    started: dict[int, int] = {}
    terminal: set[int] = set()
    jobs = []

    def on_start(job):
        started[job.job_id] = started.get(job.job_id, 0) + 1
        assert job.job_id not in terminal, "started after ending"

    for i in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(
            ["submit", "release", "cancel", "fail", "restore", "advance"]))
        if op == "submit":
            jobs.append(sched.submit(
                data.draw(st.integers(1, max(num_nodes, 1))), on_start,
                walltime=data.draw(st.sampled_from([None, 2.0, 10.0]))))
        elif op == "release" and jobs:
            sched.release(data.draw(st.sampled_from(jobs)))
        elif op == "cancel" and jobs:
            sched.cancel(data.draw(st.sampled_from(jobs)))
        elif op == "fail":
            sched.fail_node(data.draw(st.integers(0, num_nodes - 1)))
        elif op == "restore":
            sched.restore_node(data.draw(st.integers(0, num_nodes - 1)))
        else:
            loop.run_until(loop.now() + data.draw(
                st.sampled_from([0.5, 1.0, 7.0])))
        # no double start
        assert all(v == 1 for v in started.values())
        # node conservation: free / held-by-live-jobs / down partition
        free = set(sched._free_nodes)
        held = [n for j in sched.jobs.values() for n in j.nodes]
        down = set(sched._down_nodes)
        assert len(held) == len(set(held))            # no node held twice
        assert free.isdisjoint(held) and free.isdisjoint(down)
        assert down.isdisjoint(held)
        assert len(free) + len(held) + len(down) == num_nodes
        # no job lost: every job is queued, holding nodes, or terminal —
        # and terminal is TERMINAL (no resurrection out of ended/failed)
        for j in jobs:
            if j.state in (JobState.ENDED, JobState.FAILED):
                assert not j.nodes
                assert j not in sched._queue
                terminal.add(j.job_id)
            else:
                assert j.job_id not in terminal, "left a terminal state"
                if j.state == JobState.QUEUED:
                    assert j in sched._queue
                else:
                    assert j.state in (JobState.STARTING, JobState.RUNNING)
                    assert len(j.nodes) == j.num_nodes
    # drain: restore the cluster and keep releasing running jobs — every
    # job must reach a terminal state with at most one start (nothing is
    # lost in the queue, nothing started twice)
    for n_id in list(sched._down_nodes):
        sched.restore_node(n_id)
    for _ in range(len(jobs) + 1):
        loop.run_until(loop.now() + 100.0)
        for j in jobs:
            if j.state in (JobState.STARTING, JobState.RUNNING):
                sched.release(j)
    loop.run_until(loop.now() + 100.0)
    for j in jobs:
        assert j.state in (JobState.ENDED, JobState.FAILED)
        assert started.get(j.job_id, 0) <= 1


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_autoscaler_caps_cooldown_and_gating(data):
    """AutoScaler can never over-provision: no scale-up beyond
    max_instances, none without free nodes, none inside the cooldown
    window, and none while the first instance is still cold — so a random
    event ordering can never double-start instances for the same signal."""
    from repro.core.autoscale import AutoScalePolicy, AutoScaler

    class _Eng:
        def __init__(self, queued, sat):
            self.queue_depth = queued
            self._sat = sat

        def saturated(self):
            return self._sat

    class _Inst:
        def __init__(self, state, queued, sat):
            self.alive = state in ("queued", "starting", "running")
            self.state = type("S", (), {"value": state})()
            self.engine = _Eng(queued, sat)
            self._pending = []

    pol = AutoScalePolicy(max_instances=data.draw(st.integers(1, 4)),
                          queue_threshold=data.draw(st.integers(1, 6)),
                          cooldown=data.draw(st.sampled_from([5.0, 30.0])))
    loop = EventLoop(VirtualClock())
    scaler = AutoScaler(loop, pol)
    instances = []
    for _ in range(data.draw(st.integers(1, 30))):
        op = data.draw(st.sampled_from(
            ["spawn", "kill", "check", "advance"]))
        if op == "spawn":
            instances.append(_Inst(
                data.draw(st.sampled_from(
                    ["queued", "starting", "running", "released"])),
                data.draw(st.integers(0, 10)), data.draw(st.booleans())))
        elif op == "kill" and instances:
            data.draw(st.sampled_from(instances)).alive = False
        elif op == "advance":
            loop.run_until(loop.now() + data.draw(
                st.sampled_from([1.0, 10.0, 60.0])))
        else:
            free = data.draw(st.integers(0, 8))
            need = data.draw(st.integers(1, 4))
            up = scaler.should_scale_up("m", instances, free, need)
            alive = [i for i in instances if i.alive]
            hot = [i for i in alive if i.state.value == "running"]
            if up:
                assert len(alive) < pol.max_instances     # admin cap holds
                assert free >= need                       # capacity exists
                assert hot                                # first one is hot
                last = scaler._last_scale.get("m", -1e18)
                assert loop.now() - last >= pol.cooldown  # outside cooldown
                scaler.record_scale("m", len(alive) + 1)
                # immediately re-asking within the same instant must gate
                assert not scaler.should_scale_up("m", instances, free,
                                                  need)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_scale_in_policy_invariants(data):
    """Hot-pool scale-IN can never violate the pool contract: no eviction
    below the min_hot floor, never an instance holding in-flight work,
    never inside the scale-in cooldown or before the keepalive expires —
    and always the longest-idle candidate."""
    from repro.core.autoscale import AutoScalePolicy, AutoScaler

    class _Inst:
        def __init__(self, state, load, idle_since):
            self.alive = state in ("queued", "starting", "running")
            self.state = type("S", (), {"value": state})()
            self.load = load
            self.idle_since = idle_since

    keepalive = data.draw(st.one_of(st.none(),
                                    st.sampled_from([5.0, 60.0])))
    pol = AutoScalePolicy(max_instances=data.draw(st.integers(1, 5)),
                          min_hot=data.draw(st.integers(0, 3)),
                          keepalive=keepalive,
                          scale_in_cooldown=data.draw(
                              st.sampled_from([0.0, 10.0])))
    loop = EventLoop(VirtualClock())
    scaler = AutoScaler(loop, pol)
    instances = []
    for _ in range(data.draw(st.integers(1, 25))):
        op = data.draw(st.sampled_from(["spawn", "advance", "check"]))
        if op == "spawn":
            t = loop.now()
            instances.append(_Inst(
                data.draw(st.sampled_from(
                    ["queued", "starting", "running", "released"])),
                data.draw(st.integers(0, 3)),
                data.draw(st.one_of(st.none(), st.floats(0.0, max(t, 1.0))))))
        elif op == "advance":
            loop.run_until(loop.now() + data.draw(
                st.sampled_from([1.0, 30.0, 120.0])))
        else:
            victim = scaler.pick_scale_in("m", instances)
            alive = [i for i in instances if i.alive]
            if victim is None:
                continue
            assert pol.keepalive is not None          # legacy mode never picks
            assert victim in alive
            assert len(alive) > pol.min_hot           # floor survives
            assert victim.state.value == "running"
            assert victim.load == 0                   # no in-flight work
            assert loop.now() - victim.idle_since >= pol.keepalive
            last = scaler._last_scale_in.get("m", -1e18)
            assert loop.now() - last >= pol.scale_in_cooldown
            # longest-idle-first among every eligible candidate
            eligible = [i for i in alive
                        if i.state.value == "running" and i.load == 0
                        and i.idle_since is not None
                        and loop.now() - i.idle_since >= pol.keepalive]
            assert victim.idle_since == min(i.idle_since for i in eligible)
            victim.alive = False
            scaler.record_scale_in("m", len(alive) - 1)
            # same instant, again: cooldown (if any) must now gate
            if pol.scale_in_cooldown > 0:
                assert scaler.pick_scale_in("m", instances) is None


@given(free_a=st.integers(0, 4), free_b=st.integers(0, 4),
       hot_a=st.booleans(), hot_b=st.booleans())
@settings(max_examples=30, deadline=None)
def test_federation_priority_rules(free_a, free_b, hot_a, hot_b):
    from repro.core.federation import FederationRouter
    eps = {"a": _EP(hot_a, free_a), "b": _EP(hot_b, free_b)}
    router = FederationRouter(eps, {"m": ["a", "b"]})
    choice = router.select_endpoint("m")
    rule = router.decisions[-1][2]
    if hot_a and hot_b:
        # rule-1 tie: equal (zero) queue depth, so free nodes decide
        want = "b" if free_b > free_a else "a"
        assert choice == want and rule == "active-instance"
    elif hot_a:
        assert choice == "a" and rule == "active-instance"
    elif hot_b:
        assert choice == "b" and rule == "active-instance"
    elif free_a >= 1 and free_b >= 1:
        want = "b" if free_b > free_a else "a"
        assert choice == want and rule == "free-nodes"
    elif free_a >= 1:
        assert choice == "a" and rule == "free-nodes"
    elif free_b >= 1:
        assert choice == "b" and rule == "free-nodes"
    else:
        assert choice == "a" and rule == "configured-order"
