"""Property-based tests (hypothesis) on system invariants: the workload
generator, the paged-KV allocator, the gateway rate limiter, sharding rules,
and the federation selector."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clock import EventLoop, VirtualClock
from repro.core.gateway import RateLimiter
from repro.data.workload import make_workload
from repro.serving.kv_cache import OutOfPages, PagedKVCache

# ---------------------------------------------------------------------------
# workload generator
# ---------------------------------------------------------------------------


@given(n=st.integers(1, 200), rate=st.one_of(
    st.just(float("inf")), st.floats(0.1, 100.0)),
    seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_workload_invariants(n, rate, seed):
    wl = make_workload(n, rate=rate, seed=seed)
    assert len(wl) == n
    assert len({w.request_id for w in wl}) == n          # unique ids
    arr = [w.arrival for w in wl]
    assert all(a >= 0 for a in arr)
    assert arr == sorted(arr)                            # non-decreasing
    if math.isinf(rate):
        assert all(a == 0.0 for a in arr)                # saturation mode
    for w in wl:
        assert 4 <= w.prompt_tokens <= 2048
        assert 4 <= w.max_tokens <= 2048
    # determinism
    wl2 = make_workload(n, rate=rate, seed=seed)
    assert [(w.prompt_tokens, w.max_tokens, w.arrival) for w in wl] == \
        [(w.prompt_tokens, w.max_tokens, w.arrival) for w in wl2]


# ---------------------------------------------------------------------------
# paged KV allocator
# ---------------------------------------------------------------------------


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_paged_kv_allocator_invariants(data):
    num_pages = data.draw(st.integers(2, 64))
    page = data.draw(st.sampled_from([8, 16, 64, 128]))
    kv = PagedKVCache(num_pages, page)
    live: dict[str, int] = {}
    for i in range(data.draw(st.integers(1, 60))):
        op = data.draw(st.sampled_from(["alloc", "append", "free"]))
        if op == "alloc":
            n = data.draw(st.integers(1, 3 * page))
            sid = f"s{i}"
            if kv.can_allocate(n):
                pages = kv.allocate(sid, n)
                assert len(pages) == kv.pages_needed(n)
                assert 0 not in pages                    # trash page reserved
                live[sid] = n
            else:
                try:
                    kv.allocate(sid, n)
                    raise AssertionError("allocate should have raised")
                except OutOfPages:
                    pass
        elif op == "append" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            try:
                kv.append_token(sid)
                live[sid] += 1
            except OutOfPages:
                assert kv.free_pages == 0
        elif op == "free" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            kv.free(sid)
            del live[sid]
        # invariant: no page is owned twice, free + owned == num_pages - 1
        owned = [p for s in live for p in kv._tables[s]]
        assert len(owned) == len(set(owned))
        assert len(owned) + kv.free_pages == num_pages - 1
        for sid, n in live.items():
            assert len(kv._tables[sid]) >= kv.pages_needed(max(n, 1))


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_prefix_cache_allocator_invariants(data):
    """With prefix caching on, pages may be shared — the invariants become:
    refcounts exactly count owning tables, and {referenced, LRU-cached-free,
    plain-free} partition the non-trash pool."""
    from collections import Counter

    num_pages = data.draw(st.integers(4, 64))
    page = data.draw(st.sampled_from([4, 8, 16]))
    kv = PagedKVCache(num_pages, page, enable_prefix_cache=True)
    # a small prompt vocabulary makes shared prefixes (and hash hits) likely
    pool = [data.draw(st.lists(st.integers(0, 3), min_size=1,
                               max_size=3 * page)) for _ in range(3)]
    live: dict[str, int] = {}
    for i in range(data.draw(st.integers(1, 40))):
        op = data.draw(st.sampled_from(["alloc", "append", "free"]))
        if op == "alloc":
            toks = list(data.draw(st.sampled_from(pool)))
            sid = f"s{i}"
            try:
                pages, n_cached = kv.allocate_with_prefix(sid, toks)
                kv.commit_prefix(sid, toks)
                live[sid] = len(toks)
                assert n_cached <= max(len(toks) - 1, 0)
                assert len(pages) == kv.pages_needed(max(len(toks), 1))
                assert 0 not in pages
            except OutOfPages:
                pass
        elif op == "append" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            try:
                kv.writable_page(sid, kv.length(sid))   # backend-side COW
                kv.append_token(sid)
                live[sid] += 1
            except OutOfPages:
                pass
        elif op == "free" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            kv.free(sid)
            del live[sid]
        owned = Counter(p for s in live for p in kv._tables[s])
        for p, n in owned.items():
            assert kv.ref_count(p) == n
        assert set(kv._free).isdisjoint(owned)
        assert set(kv._lru).isdisjoint(owned)
        assert set(kv._free).isdisjoint(kv._lru)
        assert (len(kv._free) + len(kv._lru) + len(owned)
                == num_pages - 1)


# ---------------------------------------------------------------------------
# gateway rate limiter
# ---------------------------------------------------------------------------


@given(rate=st.floats(0.5, 50.0), burst=st.floats(1.0, 20.0),
       dts=st.lists(st.floats(0.0, 2.0), min_size=1, max_size=80))
@settings(max_examples=40, deadline=None)
def test_rate_limiter_never_exceeds_budget(rate, burst, dts):
    loop = EventLoop(VirtualClock())
    rl = RateLimiter(loop, rate, burst)
    granted = 0
    t = 0.0
    for dt in dts:
        t += dt
        loop.clock._advance_to(t) if hasattr(loop.clock, "_advance_to") \
            else None
        loop.call_at(t, lambda: None)
        loop.run_until(t)
        if rl.allow("u"):
            granted += 1
        # budget: initial burst + accrued tokens
        assert granted <= burst + rate * t + 1e-6


# ---------------------------------------------------------------------------
# sharding rules validity
# ---------------------------------------------------------------------------


def test_sharding_specs_always_divide():
    # every PartitionSpec a rule emits must evenly divide the dim it shards
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import REGISTRY
    from repro.distributed.sharding import ShardingRules
    from repro.models import make_model

    # production mesh shape arithmetic without building a device mesh
    sizes = {"data": 16, "model": 16, "pod": 2}

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for name in ("qwen1.5-4b", "yi-34b", "dbrx-132b", "mamba2-130m",
                 "zamba2-2.7b", "hubert-xlarge"):
        cfg = REGISTRY[name]
        model = make_model(cfg)
        shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        for train in (True, False):
            rules = ShardingRules(FakeMesh(), cfg, train=train)
            specs = rules.param_specs(shapes)
            flat_specs, _ = jax.tree_util.tree_flatten(
                specs, is_leaf=lambda x: isinstance(x, P))
            flat_shapes, _ = jax.tree_util.tree_flatten(shapes)
            for spec, leaf in zip(flat_specs, flat_shapes):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    n = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        n *= sizes[a]
                    assert dim % n == 0, (name, spec, leaf.shape)


# ---------------------------------------------------------------------------
# federation selector ordering
# ---------------------------------------------------------------------------


@given(free_a=st.integers(0, 4), free_b=st.integers(0, 4),
       hot_a=st.booleans(), hot_b=st.booleans())
@settings(max_examples=30, deadline=None)
def test_federation_priority_rules(free_a, free_b, hot_a, hot_b):
    class EP:
        def __init__(self, hot, free):
            self._hot = hot
            self._free = free
            self.deployments = {"m": type("D", (), {
                "nodes_per_instance": 1})()}
            self.scheduler = type("S", (), {
                "available_nodes": lambda s=None, f=free: f})()

        def hosts(self, model):
            return True

        def model_states(self, model):
            return ["running"] if self._hot else []

    from repro.core.federation import FederationRouter
    eps = {"a": EP(hot_a, free_a), "b": EP(hot_b, free_b)}
    router = FederationRouter(eps, {"m": ["a", "b"]})
    choice = router.select_endpoint("m")
    rule = router.decisions[-1][2]
    if hot_a:
        assert choice == "a" and rule == "active-instance"
    elif hot_b:
        assert choice == "b" and rule == "active-instance"
    elif free_a >= 1:
        assert choice == "a" and rule == "free-nodes"
    elif free_b >= 1:
        assert choice == "b" and rule == "free-nodes"
    else:
        assert choice == "a" and rule == "configured-order"
