"""Fused decode fast path: the device-resident decode+sample+stop loop must
be token-identical to the legacy host-driven path on both backends, across
sampling modes, sync intervals, and every finish reason — and must never
transfer logits to the host (the transfer-counting hook asserts it).

Model/engine/request builders come from tests/conftest.py."""
import numpy as np
import pytest

from repro.serving import backends


@pytest.fixture
def run(engine_factory, run_engine):
    def _run(model, params, reqs, **cfg_kw):
        eng = engine_factory(model, params, **cfg_kw)
        return run_engine(eng, reqs)
    return _run


# ---------------------------------------------------------------------------
# token identity: fused == legacy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_fused_matches_legacy(llama, backend, sampling, request_factory,
                              run):
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=96, backend=backend, page_size=16)
    reqs = request_factory(cfg.vocab_size, **sampling)
    ref, _ = run(model, params, reqs, fused_decode=False, **kw)
    for K in (1, 4):
        got, _ = run(model, params, reqs, fused_decode=True,
                     decode_steps_per_sync=K, **kw)
        assert got == ref, f"K={K} diverged from legacy"


def test_fused_matches_legacy_ssm_backend(mamba, request_factory, run):
    cfg, model, params = mamba
    kw = dict(max_slots=2, max_seq_len=64, backend="slots")
    reqs = request_factory(cfg.vocab_size, n=3, temperature=0.6, top_p=0.95)
    ref, _ = run(model, params, reqs, fused_decode=False, **kw)
    got, _ = run(model, params, reqs, fused_decode=True,
                 decode_steps_per_sync=4, **kw)
    assert got == ref


def test_fused_mid_loop_stop_token_exit(llama, request_factory, run):
    """A stop token landing mid-K must truncate at exactly the same token
    as the per-step path (the device loop freezes the slot, the host
    reports reason='stop')."""
    cfg, model, params = llama
    kw = dict(max_slots=2, max_seq_len=96, backend="paged", page_size=16)
    # seeded top-p keeps the reference output diverse (greedy on a tiny
    # random model falls into short cycles, which would put the stop
    # token's first occurrence at position 0/1)
    samp = dict(max_tokens=24, temperature=0.9, top_p=0.95)
    probe = request_factory(cfg.vocab_size, n=1, **samp)
    ref, _ = run(model, params, probe, fused_decode=False, **kw)
    toks, reason = ref["r0"]
    assert reason == "length"
    first = {}
    for j, t in enumerate(toks):
        first.setdefault(t, j)
    # prefer a stop whose first occurrence lands mid-sync for K=5
    cands = sorted((j, t) for t, j in first.items()
                   if 2 <= j < 20 and (j + 1) % 5 != 0)
    if not cands:
        cands = sorted((j, t) for t, j in first.items() if j >= 1)
    j0, stop = cands[0]
    reqs = request_factory(cfg.vocab_size, n=2, stop=stop, **samp)
    ref_s, _ = run(model, params, reqs, fused_decode=False, **kw)
    got_s, _ = run(model, params, reqs, fused_decode=True,
                   decode_steps_per_sync=5, **kw)
    assert got_s == ref_s
    assert got_s["r0"][1] == "stop"
    assert got_s["r0"][0][-1] == stop
    assert len(got_s["r0"][0]) == j0 + 1


@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_fused_max_tokens_and_seq_len_exits(llama, backend, request_factory,
                                            run):
    cfg, model, params = llama
    kw = dict(max_slots=2, max_seq_len=26, backend=backend, page_size=16)
    # r0 (prompt 16, max_tokens 8) exits on max_tokens; r2 (prompt 18,
    # max_tokens 10) runs out of sequence room first: 26 - 18 = 8 < 10
    reqs = request_factory(cfg.vocab_size, n=3, plen=16, max_tokens=8)
    ref, _ = run(model, params, reqs, fused_decode=False, **kw)
    got, _ = run(model, params, reqs, fused_decode=True,
                 decode_steps_per_sync=16, **kw)
    assert got == ref
    reasons = {rid: r for rid, (_, r) in got.items()}
    assert reasons["r0"] == "length"
    assert "max_seq_len" in reasons.values()


def test_fused_composes_with_chunked_prefill_and_prefix_cache(
        llama, request_factory, run):
    cfg, model, params = llama
    kw = dict(max_slots=3, max_seq_len=128, backend="paged", page_size=16,
              chunked_prefill_budget=24, enable_prefix_cache=True)
    rng = np.random.default_rng(3)
    shared = rng.integers(2, cfg.vocab_size, size=32).tolist()
    prompts = [shared + rng.integers(2, cfg.vocab_size, size=10).tolist()
               for _ in range(5)]
    reqs = request_factory(cfg.vocab_size, prompts=prompts, max_tokens=16,
                           seed0=0)
    ref, er = run(model, params, reqs, fused_decode=False, **kw)
    got, eg = run(model, params, reqs, fused_decode=True,
                  decode_steps_per_sync=8, **kw)
    assert got == ref
    assert eg.cache_stats()["hit_tokens"] == er.cache_stats()["hit_tokens"]
    # chunked prefill actually interleaved (several chunks per admit)
    assert eg.stats["prefill_chunks"] > len(reqs)


# ---------------------------------------------------------------------------
# transfer accounting: logits never reach the host on the fused path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slots", "paged"])
def test_fused_path_transfers_no_logits(llama, backend, request_factory,
                                        run):
    cfg, model, params = llama
    kw = dict(max_slots=2, max_seq_len=64, backend=backend, page_size=16)
    reqs = request_factory(cfg.vocab_size, n=3, max_tokens=12)

    backends.reset_transfer_stats()
    _, eng = run(model, params, reqs, fused_decode=True,
                 decode_steps_per_sync=4, **kw)
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == 0
    assert backends.TRANSFER_STATS["decode_logits_bytes"] == 0
    assert eng.stats["decode_tokens"] > 0

    backends.reset_transfer_stats()
    _, eng = run(model, params, reqs, fused_decode=False, **kw)
    # legacy path pays one (max_slots, V) logits transfer per decode sync
    assert backends.TRANSFER_STATS["decode_logits_transfers"] == \
        eng.stats["decode_syncs"]
    assert backends.TRANSFER_STATS["decode_logits_bytes"] == \
        eng.stats["decode_syncs"] * kw["max_slots"] * cfg.vocab_size * 4


def test_multi_step_syncs_once_per_k_tokens(llama, request_factory, run):
    """Steady state (no prefills in flight, stable composition): the host
    syncs once per K tokens, not per token."""
    cfg, model, params = llama
    kw = dict(max_slots=2, max_seq_len=96, backend="paged", page_size=16)
    reqs = request_factory(cfg.vocab_size, n=2, plen=12, max_tokens=33)

    _, e1 = run(model, params, reqs, fused_decode=True,
                decode_steps_per_sync=1, **kw)
    _, e8 = run(model, params, reqs, fused_decode=True,
                decode_steps_per_sync=8, **kw)
    assert e1.stats["decode_tokens"] == e8.stats["decode_tokens"]
    # K=8 must use several-fold fewer syncs (admission/finish steps still
    # fall back to K=1 by design)
    assert e8.stats["decode_syncs"] * 3 < e1.stats["decode_syncs"]


def test_multi_step_keeps_k_under_saturation(llama, request_factory, run):
    """A waiting backlog (slots full, queue forming) must NOT clamp K:
    queued requests can only admit at a sync boundary anyway, and the
    saturated regime is exactly where the multi-step win matters."""
    cfg, model, params = llama
    kw = dict(max_slots=2, max_seq_len=96, backend="paged", page_size=16)
    reqs = request_factory(cfg.vocab_size, n=5, plen=12, max_tokens=24)
    ref, e1 = run(model, params, reqs, fused_decode=False, **kw)
    got, e8 = run(model, params, reqs, fused_decode=True,
                  decode_steps_per_sync=8, **kw)
    assert got == ref
    assert e8.stats["decode_syncs"] * 2 < e1.stats["decode_syncs"]


# ---------------------------------------------------------------------------
# DES mirror: SimEngine multi-step decode
# ---------------------------------------------------------------------------

def test_sim_engine_decode_steps_per_sync():
    from repro.configs import REGISTRY
    from repro.core.clock import EventLoop
    from repro.core.instances import SimEngine, SimRequest
    from repro.serving.costmodel import InstanceCost

    cost = InstanceCost(cfg=REGISTRY["llama3.2-3b"], chips=4)

    def run(k):
        loop = EventLoop()
        done = []
        eng = SimEngine(loop, cost, max_slots=4, decode_steps_per_sync=k)
        for i in range(4):
            eng.submit(SimRequest(f"r{i}", 64, 40 + i), None, done.append)
        loop.run_until_idle()
        return loop.now(), eng.total_output_tokens, sorted(
            (d["request_id"], d["output_tokens"]) for d in done)

    t1, tok1, done1 = run(1)
    t16, tok16, done16 = run(16)
    assert done1 == done16          # same tokens per request, same finishes
    assert tok1 == tok16
    assert t16 < t1                 # amortized host sync -> faster clock
    # K=1 must reproduce the pre-fused cost model exactly
    assert cost.decode_step_time(4, 512) == pytest.approx(
        cost.decode_step_time(4, 512, steps_per_sync=1))
