"""Light-mode mypy gate over the typed surfaces (api/, core/resilience).

Runs mypy exactly as CI does (config in pyproject.toml [tool.mypy]) and
fails on any reported error. Skips cleanly when mypy is not installed —
same graceful degradation as the hypothesis/zstandard extras.
"""
import pathlib
import subprocess
import sys

import pytest

pytest.importorskip("mypy")

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_mypy_clean_on_typed_surfaces():
    proc = subprocess.run(
        [sys.executable, "-m", "mypy", "--no-error-summary"],
        cwd=REPO, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        "mypy found type errors:\n" + proc.stdout + proc.stderr)
