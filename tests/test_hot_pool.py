"""Hot-pool policy engine + disaggregated prefill/decode roles, and the
bugfix-sweep regressions that ride along: hash-seeded workload ids
(PYTHONHASHSEED), the work-stealing ``_seq_of`` leak, the cold-start
cooldown bypass, and mis-costed embeddings."""
import os
import subprocess
import sys

import pytest

from repro.core.instances import InstanceState
from repro.core.testbed import LLAMA8B, build_system, default_deployment
from repro.data.workload import make_bursty_workload

MODEL = LLAMA8B.name


def _mk(dep_kw=None, clusters=("sophia",), **sys_kw):
    deps = {c: {MODEL: default_deployment(LLAMA8B, **(dep_kw or {}))}
            for c in clusters}
    return build_system(deps, **sys_kw)


def _spawn_hot(sysd, cluster="sophia", n=1, settle=60.0):
    ep = sysd.endpoints[f"{cluster}-ep"]
    for _ in range(n - len(ep._alive_instances(MODEL))):
        ep._spawn_instance(MODEL)
    sysd.loop.run_until(sysd.loop.now() + settle)
    assert ep.model_states(MODEL) == ["running"] * n
    return ep


def _submit(sysd, rid, prompt=64, max_tokens=32, user="bench", **kw):
    fut = sysd.gateway.submit(sysd.token_for(user), {
        "request_id": rid, "model": MODEL, "prompt_tokens": prompt,
        "max_tokens": max_tokens, **kw})
    return fut


# ---------------------------------------------------------------------------
# satellite (a): token_ids_for must not depend on PYTHONHASHSEED
# ---------------------------------------------------------------------------

_TOKEN_SNIPPET = """
import sys
sys.path.insert(0, {src!r})
from repro.data.workload import make_workload, token_ids_for
wl = make_workload(5, rate=2.0, seed=7)
print([token_ids_for(w, vocab=1000, seed=3)[:8] for w in wl])
"""


def test_token_ids_stable_across_hash_seeds():
    """The generator's 'deterministic given a seed' contract must hold
    across processes: builtin ``hash`` is randomized per process by
    PYTHONHASHSEED, so seeding from it made every CI run see different
    'deterministic' prompts. Two subprocesses with different hash seeds
    must agree (fails under the old hash()-seeded code)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    snippet = _TOKEN_SNIPPET.format(src=os.path.abspath(src))
    outs = []
    for hash_seed in ("1", "2"):
        env = dict(os.environ, PYTHONHASHSEED=hash_seed)
        outs.append(subprocess.run(
            [sys.executable, "-c", snippet], env=env, text=True,
            capture_output=True, check=True).stdout)
    assert outs[0] == outs[1]
    assert outs[0].strip()                      # actually produced ids


# ---------------------------------------------------------------------------
# satellite (b): work stealing must not leak the robbed engine's _seq_of
# ---------------------------------------------------------------------------

def _seq_invariant(ep):
    for insts in ep.instances.values():
        for i in insts:
            assert len(i.engine._seq_of) == len(i.engine.queue), \
                (f"{i.instance_id}: _seq_of has {len(i.engine._seq_of)} "
                 f"entries for a queue of {len(i.engine.queue)}")


def test_work_steal_pops_robbed_seq_map():
    """``_balance_queues`` moves queued entries between hot engines; the
    robbed engine's ``_seq_of`` must shrink with its queue (the documented
    invariant: the arrival order moves into the entry; the map must not
    grow with engine age). The old ``queue.clear()`` steal leaked one map
    entry per stolen request forever."""
    sysd = _mk(dep_kw=dict(max_instances=2, max_slots=1, storage_bw=40e9))
    ep = _spawn_hot(sysd, n=2)
    # saturate instance 0's engine directly: 1 runs, 5 queue on it
    eng = ep.instances[MODEL][0].engine
    from repro.core.instances import SimRequest
    for i in range(6):
        eng.submit(SimRequest(request_id=f"s{i}", prompt_tokens=16,
                              max_tokens=600), None, lambda r: None)
    assert eng.queue_depth == 5
    ep._balance_queues(MODEL)
    _seq_invariant(ep)
    # the steal actually redistributed: both engines now hold work
    loads = sorted(i.engine.load for i in ep.instances[MODEL])
    assert loads[0] >= 1
    sysd.loop.run_until_idle()
    _seq_invariant(ep)                          # drained: both maps empty
    assert sum(i.engine.total_finished for i in ep.instances[MODEL]) == 6


def test_steal_churn_keeps_seq_map_tight():
    """Fixed-seed churn property (hypothesis-style fallback): random
    submit/steal/advance cycles across two hot engines never break
    ``len(_seq_of) == len(queue)`` on any engine."""
    import random
    rng = random.Random(42)
    sysd = _mk(dep_kw=dict(max_instances=2, max_slots=1, storage_bw=40e9))
    ep = _spawn_hot(sysd, n=2)
    from repro.core.instances import SimRequest
    n = 0
    for _ in range(60):
        op = rng.choice(["submit", "submit", "steal", "advance"])
        if op == "submit":
            inst = ep.instances[MODEL][rng.randrange(2)]
            if inst.state == InstanceState.HOT:
                inst.engine.submit(
                    SimRequest(request_id=f"c{n}", prompt_tokens=8,
                               max_tokens=rng.randrange(50, 400)),
                    None, lambda r: None)
                n += 1
        elif op == "steal":
            ep._balance_queues(MODEL)
        else:
            sysd.loop.run_until(sysd.loop.now() + rng.uniform(0.01, 1.0))
        _seq_invariant(ep)
    sysd.loop.run_until_idle()
    _seq_invariant(ep)


# ---------------------------------------------------------------------------
# satellite (c): cold-start spawns must stamp the scale (cooldown + events)
# ---------------------------------------------------------------------------

def test_cold_start_spawn_starts_cooldown():
    """The cold-start spawn in ``_dispatch`` used to bypass
    ``record_scale``: the cooldown window never started, ``scale_events``
    missed the first instance, and the periodic tick could double-spawn
    right behind a cold start. Clock-driven: with a 60 s cooldown, the
    second instance must NOT appear before t=60 even under queue pressure,
    and the first (cold) spawn must be in ``scale_events``."""
    sysd = _mk(dep_kw=dict(max_instances=2, max_slots=1,
                           scale_cooldown=60.0, queue_threshold=2))
    ep = sysd.endpoints["sophia-ep"]
    futs = [_submit(sysd, f"p{i}", max_tokens=2000) for i in range(10)]
    t0 = sysd.loop.now()
    sysd.loop.run_until(t0 + 55.0)
    # cold start ~28s (20s startup + 8B at 2 GB/s); pressure is there, but
    # the cooldown from the COLD spawn holds the second instance back
    assert len(ep._alive_instances(MODEL)) == 1
    scaler = ep._autoscalers[MODEL]
    assert len(scaler.scale_events) == 1        # the cold spawn is recorded
    assert scaler.scale_events[0][0] <= t0 + 5.0
    sysd.loop.run_until(t0 + 90.0)
    assert len(ep._alive_instances(MODEL)) == 2  # delayed, not prevented
    assert len(scaler.scale_events) == 2
    assert scaler.scale_events[1][0] >= t0 + 60.0
    sysd.loop.run_until_idle()
    assert all(f.error is None for f in futs)


# ---------------------------------------------------------------------------
# satellite (d): embed tasks are costed as ONE output token
# ---------------------------------------------------------------------------

def test_embed_clamps_max_tokens_to_one():
    """'embed' is documented as generate-with-1-token, but forwarded
    ``max_tokens`` unchanged — a completions-shaped payload sent to the
    pre-registered 'embed' function was costed and slotted as a full
    generation. The endpoint-side clamp caps it."""
    sysd = _mk(dep_kw=dict(storage_bw=40e9))
    ep = _spawn_hot(sysd)
    t0 = sysd.loop.now()
    fut = ep.execute("embed", {"request_id": "e1", "model": MODEL,
                               "prompt_tokens": 64, "max_tokens": 400})
    sysd.loop.run_until_idle()
    assert fut.error is None
    res = fut.result()
    assert res["output_tokens"] == 1
    # cost assertion: one prefill + one decode step, nowhere near the
    # ~400-step generation the unclamped path would charge
    dep = ep.deployments[MODEL]
    budget = (dep.cost.prefill_time(64) + 5 * dep.cost.decode_step_time(1)
              + 1.0)
    assert res["finish_time"] - t0 < budget
    assert res["finish_time"] - t0 < 0.25 * (
        400 * dep.cost.decode_step_time(1))


# ---------------------------------------------------------------------------
# hot-pool policy engine
# ---------------------------------------------------------------------------

def test_pool_floor_prespawns_without_demand():
    """min_hot provisions warm capacity with ZERO traffic — the hot-node
    pool the paper keeps for interactive TTFT."""
    sysd = _mk(dep_kw=dict(min_hot=2, max_instances=3, keepalive=300.0))
    ep = sysd.endpoints["sophia-ep"]
    sysd.loop.run_until(60.0)
    assert ep.model_states(MODEL) == ["running", "running"]
    # and the floor refills after a failure
    ep.instances[MODEL][0].fail()
    sysd.loop.run_until(sysd.loop.now() + 60.0)
    assert ep.model_states(MODEL) == ["running", "running"]


def test_keepalive_scale_in_respects_min_hot_floor():
    """Idle instances above the floor are released once their keepalive
    expires (longest-idle first, one per scale-in cooldown); the pinned
    min_hot floor survives unbounded idleness."""
    sysd = _mk(dep_kw=dict(min_hot=1, max_instances=3, keepalive=40.0,
                           scale_in_cooldown=10.0, storage_bw=40e9))
    ep = _spawn_hot(sysd, n=3)
    scaler = ep._autoscalers[MODEL]
    sysd.loop.run_until(sysd.loop.now() + 300.0)
    assert ep.model_states(MODEL) == ["running"]     # floor holds forever
    assert len(scaler.scale_in_events) == 2
    assert ep.stats["scale_ins"] == 2
    assert sysd.schedulers["sophia"].available_nodes() == 23
    # keepalive=None (legacy) would have left idle_timeout in charge; with
    # the pool managing scale-in the instances carry no idle timer at all
    assert ep.instances[MODEL][0].idle_timeout is None


def test_scale_in_never_evicts_inflight_work():
    """An instance holding queued/running work is never an eviction
    candidate, no matter how long the pool has been over target."""
    sysd = _mk(dep_kw=dict(min_hot=1, max_instances=2, keepalive=20.0,
                           scale_in_cooldown=5.0, max_slots=4,
                           storage_bw=40e9))
    ep = _spawn_hot(sysd, n=2, settle=22.0)   # hot, but not yet idle-expired
    busy = ep.instances[MODEL][0]
    from repro.core.instances import SimRequest
    done = []
    busy.engine.submit(SimRequest(request_id="long", prompt_tokens=32,
                                  max_tokens=20000), None, done.append)
    sysd.loop.run_until(sysd.loop.now() + 60.0)
    # the idle peer was scaled in; the busy one survived with its work
    assert len(ep._alive_instances(MODEL)) == 1
    assert ep._alive_instances(MODEL)[0] is busy
    assert busy.state == InstanceState.HOT and not done
    sysd.loop.run_until_idle()
    assert done and done[0]["output_tokens"] == 20000


def _pool_bounds_run(seed):
    """Random arrival bursts against a min_hot=1 / max_instances=3 pool:
    the alive-instance count must stay within [min_hot, max_instances]
    from the first tick to the end of the run."""
    import random
    rng = random.Random(seed)
    sysd = _mk(dep_kw=dict(min_hot=1, max_instances=3, keepalive=60.0,
                           scale_in_cooldown=15.0, scale_cooldown=10.0,
                           queue_threshold=2, max_slots=2,
                           storage_bw=40e9))
    ep = sysd.endpoints["sophia-ep"]
    wl = make_bursty_workload(n_bursts=rng.randrange(2, 4),
                              burst_n=rng.randrange(5, 20),
                              rate=rng.uniform(0.5, 8.0),
                              gap=rng.uniform(20.0, 90.0), seed=seed)
    token = sysd.token_for("bench")
    for w in wl:
        sysd.loop.call_at(w.arrival + 10.0, lambda w=w: sysd.gateway.submit(
            token, {"request_id": w.request_id, "model": MODEL,
                    "prompt_tokens": w.prompt_tokens,
                    "max_tokens": w.max_tokens}))
    counts = []
    horizon = wl[-1].arrival + 400.0

    def sample():
        counts.append(len(ep._alive_instances(MODEL)))
        if sysd.loop.now() + 5.0 < horizon:
            sysd.loop.call_after(5.0, sample, daemon=True)

    sysd.loop.call_at(6.0, sample, daemon=True)   # after the first tick
    sysd.loop.run_until(horizon)
    sysd.loop.run_until_idle()
    assert counts and min(counts) >= 1 and max(counts) <= 3
    assert len(ep._alive_instances(MODEL)) == 1   # drained back to floor


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_pool_size_stays_within_bounds(seed):
        _pool_bounds_run(seed)

except ImportError:
    # no hypothesis in this environment: same property, fixed seeds
    @pytest.mark.parametrize("seed", [3, 1717, 90210])
    def test_pool_size_stays_within_bounds(seed):
        _pool_bounds_run(seed)


# ---------------------------------------------------------------------------
# disaggregated prefill/decode roles
# ---------------------------------------------------------------------------

def _mk_disagg(**dep_kw):
    kw = dict(storage_bw=40e9, max_slots=8, **dep_kw)
    deps = {
        "sophia": {MODEL: default_deployment(LLAMA8B, role="prefill-heavy",
                                             **kw)},
        "polaris": {MODEL: default_deployment(LLAMA8B, role="decode-heavy",
                                              **kw)},
    }
    sysd = build_system(deps)
    _spawn_hot(sysd, "sophia")
    _spawn_hot(sysd, "polaris")
    return sysd


def test_router_role_filter():
    sysd = _mk_disagg()
    r = sysd.router
    # fresh dispatches need prefill capability; handoffs want decode pools
    assert r.select_endpoint(MODEL) == "sophia-ep"
    assert r.select_endpoint(MODEL, role="decode") == "polaris-ep"
    assert "role=decode" in r.decisions[-1][3]
    # with the decode pool down, a handoff degrades to whatever remains
    r.set_healthy("polaris-ep", False)
    assert r.select_endpoint(MODEL, role="decode") == "sophia-ep"


def test_prefill_decode_handoff_end_to_end():
    """Requests land on the prefill pool, stream their first token there,
    then move to the decode pool via the restore machinery: no token is
    lost or duplicated, TTFT comes from the prefill side, and both pools'
    engine counters agree with the move."""
    sysd = _mk_disagg()
    n, max_tokens = 8, 64
    futs = [_submit(sysd, f"h{i}", max_tokens=max_tokens) for i in range(n)]
    sysd.loop.run_until_idle()
    assert all(f.error is None for f in futs)
    ep_p = sysd.endpoints["sophia-ep"]
    ep_d = sysd.endpoints["polaris-ep"]
    assert ep_p.stats["handoffs_out"] == n
    assert ep_d.stats["handoffs_in"] == n
    assert ep_p.stats["handoff_fallbacks"] == 0
    eng_p = ep_p.instances[MODEL][0].engine
    eng_d = ep_d.instances[MODEL][0].engine
    assert eng_p.total_handoffs == n
    # token conservation: the prefill engine produced each first token,
    # the decode engine the rest — together exactly max_tokens per request
    assert eng_p.total_output_tokens == n            # one first token each
    assert eng_d.total_output_tokens == n * (max_tokens - 1)
    assert eng_d.total_resumed_tokens == n
    for f in futs:
        res = f.result()
        assert res["output_tokens"] == max_tokens
        # the decode leg admitted it through the restore path (KV rebuilt
        # from prompt + the handed-over first token, hit rate 1.0)
        assert res["restore_cached_tokens"] >= 64
        # TTFT is the prefill-side first token, far ahead of the finish
        assert res["first_token_time"] < res["finish_time"] - 0.01
    # finishing on the decode endpoint cleaned the forwarding breadcrumbs
    assert not ep_p._handoffs


def test_handoff_streams_contiguous_offsets():
    """A streamed request keeps contiguous delta offsets across the
    prefill->decode move — the client never re-receives a token."""
    sysd = _mk_disagg()
    frames = []
    fut = sysd.gateway.submit(
        sysd.token_for("bench"),
        {"request_id": "st1", "model": MODEL, "prompt_tokens": 64,
         "max_tokens": 32, "stream": True},
        on_delta=frames.append)
    sysd.loop.run_until_idle()
    assert fut.error is None
    data = [f for f in frames if f.n_tokens]
    assert data[0].offset == 0                       # prefill's first token
    got = 0
    for f in data:
        assert f.offset == got
        got += f.n_tokens
    assert got == 32


def test_abort_forwards_across_handoff():
    """Cancellation reaching the prefill endpoint after the sequence moved
    is forwarded to the decode endpoint and frees its slot."""
    sysd = _mk_disagg()
    ep_p = sysd.endpoints["sophia-ep"]
    ep_d = sysd.endpoints["polaris-ep"]
    fut = ep_p.execute("generate", {"request_id": "ab1", "model": MODEL,
                                    "prompt_tokens": 64,
                                    "max_tokens": 50000})
    sysd.loop.run_until(sysd.loop.now() + 10.0)      # handed off, decoding
    assert ep_d.stats["handoffs_in"] == 1 and not fut.done()
    ab = ep_p.execute("abort", {"request_id": "ab1"})
    sysd.loop.run_until_idle()
    assert ab.result()["aborted"] is True
    assert fut.done() and fut.error is not None      # RequestCancelled
    assert ep_d.instances[MODEL][0].engine.load == 0


def test_handoff_falls_back_to_local_decode():
    """With no decode-capable target (peer down), the prefill engine keeps
    the sequence and decodes it locally — degraded, never dropped."""
    sysd = _mk_disagg()
    sysd.endpoints["polaris-ep"].crash()
    sysd.router.set_healthy("polaris-ep", False)
    fut = _submit(sysd, "fb1", max_tokens=24)
    sysd.loop.run_until_idle()
    assert fut.error is None
    assert fut.result()["output_tokens"] == 24
    ep_p = sysd.endpoints["sophia-ep"]
    assert ep_p.stats["handoff_fallbacks"] >= 1
    assert ep_p.stats["handoffs_out"] == 0


# ---------------------------------------------------------------------------
# real engine: the handoff is the resume machinery, token-identical
# ---------------------------------------------------------------------------

def test_prefill_decode_handoff_token_identity(llama, engine_factory,
                                               request_factory, sampling):
    """Real-engine mirror of the DES handoff: a 'prefill' engine produces
    the first token, a 'decode' engine resumes from it via the restore
    path. The stitched stream must equal an uninterrupted run token for
    token, under greedy AND seeded top-p (the sampling fixture)."""
    import copy

    cfg, model, params = llama
    (req,) = request_factory(cfg.vocab_size, n=1, plen=20, max_tokens=24,
                             **sampling)
    ref_eng = engine_factory(model, params)
    ref_eng.add_request(copy.deepcopy(req))
    (ref,) = ref_eng.run_to_completion()
    assert len(ref.output_tokens) == 24

    # prefill leg: ingest the prompt, emit exactly the first token
    pre_req = copy.deepcopy(req)
    pre_req.sampling.max_tokens = 1
    pre_eng = engine_factory(model, params)
    pre_eng.add_request(pre_req)
    (first,) = pre_eng.run_to_completion()
    assert first.output_tokens == ref.output_tokens[:1]

    # decode leg: restore (prompt + first token) and continue the stream
    dec_eng = engine_factory(model, params)
    frames = []
    dec_eng.resume_request(copy.deepcopy(req), first.output_tokens,
                           on_delta=frames.append)
    (out,) = dec_eng.run_to_completion()
    assert out.output_tokens == ref.output_tokens
    assert dec_eng.stats["resumed_tokens"] == 1
    assert dec_eng.stats["restores"] == 1
    offs = [f.offset for f in frames]
    toks = [t for f in frames for t in (f.tokens or [])]
    assert offs[0] == 1 and toks == ref.output_tokens[1:]
    assert all(f.offset + f.n_tokens == n.offset
               for f, n in zip(frames, frames[1:]))


# ---------------------------------------------------------------------------
# cold-start-aware interactive placement
# ---------------------------------------------------------------------------

def test_interactive_prefers_warm_pool():
    """Rule 1 with one warm and one still-starting endpoint: interactive
    traffic goes to the warm pool (no cold-start tail); batch keeps the
    plain load-based tie-break."""
    deps = {c: {MODEL: default_deployment(LLAMA8B)}
            for c in ("sophia", "polaris")}
    sysd = build_system(deps)
    _spawn_hot(sysd, "sophia")
    sysd.endpoints["polaris-ep"]._spawn_instance(MODEL)   # cold-starting
    sysd.loop.run_until(sysd.loop.now() + 1.0)            # still loading
    assert "running" not in sysd.endpoints["polaris-ep"].model_states(MODEL)
    pick = sysd.router.select_endpoint(MODEL, qos="interactive")
    assert pick == "sophia-ep"
    assert "warm=1" in sysd.router.decisions[-1][3]


def test_interactive_cold_placement_charges_load_time():
    """Rule 2 (everything cold): interactive placement minimizes the
    cold-start penalty — startup delay + cost.load_time — so the cluster
    with fast weight storage wins even when another has more free nodes."""
    deps = {
        "slowstore": {MODEL: default_deployment(LLAMA8B, storage_bw=1e9)},
        "faststore": {MODEL: default_deployment(LLAMA8B, storage_bw=40e9)},
    }
    # slowstore first in registry and with more nodes: it would win the
    # plain rule-2 tie-break; the cold penalty flips interactive traffic
    sysd = build_system(deps, nodes_per_cluster=24)
    sysd.schedulers["faststore"].fail_node(0)      # fewer free nodes there
    pick = sysd.router.select_endpoint(MODEL, qos="interactive")
    assert pick == "faststore-ep"
    assert "cold_penalty" in sysd.router.decisions[-1][3]
    # batch traffic keeps the paper's §4.5 tie-break (free nodes)
    assert sysd.router.select_endpoint(MODEL, qos="batch") == "slowstore-ep"
