"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked, ssd_decode_step


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, S, H, KH, D, causal, window
    (2, 256, 8, 2, 64, True, 0),
    (1, 512, 4, 4, 128, True, 0),
    (2, 384, 8, 1, 64, False, 0),     # MQA, bidirectional (encoder)
    (1, 512, 8, 2, 64, True, 128),    # sliding window
    (2, 100, 4, 2, 32, True, 0),      # non-block-multiple seq
    (1, 128, 56, 8, 128, True, 0),    # yi/llava head config
    (1, 160, 20, 20, 64, True, 0),    # qwen MHA head config
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    B, S, H, KH, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=128, k_block=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert out.dtype == dtype
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 512, 8, 64))
    k = jax.random.normal(ks[1], (2, 512, 4, 64))
    v = jax.random.normal(ks[2], (2, 512, 4, 64))
    outs = [flash_attention(q, k, v, q_block=qb, k_block=kb, interpret=True)
            for qb, kb in [(64, 64), (128, 256), (256, 128), (512, 512)]]
    for o in outs[1:]:
        assert_allclose(np.asarray(outs[0]), np.asarray(o), rtol=1e-5,
                        atol=1e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # B, H, KH, D, page, PPS, NP
    (4, 8, 2, 64, 128, 4, 32),
    (2, 4, 4, 128, 128, 8, 64),
    (3, 8, 1, 64, 256, 2, 16),        # MQA
    (2, 56, 8, 128, 128, 4, 16),      # yi head config (G=7, sublane-padded)
    (2, 12, 4, 64, 128, 4, 16),       # GQA G=3 (pads to the sublane tile)
    (1, 32, 2, 64, 128, 2, 8),        # GQA G=16 (exceeds one f32 sublane)
    (2, 40, 8, 32, 128, 3, 16),       # GQA G=5, small head dim
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(case, dtype):
    B, H, KH, D, page, PPS, NP = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KH, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KH, D), dtype)
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jax.random.randint(ks[4], (B,), 1, PPS * page + 1)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


def test_paged_attention_gqa_group_padding_is_invisible():
    """The GQA wrapper pads the query-group axis to the sublane tile; the
    padded rows must not leak: each KV head's G query heads must produce
    exactly what an unpadded per-head gather computes."""
    B, H, KH, D, page, PPS, NP = 2, 6, 2, 64, 128, 3, 8   # G=3 -> pads to 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jax.random.randint(ks[4], (B,), 1, PPS * page + 1)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert out.shape == (B, H, D)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_paged_attention_rejects_ragged_grouping():
    with pytest.raises(AssertionError, match="multiple of kv heads"):
        paged_attention(jnp.zeros((1, 6, 64)), jnp.zeros((4, 128, 4, 64)),
                        jnp.zeros((4, 128, 4, 64)),
                        jnp.zeros((1, 2), jnp.int32),
                        jnp.ones((1,), jnp.int32), interpret=True)


def test_paged_attention_page_permutation_invariance():
    """Physically permuting pages (and the table with them) must not change
    the result — the indirection property PagedAttention relies on."""
    B, H, KH, D, page, PPS, NP = 2, 8, 2, 64, 128, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jnp.array([page * PPS, page * 2 + 17])
    out1 = paged_attention(q, kp, vp, tables, lens, interpret=True)
    perm = jax.random.permutation(ks[4], NP)
    inv = jnp.argsort(perm)
    out2 = paged_attention(q, kp[inv], vp[inv], perm[tables], lens,
                           interpret=True)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

SSD_CASES = [
    # b, s, h, p, n, chunk
    (2, 256, 4, 64, 64, 64),
    (1, 512, 8, 32, 128, 128),
    (2, 200, 3, 16, 32, 64),          # non-chunk-multiple seq
    (1, 256, 24, 64, 128, 128),       # mamba2-130m layout
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd(case, dtype):
    b, s, h, p, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1).astype(jnp.float32)
    B = jax.random.normal(ks[2], (b, s, n), dtype)
    C = jax.random.normal(ks[3], (b, s, n), dtype)
    y, st = ssd(x, a, B, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_chunked(x, a, B, C, chunk)
    rt = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), **rt)
    assert_allclose(np.asarray(st), np.asarray(str_), rtol=1e-4, atol=1e-4)


def test_ssd_matches_step_recurrence():
    """Chunked kernel == token-by-token recurrence (the SSD duality)."""
    b, s, h, p, n = 1, 96, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.2
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y, st = ssd(x, a, B, C, chunk=32, interpret=True)
    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, hstate = ssd_decode_step(x[:, t], a[:, t], B[:, t], C[:, t], hstate)
        ys.append(yt)
    yr = jnp.stack(ys, axis=1)
    assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-3, atol=1e-3)
    assert_allclose(np.asarray(st), np.asarray(hstate), rtol=1e-3, atol=1e-3)
