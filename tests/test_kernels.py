"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels.flash_attention.ops import (flash_attention,
                                               paged_flash_prefill)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import (fused_decode_attention,
                                               fused_decode_attention_sharded,
                                               paged_attention,
                                               paged_attention_sharded)
from repro.kernels.paged_attention.ref import (fused_decode_attention_ref,
                                               paged_attention_ref,
                                               paged_prefill_attention_ref)
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked, ssd_decode_step


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, S, H, KH, D, causal, window
    (2, 256, 8, 2, 64, True, 0),
    (1, 512, 4, 4, 128, True, 0),
    (2, 384, 8, 1, 64, False, 0),     # MQA, bidirectional (encoder)
    (1, 512, 8, 2, 64, True, 128),    # sliding window
    (2, 100, 4, 2, 32, True, 0),      # non-block-multiple seq
    (1, 128, 56, 8, 128, True, 0),    # yi/llava head config
    (1, 160, 20, 20, 64, True, 0),    # qwen MHA head config
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    B, S, H, KH, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=128, k_block=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert out.dtype == dtype
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 512, 8, 64))
    k = jax.random.normal(ks[1], (2, 512, 4, 64))
    v = jax.random.normal(ks[2], (2, 512, 4, 64))
    outs = [flash_attention(q, k, v, q_block=qb, k_block=kb, interpret=True)
            for qb, kb in [(64, 64), (128, 256), (256, 128), (512, 512)]]
    for o in outs[1:]:
        assert_allclose(np.asarray(outs[0]), np.asarray(o), rtol=1e-5,
                        atol=1e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # B, H, KH, D, page, PPS, NP
    (4, 8, 2, 64, 128, 4, 32),
    (2, 4, 4, 128, 128, 8, 64),
    (3, 8, 1, 64, 256, 2, 16),        # MQA
    (2, 56, 8, 128, 128, 4, 16),      # yi head config (G=7, sublane-padded)
    (2, 12, 4, 64, 128, 4, 16),       # GQA G=3 (pads to the sublane tile)
    (1, 32, 2, 64, 128, 2, 8),        # GQA G=16 (exceeds one f32 sublane)
    (2, 40, 8, 32, 128, 3, 16),       # GQA G=5, small head dim
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(case, dtype):
    B, H, KH, D, page, PPS, NP = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KH, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KH, D), dtype)
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jax.random.randint(ks[4], (B,), 1, PPS * page + 1)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


def test_paged_attention_gqa_group_padding_is_invisible():
    """The GQA wrapper pads the query-group axis to the sublane tile; the
    padded rows must not leak: each KV head's G query heads must produce
    exactly what an unpadded per-head gather computes."""
    B, H, KH, D, page, PPS, NP = 2, 6, 2, 64, 128, 3, 8   # G=3 -> pads to 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jax.random.randint(ks[4], (B,), 1, PPS * page + 1)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert out.shape == (B, H, D)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_paged_attention_rejects_ragged_grouping():
    with pytest.raises(AssertionError, match="multiple of kv heads"):
        paged_attention(jnp.zeros((1, 6, 64)), jnp.zeros((4, 128, 4, 64)),
                        jnp.zeros((4, 128, 4, 64)),
                        jnp.zeros((1, 2), jnp.int32),
                        jnp.ones((1,), jnp.int32), interpret=True)


def test_paged_attention_page_permutation_invariance():
    """Physically permuting pages (and the table with them) must not change
    the result — the indirection property PagedAttention relies on."""
    B, H, KH, D, page, PPS, NP = 2, 8, 2, 64, 128, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jnp.array([page * PPS, page * 2 + 17])
    out1 = paged_attention(q, kp, vp, tables, lens, interpret=True)
    perm = jax.random.permutation(ks[4], NP)
    inv = jnp.argsort(perm)
    out2 = paged_attention(q, kp[inv], vp[inv], perm[tables], lens,
                           interpret=True)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


# edge geometry: page-boundary lengths, single-page, one-token, empty
# context — the cases the serving allocator actually produces
EDGE_LEN_CASES = [
    # page, PPS, lens (None entries filled below)
    (16, 4, [16, 32]),                # context_len % page_size == 0
    (16, 4, [64, 48]),                # full table, and 3 exact pages
    (16, 1, [7, 16]),                 # single-page table, partial + full
    (16, 4, [1, 17]),                 # one token; first token of page 2
]


@pytest.mark.parametrize("case", EDGE_LEN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_edge_lengths(case, dtype):
    page, PPS, lens = case
    B, H, KH, D, NP = len(lens), 6, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KH, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KH, D), dtype)
    tables = jnp.arange(B * PPS, dtype=jnp.int32).reshape(B, PPS) % NP
    lens = jnp.asarray(lens, jnp.int32)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


def test_paged_attention_empty_context_is_finite():
    """A zero-length row has no valid positions: the kernel's normalizer
    clamp must yield finite output (zeros), never NaN, and live rows in
    the same batch must be unaffected. (The jnp reference softmaxes the
    all-masked row to uniform instead — the two paths only have to agree
    on rows that can actually be sampled from.)"""
    B, H, KH, D, page, PPS, NP = 2, 4, 2, 32, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    tables = jnp.arange(B * PPS, dtype=jnp.int32).reshape(B, PPS)
    lens = jnp.asarray([0, 20], jnp.int32)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert_allclose(np.asarray(out[1]), np.asarray(ref[1]), rtol=2e-5,
                    atol=2e-5)


# ---------------------------------------------------------------------------
# fused decode-tail attention
# ---------------------------------------------------------------------------

FUSED_CASES = [
    # B, H, KH, D, page, PPS, NP, Kt
    (3, 8, 2, 64, 16, 4, 16, 4),
    (2, 56, 8, 32, 16, 4, 16, 16),    # yi grouping G=7 (sublane-padded)
    (2, 4, 4, 32, 16, 2, 8, 1),       # MHA, K=1 tail
    (2, 4, 1, 32, 16, 2, 8, 5),       # MQA, odd tail (pads to sublane)
]


@pytest.mark.parametrize("case", FUSED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_decode_attention(case, dtype):
    B, H, KH, D, page, PPS, NP, Kt = case
    ks = jax.random.split(jax.random.PRNGKey(21), 6)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KH, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KH, D), dtype)
    kt = jax.random.normal(ks[3], (B, Kt, KH, D), dtype)
    vt = jax.random.normal(ks[4], (B, Kt, KH, D), dtype)
    tables = jnp.arange(B * PPS, dtype=jnp.int32).reshape(B, PPS) % NP
    lens = jax.random.randint(ks[5], (B,), 0, PPS * page + 1)
    tail_lens = (jnp.arange(B, dtype=jnp.int32) * Kt // max(B - 1, 1)) \
        if B > 1 else jnp.full((B,), Kt, jnp.int32)
    tail_lens = jnp.maximum(tail_lens, 1)  # >= 1 like the fused loop
    out = fused_decode_attention(q, kp, vp, tables, lens, kt, vt,
                                 tail_lens, interpret=True)
    ref = fused_decode_attention_ref(q, kp, vp, tables, lens, kt, vt,
                                     tail_lens)
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


def test_fused_decode_attention_equals_materialized_pages():
    """Committing the tail into the pages and running plain paged
    attention over context_len + tail_len must give the same answer — the
    deferred-commit contract of the fused decode loop."""
    B, H, KH, D, page, PPS, NP, Kt = 2, 8, 2, 64, 16, 4, 32, 4
    ks = jax.random.split(jax.random.PRNGKey(22), 6)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    kt = jax.random.normal(ks[3], (B, Kt, KH, D))
    vt = jax.random.normal(ks[4], (B, Kt, KH, D))
    # disjoint tables so the committed tails can't collide across rows
    tables = jnp.arange(1, 1 + B * PPS, dtype=jnp.int32).reshape(B, PPS)
    lens = jnp.asarray([13, 32], jnp.int32)   # mid-page and page-boundary
    tail_lens = jnp.asarray([4, 3], jnp.int32)
    out = fused_decode_attention(q, kp, vp, tables, lens, kt, vt,
                                 tail_lens, interpret=True)
    kp2, vp2 = kp, vp
    for b in range(B):
        for j in range(int(tail_lens[b])):
            pos = int(lens[b]) + j
            pid = int(tables[b, pos // page])
            kp2 = kp2.at[pid, pos % page].set(kt[b, j])
            vp2 = vp2.at[pid, pos % page].set(vt[b, j])
    ref = paged_attention_ref(q, kp2, vp2, tables, lens + tail_lens)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged flash prefill
# ---------------------------------------------------------------------------

PREFILL_CASES = [
    # B, C, H, KH, D, page, PPS, NP, start
    (2, 16, 8, 2, 64, 16, 4, 16, 0),      # fresh prompt chunk
    (1, 16, 4, 4, 32, 16, 4, 8, 32),      # later chunk (cached prefix)
    (2, 8, 56, 8, 32, 16, 2, 8, 8),       # yi grouping, tiny chunk
    (1, 5, 4, 1, 32, 16, 1, 4, 0),        # MQA, ragged chunk, single page
    (1, 16, 4, 2, 32, 16, 4, 8, 15),      # chunk straddles a page boundary
]


@pytest.mark.parametrize("case", PREFILL_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_prefill(case, dtype):
    B, C, H, KH, D, page, PPS, NP, start = case
    ks = jax.random.split(jax.random.PRNGKey(31), 3)
    q = jax.random.normal(ks[0], (B, C, H, D), dtype)
    kp = jax.random.normal(ks[1], (NP, page, KH, D), dtype)
    vp = jax.random.normal(ks[2], (NP, page, KH, D), dtype)
    tables = jnp.arange(B * PPS, dtype=jnp.int32).reshape(B, PPS) % NP
    kv_len = start + C
    assert kv_len <= PPS * page
    out = paged_flash_prefill(q, kp, vp, tables, start, kv_len,
                              interpret=True)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, start, kv_len)
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    **tol(dtype))


# ---------------------------------------------------------------------------
# shard_map variants (simulated mesh)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh4():
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices; run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(1, 4)


def test_paged_attention_sharded_matches_unsharded(mesh4):
    """shard_map over the kv-head axis (8 kv heads / 4 shards): per-shard
    kernels must reproduce the single-device kernel bit-for-bit — the
    heads are independent, no collective touches the math."""
    B, H, KH, D, page, PPS, NP = 2, 16, 8, 32, 16, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(41), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    tables = jax.random.randint(ks[3], (B, PPS), 0, NP)
    lens = jax.random.randint(ks[4], (B,), 1, PPS * page + 1)
    ref = paged_attention(q, kp, vp, tables, lens, interpret=True)
    out = paged_attention_sharded(q, kp, vp, tables, lens, mesh=mesh4,
                                  interpret=True)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=0, atol=0)


def test_fused_decode_attention_sharded_matches_unsharded(mesh4):
    B, H, KH, D, page, PPS, NP, Kt = 2, 8, 4, 32, 16, 2, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(42), 6)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (NP, page, KH, D))
    vp = jax.random.normal(ks[2], (NP, page, KH, D))
    kt = jax.random.normal(ks[3], (B, Kt, KH, D))
    vt = jax.random.normal(ks[4], (B, Kt, KH, D))
    tables = jax.random.randint(ks[5], (B, PPS), 0, NP)
    lens = jnp.asarray([16, 9], jnp.int32)
    tail_lens = jnp.asarray([2, 4], jnp.int32)
    ref = fused_decode_attention(q, kp, vp, tables, lens, kt, vt,
                                 tail_lens, interpret=True)
    out = fused_decode_attention_sharded(q, kp, vp, tables, lens, kt, vt,
                                         tail_lens, mesh=mesh4,
                                         interpret=True)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

SSD_CASES = [
    # b, s, h, p, n, chunk
    (2, 256, 4, 64, 64, 64),
    (1, 512, 8, 32, 128, 128),
    (2, 200, 3, 16, 32, 64),          # non-chunk-multiple seq
    (1, 256, 24, 64, 128, 128),       # mamba2-130m layout
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd(case, dtype):
    b, s, h, p, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1).astype(jnp.float32)
    B = jax.random.normal(ks[2], (b, s, n), dtype)
    C = jax.random.normal(ks[3], (b, s, n), dtype)
    y, st = ssd(x, a, B, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_chunked(x, a, B, C, chunk)
    rt = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32), **rt)
    assert_allclose(np.asarray(st), np.asarray(str_), rtol=1e-4, atol=1e-4)


def test_ssd_matches_step_recurrence():
    """Chunked kernel == token-by-token recurrence (the SSD duality)."""
    b, s, h, p, n = 1, 96, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.2
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y, st = ssd(x, a, B, C, chunk=32, interpret=True)
    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, hstate = ssd_decode_step(x[:, t], a[:, t], B[:, t], C[:, t], hstate)
        ys.append(yt)
    yr = jnp.stack(ys, axis=1)
    assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-3, atol=1e-3)
    assert_allclose(np.asarray(st), np.asarray(hstate), rtol=1e-3, atol=1e-3)
