"""firstlint (repro.analysis) — rule fixtures, suppressions, CLI, and the
run-on-repo regression that keeps the serving stack's invariants enforced.

Each rule has a bad fixture (every violation flagged) and a good fixture
(the idiomatic pattern, zero findings). The mutation regressions textually
delete each invalidation call / version bump from the REAL serving sources
and assert the cache-invalidation rule notices — that is the property the
issue gates on: the hand-enumerated invalidation inventory cannot drift.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import analyze_paths, analyze_source, get_rules
from repro.analysis.framework import Report
from repro.analysis.rules import ALL_RULES, RULES_BY_NAME

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "lint"
REPO_PATHS = ["src", "tests", "benchmarks", "scripts", "examples"]


def run_on(path: pathlib.Path, rules=None):
    kept, waived = analyze_source(path.read_text(), str(path),
                                  get_rules(rules))
    return kept, waived


def cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)


# ---------------------------------------------------------------------------
# per-rule fixtures: bad flags, good passes
# ---------------------------------------------------------------------------

CASES = [
    ("host-sync-in-hot-path", "host_sync_bad.py", "host_sync_good.py", 5),
    ("cache-invalidation", "cache_invalidation_bad.py",
     "cache_invalidation_good.py", 5),
    ("pallas-kernel-safety", "pallas_safety_bad.py",
     "pallas_safety_good.py", 5),
    ("donation-safety", "donation_bad.py", "donation_good.py", 2),
    ("wire-schema", "wire_schema_bad.py", "wire_schema_good.py", 3),
]


@pytest.mark.parametrize("rule,bad,good,n_bad",
                         CASES, ids=[c[0] for c in CASES])
def test_rule_flags_bad_fixture(rule, bad, good, n_bad):
    kept, waived = run_on(FIXTURES / bad)
    assert len(kept) == n_bad, [f.render() for f in kept]
    assert {f.rule for f in kept} == {rule}
    assert waived == 0
    for f in kept:
        assert f.line > 0 and f.message


@pytest.mark.parametrize("rule,bad,good,n_bad",
                         CASES, ids=[c[0] for c in CASES])
def test_rule_passes_good_fixture(rule, bad, good, n_bad):
    kept, waived = run_on(FIXTURES / good)
    assert kept == [], [f.render() for f in kept]
    assert waived == 0


def test_rule_registry_complete():
    assert len(ALL_RULES) == 5
    assert set(RULES_BY_NAME) == {c[0] for c in CASES}
    with pytest.raises(KeyError):
        get_rules(["no-such-rule"])


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_inline_nextline_and_all_suppressions():
    kept, waived = run_on(FIXTURES / "suppressed.py")
    assert kept == [], [f.render() for f in kept]
    assert waived == 3          # same-line, next-line, disable=all


def test_file_level_suppression():
    kept, waived = run_on(FIXTURES / "suppressed_file.py")
    assert kept == []
    assert waived == 2


def test_suppression_is_rule_specific():
    src = (
        "import jax\nimport numpy as np\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.asarray(x)"
        "  # firstlint: disable=wire-schema -- wrong rule name\n")
    kept, waived = analyze_source(src, "t.py", get_rules())
    assert len(kept) == 1 and kept[0].rule == "host-sync-in-hot-path"
    assert waived == 0


def test_parse_error_is_unsuppressable_finding():
    kept, _ = analyze_source("def broken(:\n", "t.py", get_rules())
    assert len(kept) == 1 and kept[0].rule == "parse-error"


# ---------------------------------------------------------------------------
# JSON output schema + CLI behavior
# ---------------------------------------------------------------------------

def test_json_output_schema_and_exit_code():
    proc = cli(str(FIXTURES / "wire_schema_bad.py"), "--format=json")
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["version"] == 1 and doc["tool"] == "firstlint"
    assert doc["files_checked"] == 1 and doc["suppressed"] == 0
    assert doc["counts"] == {"wire-schema": 3}
    assert len(doc["findings"]) == 3
    for f in doc["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message"}


def test_cli_clean_file_exits_zero():
    proc = cli(str(FIXTURES / "wire_schema_good.py"))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 findings" in proc.stdout


def test_cli_rule_subset_and_list_rules():
    proc = cli(str(FIXTURES / "host_sync_bad.py"), "--rules=wire-schema")
    assert proc.returncode == 0          # host-sync findings not selected
    proc = cli("--list-rules")
    assert proc.returncode == 0
    for name in RULES_BY_NAME:
        assert name in proc.stdout
    proc = cli("--rules=bogus", str(FIXTURES / "wire_schema_good.py"))
    assert proc.returncode == 2


def test_report_to_dict_roundtrips_through_json():
    report = analyze_paths([str(FIXTURES / "donation_bad.py")], get_rules())
    assert isinstance(report, Report)
    doc = json.loads(json.dumps(report.to_dict()))
    assert doc["counts"]["donation-safety"] == 2


# ---------------------------------------------------------------------------
# run-on-repo regression: the tree must be clean
# ---------------------------------------------------------------------------

def test_repo_is_clean_under_all_rules():
    report = analyze_paths([str(REPO / p) for p in REPO_PATHS], get_rules())
    assert report.files_checked > 50
    assert report.findings == [], "\n".join(
        f.render() for f in report.findings)
    assert not report.errors


def test_directory_walk_skips_fixtures_but_explicit_path_checks_them():
    walked = analyze_paths([str(FIXTURES.parent.parent)], get_rules())
    fixture_paths = {str(FIXTURES / "host_sync_bad.py")}
    assert not {f.path for f in walked.findings} & fixture_paths
    explicit = analyze_paths([str(FIXTURES / "host_sync_bad.py")],
                             get_rules())
    assert len(explicit.findings) == 5


# ---------------------------------------------------------------------------
# mutation regressions against the real serving sources
# ---------------------------------------------------------------------------

def _delete_line_findings(path: pathlib.Path, needle: str):
    """Delete each line equal to ``needle`` (stripped) in turn; yield the
    cache-invalidation findings that deletion produces."""
    lines = path.read_text().splitlines(keepends=True)
    rules = get_rules(["cache-invalidation"])
    sites = [i for i, l in enumerate(lines) if l.strip() == needle]
    assert sites, f"no {needle!r} lines found in {path}"
    for i in sites:
        mutated = "".join(lines[:i] + lines[i + 1:])
        kept, _ = analyze_source(mutated, str(path), rules)
        yield i + 1, kept


def test_deleting_any_invalidation_call_in_backends_is_caught():
    path = REPO / "src" / "repro" / "serving" / "backends.py"
    seen = 0
    for line_no, kept in _delete_line_findings(path,
                                               "self._invalidate_view()"):
        assert kept, f"deleting backends.py:{line_no} went unnoticed"
        assert all(f.rule == "cache-invalidation" for f in kept)
        seen += 1
    assert seen == 7      # the documented seven-site inventory


def test_deleting_table_version_bumps_in_kv_cache_is_caught():
    path = REPO / "src" / "repro" / "serving" / "kv_cache.py"
    caught = 0
    for _line_no, kept in _delete_line_findings(path,
                                                "self.table_version += 1"):
        caught += bool(kept)
    # every bump guarding a block-table mutation is load-bearing (one bump
    # protects a lens-only re-upload, outside this rule's contract)
    assert caught >= 6


def test_unchanged_serving_sources_are_clean():
    for rel in ("src/repro/serving/backends.py",
                "src/repro/serving/kv_cache.py"):
        path = REPO / rel
        kept, _ = analyze_source(path.read_text(), str(path), get_rules())
        assert kept == [], "\n".join(f.render() for f in kept)
