"""/v1 schema contract tests: validation, the error taxonomy, canonical
serialization, and the golden-file round-trip check (serialize -> parse ->
serialize must be byte-stable against the committed fixtures in
``tests/golden/`` — regenerate them with
``PYTHONPATH=src python tests/golden/regen.py`` when the contract
deliberately changes)."""
import json
import pathlib

import pytest

from repro.api import errors, schemas

GOLDEN = pathlib.Path(__file__).parent / "golden"


# ---------------------------------------------------------------------------
# examples: one representative instance per /v1 schema (shared with regen.py)
# ---------------------------------------------------------------------------

def schema_examples():
    chat_req = schemas.ChatCompletionRequest(
        model="llama3.3-70b",
        messages=[schemas.ChatMessage("system", "You are terse."),
                  schemas.ChatMessage("user", "Say hi")],
        max_tokens=32, temperature=0.7, top_p=0.9, seed=11, stream=True,
        user="alice", qos="interactive", priority=1, deadline=12.5,
        request_id="chat-1").validate()
    comp_req = schemas.CompletionRequest(
        model="llama3.3-70b", prompt_tokens=[3, 1, 4, 1, 5, 9],
        max_tokens=16, stop_token=7, request_id="comp-1",
        qos="batch").validate()
    comp_req_count = schemas.CompletionRequest(
        model="llama3.3-70b", prompt_tokens=128, max_tokens=64,
        prompt_hash="abc123", request_id="comp-2").validate()
    emb_req = schemas.EmbeddingRequest(
        model="hubert-xlarge", input=[2, 7, 1, 8], request_id="emb-1"
        ).validate()
    usage = schemas.Usage(prompt_tokens=128, completion_tokens=64,
                          total_tokens=192, cached_tokens=96)
    chat_resp = schemas.ChatCompletionResponse(
        id="chat-1", model="llama3.3-70b", created=4.25, usage=usage,
        endpoint_id="sophia-ep", first_token_time=4.5, finish_time=9.75,
        prefill_chunks=3, preemptions=1, restore_cached_tokens=40,
        choices=[schemas.CompletionChoice(index=0, tokens=[5, 6, 7],
                                          finish_reason="length")])
    comp_resp = schemas.CompletionResponse(
        id="comp-1", model="llama3.3-70b", created=1.0, usage=usage,
        endpoint_id="polaris-ep",
        choices=[schemas.CompletionChoice(finish_reason="stop")])
    emb_resp = schemas.EmbeddingResponse(
        id="emb-1", model="hubert-xlarge", created=2.0,
        usage=schemas.Usage(prompt_tokens=4, total_tokens=4),
        endpoint_id="sophia-ep",
        data=[{"object": "embedding", "index": 0, "embedding": None}])
    delta = schemas.StreamDelta(id="chat-1", index=3, tokens=[17, 19],
                                n_tokens=2, created=5.125)
    final = schemas.StreamDelta(id="chat-1", index=4, tokens=[], n_tokens=0,
                                created=6.0, finished=True,
                                finish_reason="length")
    batch_req = schemas.BatchRequest(
        items=[schemas.BatchItem(custom_id="a", body=comp_req),
               schemas.BatchItem(custom_id="b", body=comp_req_count,
                                 url="/v1/completions")],
        metadata={"run": "nightly"}).validate()
    batch_status = schemas.BatchStatus(
        id="batch-1", status="in_progress", model="llama3.3-70b",
        created_at=0.5, in_progress_at=90.0, total=2, completed=1,
        failed=0, output_tokens=64)
    err = errors.RateLimitError("user alice exceeded 1 req/s",
                                retry_after=0.75)
    return {
        "chat_completion_request": chat_req,
        "completion_request_ids": comp_req,
        "completion_request_count": comp_req_count,
        "embedding_request": emb_req,
        "usage": usage,
        "chat_completion_response": chat_resp,
        "completion_response": comp_resp,
        "embedding_response": emb_resp,
        "stream_delta": delta,
        "stream_delta_final": final,
        "batch_request": batch_req,
        "batch_status": batch_status,
        "error_rate_limit": err,
    }


_PARSERS = {
    "chat_completion_request": schemas.ChatCompletionRequest.from_dict,
    "completion_request_ids": schemas.CompletionRequest.from_dict,
    "completion_request_count": schemas.CompletionRequest.from_dict,
    "embedding_request": schemas.EmbeddingRequest.from_dict,
    "usage": schemas.Usage.from_dict,
    "chat_completion_response": schemas.ChatCompletionResponse.from_dict,
    "completion_response": schemas.CompletionResponse.from_dict,
    "embedding_response": schemas.EmbeddingResponse.from_dict,
    "stream_delta": schemas.StreamDelta.from_dict,
    "stream_delta_final": schemas.StreamDelta.from_dict,
    "batch_request": schemas.BatchRequest.from_dict,
    "batch_status": schemas.BatchStatus.from_dict,
    "error_rate_limit": errors.error_from_dict,
}


# ---------------------------------------------------------------------------
# golden round-trip: byte-stable against committed fixtures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(_PARSERS))
def test_golden_roundtrip_byte_stable(name):
    obj = schema_examples()[name]
    path = GOLDEN / f"{name}.json"
    assert path.exists(), \
        f"missing golden fixture {path}; run tests/golden/regen.py"
    committed = path.read_text().strip()
    # 1) today's code serializes the example exactly as committed
    assert schemas.dumps(obj) == committed
    # 2) parse -> serialize is byte-stable
    parsed = _PARSERS[name](json.loads(committed))
    assert schemas.dumps(parsed) == committed


def test_wire_envelope_roundtrip():
    ex = schema_examples()
    for name in ("chat_completion_request", "completion_request_ids",
                 "embedding_request"):
        req = ex[name]
        wire = schemas.to_wire(req)
        assert wire["v"] == schemas.API_VERSION
        back = schemas.from_wire(json.loads(json.dumps(wire)))
        assert type(back) is type(req)
        assert schemas.dumps(back) == schemas.dumps(req)


# ---------------------------------------------------------------------------
# validation + taxonomy
# ---------------------------------------------------------------------------

def test_invalid_requests_reject_with_param():
    with pytest.raises(errors.InvalidRequestError) as e:
        schemas.CompletionRequest.from_dict({"model": "m",
                                             "prompt_tokens": -1})
    assert e.value.param == "prompt_tokens"
    with pytest.raises(errors.InvalidRequestError):
        schemas.CompletionRequest.from_dict({"prompt_tokens": 8})  # no model
    with pytest.raises(errors.InvalidRequestError):
        schemas.CompletionRequest.from_dict(
            {"model": "m", "prompt_tokens": 8, "max_tokens": 0})
    with pytest.raises(errors.InvalidRequestError):
        schemas.ChatCompletionRequest.from_dict({"model": "m"})  # no prompt
    with pytest.raises(errors.InvalidRequestError):
        schemas.parse_request({"model": "m", "prompt_tokens": 4,
                               "api": "images"})
    with pytest.raises(errors.InvalidRequestError):
        schemas.CompletionRequest.from_dict(
            {"model": "m", "prompt_tokens": 4, "qos": "realtime"})


def test_error_taxonomy_codes_and_wire_shape():
    cases = [
        (errors.InvalidRequestError("x"), "invalid_request_error", 400),
        (errors.AuthenticationError("x"), "authentication_error", 401),
        (errors.ModelNotFoundError("x"), "model_not_found", 404),
        (errors.RateLimitError("x", retry_after=1.5), "rate_limit_error",
         429),
        (errors.OverloadedError("x"), "overloaded", 503),
        (errors.RequestCancelled("x"), "request_cancelled", 499),
    ]
    for err, code, status in cases:
        assert err.code == code and err.status == status
        d = err.to_dict()
        assert d["error"]["code"] == code
        back = errors.error_from_dict(d)
        assert type(back) is type(err)
    assert errors.RateLimitError("x", retry_after=1.5) \
        .to_dict()["error"]["retry_after"] == 1.5


def test_content_hash_semantics():
    # same token count, different ids -> different hashes
    a = schemas.CompletionRequest(model="m", prompt_tokens=[1, 2, 3])
    b = schemas.CompletionRequest(model="m", prompt_tokens=[4, 5, 6])
    assert a.content_hash != b.content_hash
    # count-only prompts carry no content identity
    c = schemas.CompletionRequest(model="m", prompt_tokens=3)
    assert c.content_hash is None
    # explicit hash wins
    d = schemas.CompletionRequest(model="m", prompt_tokens=3,
                                  prompt_hash="h")
    assert d.content_hash == "h"
    # chat: message content hashes differ even at equal lengths
    m1 = schemas.ChatCompletionRequest(
        model="m", messages=[schemas.ChatMessage("user", "aa bb")])
    m2 = schemas.ChatCompletionRequest(
        model="m", messages=[schemas.ChatMessage("user", "cc dd")])
    assert m1.content_hash != m2.content_hash
    assert m1.prompt_token_count == m2.prompt_token_count == 2


def test_legacy_mapping_access():
    resp = schema_examples()["chat_completion_response"]
    assert resp["output_tokens"] == 64
    assert resp["cached_prompt_tokens"] == 96
    assert resp["endpoint"] == "sophia-ep"
    assert resp["request_id"] == "chat-1"
    assert resp.get("nope", 0) == 0
    st = schema_examples()["batch_status"]
    assert st["state"] == "in_progress" and st["total"] == 2
