"""Training substrate: loss decreases, grad-accum equivalence, checkpoint
round-trip + resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduced
from repro.data.tokens import TokenDataset
from repro.distributed.checkpoint import (checkpoint_path, latest_checkpoint,
                                          load_checkpoint, save_checkpoint)
from repro.models import make_model
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_training, make_train_step


def _setup(arch="llama3.2-3b", batch=8, seq=32):
    cfg = reduced(REGISTRY[arch])
    model = make_model(cfg)
    params, opt_state = init_training(model, jax.random.PRNGKey(0))
    ds = TokenDataset(cfg.vocab_size, seq, batch, seed=1,
                      input_kind=cfg.input_kind, d_model=cfg.d_model)
    return cfg, model, params, opt_state, ds


def test_loss_decreases():
    cfg, model, params, opt_state, ds = _setup()
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2,
                                                      warmup_steps=5,
                                                      total_steps=200)),
                   donate_argnums=(0, 1))
    losses = []
    for _ in range(30):
        params, opt_state, m = step(params, opt_state, ds.next_batch())
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_accumulation_equivalence():
    cfg, model, params, opt_state, ds = _setup(batch=8)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, grad_clip=0.0)
    batch = ds.next_batch()
    s1 = jax.jit(make_train_step(model, ocfg, num_microbatches=1))
    s4 = jax.jit(make_train_step(model, ocfg, num_microbatches=4))
    p1, o1, m1 = s1(params, opt_state, batch)
    p4, o4, m4 = s4(params, opt_state, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-5
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4


def test_moe_and_ssm_train_step():
    for arch in ["phi3.5-moe-42b-a6.6b", "mamba2-130m", "zamba2-2.7b",
                 "hubert-xlarge"]:
        cfg, model, params, opt_state, ds = _setup(arch, batch=4, seq=32)
        step = jax.jit(make_train_step(model, AdamWConfig()),
                       donate_argnums=(0, 1))
        for _ in range(2):
            params, opt_state, m = step(params, opt_state, ds.next_batch())
        assert np.isfinite(float(m["loss"])), arch


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, model, params, opt_state, ds = _setup(batch=4)
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=0)
    step = jax.jit(make_train_step(model, ocfg))
    for _ in range(3):
        params, opt_state, _ = step(params, opt_state, ds.next_batch())

    path = checkpoint_path(str(tmp_path), 3)
    save_checkpoint(path, {"params": params, "opt": opt_state},
                    step=3, metadata={"data": ds.state()})
    assert latest_checkpoint(str(tmp_path)) == path

    # continue original
    p_a, o_a = params, opt_state
    for _ in range(2):
        p_a, o_a, m_a = step(p_a, o_a, ds.next_batch())

    # restore and continue — must reproduce the same trajectory
    tree, step_no, meta = load_checkpoint(
        path, target={"params": params, "opt": opt_state})
    assert step_no == 3
    ds2 = TokenDataset(cfg.vocab_size, 32, 4, seed=1)
    ds2.restore(meta["data"])
    p_b, o_b = tree["params"], tree["opt"]
    for _ in range(2):
        p_b, o_b, m_b = step(p_b, o_b, ds2.next_batch())
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p_a, p_b)
    assert max(jax.tree.leaves(diffs)) == 0.0
    assert float(m_a["loss"]) == float(m_b["loss"])


def test_checkpoint_bf16_preserved(tmp_path):
    x = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.5,
         "b": jnp.ones((3,), jnp.float32)}
    p = os.path.join(tmp_path, "t.ckpt")
    save_checkpoint(p, x, step=1)
    y, s, _ = load_checkpoint(p, target=x)
    assert y["w"].dtype == jnp.bfloat16
    assert jnp.array_equal(y["w"], x["w"]) and s == 1


def test_dataset_cursor_determinism():
    ds1 = TokenDataset(128, 16, 4, seed=9)
    b1 = [ds1.next_batch() for _ in range(3)]
    ds2 = TokenDataset(128, 16, 4, seed=9)
    ds2.restore({"step": 1, "seed": 9})
    b2 = ds2.next_batch()
    assert np.array_equal(b1[1]["tokens"], b2["tokens"])
    with pytest.raises(AssertionError):
        ds2.restore({"step": 0, "seed": 8})
