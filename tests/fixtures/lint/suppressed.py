"""Fixture: every violation explicitly waived (0 findings, 3 suppressed)."""
import jax
import numpy as np


@jax.jit
def step(x):
    a = np.asarray(x)  # firstlint: disable=host-sync-in-hot-path -- fixture
    # firstlint: disable-next-line=host-sync-in-hot-path -- fixture
    b = x.item()
    c = x.tolist()  # firstlint: disable=all -- fixture
    return a + b + c
