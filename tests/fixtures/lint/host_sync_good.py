"""Fixture: clean hot path; host syncs only in host-side wrappers."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def good_step(x):
    return jnp.maximum(x, 0)


def host_driver(x):
    # host side (not reachable FROM a jit root): syncs are the point here
    out = good_step(x)
    return int(np.asarray(out)[0])
