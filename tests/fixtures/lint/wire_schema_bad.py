"""Fixture: ad-hoc wire envelopes (all flagged)."""
API_VERSION = "v1"


def send_abort(ep, rid):
    return ep.execute("abort", {"v": "v1", "request_id": rid})


def send_fake_envelope(ep):
    return ep.execute("generate", {"kind": "completion.request", "data": {}})


def send_const(ep, rid):
    return ep.execute("abort", {"v": API_VERSION, "request_id": rid})
