"""Fixture: file-level waiver silences the whole module."""
# firstlint: disable-file=wire-schema -- fixture exercises file waivers
API_VERSION = "v1"


def send(ep, rid):
    return ep.execute("abort", {"v": "v1", "request_id": rid})


def send2(ep, rid):
    return ep.execute("abort", {"v": API_VERSION, "request_id": rid})
