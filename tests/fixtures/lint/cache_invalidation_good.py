"""Fixture: every mutation bumps/invalidates (or is exempt by contract)."""


class Cache:
    def __init__(self):
        self._tables = {}
        self._lens = {}
        self.table_version = 0

    def allocate(self, seq):
        self._tables[seq] = [0]
        self.table_version += 1

    def grow(self, seq, page):
        table = self._tables[seq]
        table.append(page)
        self.table_version += 1

    def advance(self, seq):
        # lens-only mutation: intentionally NOT a table mutation
        self._lens[seq] = self._lens.get(seq, 0) + 1

    def lookup(self, seq):
        return self._tables.get(seq)  # reads never need a bump


class Backend:
    def __init__(self):
        self.pools = {}
        self._ctx_view = None

    def _invalidate_view(self):
        self._ctx_view = None

    def prefill(self, new_pools):
        self.pools = new_pools
        self._invalidate_view()

    def fused_decode(self, step):
        # fused-loop contract: view maintained in place by the donated call
        self.pools, self._ctx_view = step(self.pools, self._ctx_view)
