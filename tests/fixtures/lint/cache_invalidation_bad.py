"""Fixture: table/pool mutations missing their invalidation (all flagged)."""


class Cache:
    def __init__(self):
        self._tables = {}
        self.table_version = 0

    def allocate(self, seq):
        self._tables[seq] = [0]       # no version bump

    def grow(self, seq, page):
        table = self._tables[seq]
        table.append(page)            # alias mutation, no version bump

    def drop(self, seq):
        del self._tables[seq]         # delete, no version bump


class Backend:
    def __init__(self):
        self.pools = {}
        self._ctx_view = None

    def _invalidate_view(self):
        self._ctx_view = None

    def prefill(self, new_pools):
        self.pools = new_pools        # no invalidation call

    def reupload(self, tables):
        self._dev_tables = tables     # no invalidation call
