"""Fixture: the guarded online-softmax shape the real kernels use."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def good_kernel(x_ref, o_ref, acc_scr):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _store():
        # helper only ever called from a guarded region: counts as guarded
        o_ref[...] = acc_scr[...]

    @pl.when(i > 0)
    def _commit():
        acc_scr[...] = acc_scr[...] + x_ref[...]
        _store()

    live = jnp.where(i > 0, 1.0, 0.0)   # data-level select, not a branch
    return live


def aligned_spec(chunk):
    # symbolic dims and size-1 squeezed axes are trusted/exempt
    return [pl.BlockSpec((8, 128), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk, 256), lambda i: (0, i, 0))]
